//! Failover availability (simulated): reproduce the paper's Figure 7
//! story interactively — pick a consistency mode and watch the
//! availability timeline around a leader crash.
//!
//! ```bash
//! cargo run --release --example failover_availability -- leaseguard
//! cargo run --release --example failover_availability -- loglease
//! ```

use leaseguard::cluster::Cluster;
use leaseguard::config::{ConsistencyMode, Params};
use leaseguard::figures::{fig7, Scale};
use leaseguard::linearizability;
use leaseguard::report::timeline_chart;

fn main() -> anyhow::Result<()> {
    let mode: ConsistencyMode = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "leaseguard".into())
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))?;
    let p = fig7::params_for(&Params::default(), mode, Scale(1.0));
    println!(
        "mode={mode}  ET={}ms  Δ={}ms  crash at {}ms",
        p.election_timeout_us / 1000,
        p.lease_duration_us / 1000,
        p.crash_leader_at_us / 1000
    );
    let rep = Cluster::new(p.clone()).run();
    println!(
        "{}",
        timeline_chart(
            &["reads/s", "writes/s"],
            &[rep.series.ok_rate_per_sec(true), rep.series.ok_rate_per_sec(false)],
            p.bucket_us as f64 / 1000.0,
        )
    );
    println!(
        "elections={}  limbo region on new leader={} entries",
        rep.elections, rep.limbo_len
    );
    let during_wait = rep.series.window_totals(true, 1_000_000, 1_500_000);
    println!(
        "reads while new leader awaits old lease: {}/{} ok",
        during_wait.ok,
        during_wait.ok + during_wait.failed
    );
    linearizability::assert_linearizable(&rep.history);
    println!("linearizability: OK");
    Ok(())
}
