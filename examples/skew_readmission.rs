//! Skew vs read admission (Figure 8): how workload skewness limits the
//! inherited-lease reads a new leader can serve while awaiting a lease,
//! judged both by the scalar path and the AOT-compiled XLA engine.
//!
//! ```bash
//! make artifacts && cargo run --release --example skew_readmission
//! ```

use leaseguard::config::Params;
use leaseguard::figures::{fig8, Scale};

fn main() -> anyhow::Result<()> {
    std::fs::create_dir_all("results").ok();
    let report = fig8::run(&Params::default(), Scale(1.0), "results")?;
    println!("{report}");
    println!("CSV written to results/fig8.csv");
    Ok(())
}
