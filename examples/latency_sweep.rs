//! Latency vs network latency (simulated Figure 6): how does each
//! consistency mechanism's read/write latency respond as one-way
//! latency grows from LAN to WAN?
//!
//! ```bash
//! cargo run --release --example latency_sweep
//! ```

use leaseguard::config::Params;
use leaseguard::figures::{fig6, Scale};

fn main() {
    std::fs::create_dir_all("results").ok();
    let report = fig6::run(&Params::default(), Scale(0.5), "results");
    println!("{report}");
    println!("CSV written to results/fig6.csv");
}
