//! Run a real 3-node cluster in-process and drive it with the open-loop
//! client — the smallest "deploy it" demo: servers on loopback TCP,
//! leader election, consistent reads with zero roundtrips, and a
//! planned leader handover via the §5.1 end-lease entry is left as an
//! exercise (see `Node::begin_stepdown`).
//!
//! ```bash
//! cargo run --release --example serve_cluster -- --param consistency=leaseguard
//! ```

use std::time::Duration;

use leaseguard::cli::Args;
use leaseguard::client::run_open_loop;
use leaseguard::config::Params;
use leaseguard::figures::realcluster::RealCluster;
use leaseguard::linearizability;
use leaseguard::report::fmt_us;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(|e| anyhow::anyhow!(e))?;
    let mut p = Params::default();
    args.apply_params(&mut p).map_err(|e| anyhow::anyhow!(e))?;
    p.duration_us = p.duration_us.min(3_000_000);

    let cluster = RealCluster::spawn(&p, Duration::ZERO, None)?;
    let leader = cluster
        .wait_for_leader(Duration::from_secs(10))
        .ok_or_else(|| anyhow::anyhow!("no leader"))?;
    println!("cluster up at {:?}; node {leader} leads", cluster.addrs);

    let rep = run_open_loop(&cluster.addrs, &p, Some(cluster.applies.clone()))?;
    println!(
        "completed {}/{} ops; read p90={} write p90={}",
        rep.completed,
        rep.sent,
        fmt_us(rep.read_latency.p90()),
        fmt_us(rep.write_latency.p90())
    );
    linearizability::assert_linearizable(&rep.history);
    println!("linearizability: OK");
    cluster.shutdown();
    Ok(())
}
