//! Quickstart: the end-to-end driver (DESIGN.md §End-to-end validation).
//!
//! Spins up a real 3-node LeaseGuard cluster over TCP (loopback), loads
//! the AOT-compiled XLA read-admission artifact, runs an open-loop
//! read/write workload, crashes the leader mid-run, and verifies:
//!
//! 1. the new leader serves inherited-lease reads while waiting for the
//!    old lease to expire (the paper's headline availability claim);
//! 2. the full client history is linearizable (§6.2 checker);
//! 3. latency/throughput are reported like the paper's evaluation.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::time::Duration;

use leaseguard::client::run_open_loop;
use leaseguard::config::{ConsistencyMode, Params};
use leaseguard::figures::realcluster::RealCluster;
use leaseguard::linearizability;
use leaseguard::report::{fmt_us, timeline_chart};
use leaseguard::runtime::EngineHandle;

fn main() -> anyhow::Result<()> {
    // 1. Parameters: the paper's Q2 scenario, gently scaled for a laptop.
    let mut p = Params::default();
    p.consistency = ConsistencyMode::LeaseGuard;
    p.election_timeout_us = 500_000; // ET = 500ms
    p.lease_duration_us = 1_000_000; // Δ = 2·ET, exposing the window
    p.interarrival_us = 500.0; // ~2000 ops/s open loop
    p.write_fraction = 1.0 / 3.0;
    p.value_bytes = 1024;
    p.zipf_a = 0.5;
    p.duration_us = 3_000_000;
    p.use_xla_admission = true;

    // 2. XLA admission engine (Layer 1/2, AOT — `make artifacts`).
    let engine = match EngineHandle::spawn(std::path::Path::new("artifacts")) {
        Ok(e) => {
            println!("XLA read-admission engine loaded (batched limbo checks on the leader)");
            Some(e)
        }
        Err(e) => {
            println!("engine unavailable ({e}); falling back to scalar admission");
            None
        }
    };

    // 3. Real cluster on loopback TCP.
    let mut cluster = RealCluster::spawn(&p, Duration::ZERO, engine)?;
    let leader = cluster
        .wait_for_leader(Duration::from_secs(10))
        .ok_or_else(|| anyhow::anyhow!("no leader elected"))?;
    println!("3-node cluster up; node {leader} leads; starting open-loop workload");

    // 4. Open-loop client + mid-run leader crash.
    let addrs = cluster.addrs.clone();
    let applies = cluster.applies.clone();
    let pc = p.clone();
    let client = std::thread::spawn(move || run_open_loop(&addrs, &pc, Some(applies)));
    std::thread::sleep(Duration::from_millis(500));
    println!(">>> crashing the leader (node {leader})");
    cluster.kill(leader);
    let rep = client.join().expect("client")?;
    cluster.shutdown();

    // 5. Report.
    println!(
        "\n{}",
        timeline_chart(
            &["reads/s", "writes/s"],
            &[rep.series.ok_rate_per_sec(true), rep.series.ok_rate_per_sec(false)],
            p.bucket_us as f64 / 1000.0,
        )
    );
    println!(
        "reads : p50={} p90={} p99={} ok={}",
        fmt_us(rep.read_latency.p50()),
        fmt_us(rep.read_latency.p90()),
        fmt_us(rep.read_latency.p99()),
        rep.read_latency.count(),
    );
    println!(
        "writes: p50={} p90={} p99={} ok={}",
        fmt_us(rep.write_latency.p50()),
        fmt_us(rep.write_latency.p90()),
        fmt_us(rep.write_latency.p99()),
        rep.write_latency.count(),
    );
    let wait_window = rep.series.window_totals(true, 1_000_000, 1_500_000);
    println!(
        "reads during [election, old-lease-expiry): {} ok / {} attempted",
        wait_window.ok,
        wait_window.ok + wait_window.failed
    );

    // 6. Linearizability (§6.2).
    let viol = linearizability::check(&rep.history);
    if viol.is_empty() {
        println!("linearizability: OK over {} operations", rep.history.entries.len());
        Ok(())
    } else {
        for v in viol.iter().take(5) {
            eprintln!("violation: op {} key {}: {}", v.op, v.key, v.detail);
        }
        anyhow::bail!("{} linearizability violations", viol.len());
    }
}
