//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build container has no crates.io access, so this vendored shim
//! implements exactly the surface the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`] and [`bail!`] macros, and the
//! [`Context`] extension trait. Errors are flattened to their display
//! string at conversion time — good enough for a CLI / test harness
//! that only ever formats them.

use std::fmt;

/// `Result<T>` defaulting the error type to [`Error`] (the same alias
/// trick the real crate uses, so `Result<T, E>` still works).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed dynamic error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (what `anyhow!` expands
    /// to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` and `{e:#}` (the real crate's chain format) both print
        // the flattened message here.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`,
// which is what makes this blanket conversion coherent (mirroring the
// real crate's specialization-free trick).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// Context extension for `Result` and `Option` (message-prefixing only).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn conversions_and_macros() {
        let e: Error = io_err().into();
        assert!(format!("{e}").contains("boom"));
        let e = anyhow!("x = {}", 7);
        assert_eq!(format!("{e:#}"), "x = 7");
        let e = anyhow!(String::from("plain"));
        assert_eq!(format!("{e:?}"), "plain");
    }

    #[test]
    fn context_prefixes() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "opening artifacts").unwrap_err();
        assert!(format!("{e}").starts_with("opening artifacts: "));
        let n: Option<u32> = None;
        assert!(n.context("missing").is_err());
    }

    #[test]
    fn bail_returns() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("nope {}", 1);
            }
            Ok(3)
        }
        assert_eq!(f(false).unwrap(), 3);
        assert_eq!(format!("{}", f(true).unwrap_err()), "nope 1");
    }
}
