//! Offline **stub** of the `xla` (xla_extension / PJRT) bindings.
//!
//! The build container cannot link a real PJRT runtime, so this crate
//! provides the exact API surface `leaseguard::runtime::engine` uses,
//! with every constructor returning an error at runtime.
//! [`crate::PjRtClient::cpu`] failing is the designed degradation path:
//! `AdmissionEngine::load` reports "engine unavailable" and callers fall
//! back to the scalar admission oracle, which implements the identical
//! decision. Swap this path dependency for the real `xla` crate to run
//! the AOT artifacts.

use std::fmt;

/// Error type; the engine formats it with `{:?}`.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

type R<T> = Result<T, XlaError>;

fn unavailable<T>() -> R<T> {
    Err(XlaError(
        "PJRT unavailable: built against the offline xla stub (scalar admission fallback applies)"
            .to_string(),
    ))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> R<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> R<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> R<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub: unreachable in practice because the
/// client constructor fails first).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> R<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> R<Literal> {
        unavailable()
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn to_tuple1(self) -> R<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> R<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("stub"));
    }
}
