"""Layer-2 JAX model: LeaseGuard batched read admission.

This is the vectorized form of the paper's ``ClientRead`` gate (Fig 2,
lines 17-26) that a new leader evaluates over the whole queue of pending
reads after an election: lease-age check AND limbo-region conflict check
(the conflict check is the Layer-1 Pallas kernel).

The function is lowered once, at build time, by ``aot.py`` to
``artifacts/read_admission_b{B}_k{K}.hlo.txt`` and executed from the Rust
coordinator's hot path via PJRT.  Python never runs at request time.

ABI (all int32, fixed shapes per artifact):
  inputs : query_hashes[B], limbo_hashes[K] (PAD_SENTINEL-padded),
           scalars[4] = [commit_age_us, delta_us, has_own_term_commit,
                         reserved]
  output : (admit[B],) int32 0/1 — return_tuple=True, unwrap with
           to_tuple1() on the Rust side.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.limbo_mask import limbo_conflict

# Shape points compiled into artifacts. (B, K) — B pending reads judged
# against K limbo-region writes. The Rust runtime picks the smallest
# artifact that fits and pads.
ARTIFACT_SHAPES = ((256, 128), (1024, 256))


def read_admission(query_hashes, limbo_hashes, scalars):
    """Batched admission decision. See module docstring for the ABI.

    scalars[0] = commit_age_us  — conservative age (now.latest -
                  entry.earliest) of the newest committed entry.
    scalars[1] = delta_us       — lease duration Δ.
    scalars[2] = has_own_term_commit (0/1) — when 1 the limbo region is
                  empty (leader committed in its own term) and conflicts
                  are ignored.
    scalars[3] = reserved (0).
    """
    commit_age_us = scalars[0]
    delta_us = scalars[1]
    has_own_term_commit = scalars[2]

    lease_valid = commit_age_us < delta_us
    conflict = limbo_conflict(query_hashes, limbo_hashes)
    no_limbo_block = jnp.logical_or(has_own_term_commit != 0, conflict == 0)
    admit = jnp.logical_and(lease_valid, no_limbo_block)
    return (admit.astype(jnp.int32),)
