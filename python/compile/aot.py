"""AOT bridge: lower the Layer-2 model to HLO text for the Rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate links) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts
Writes one artifact per (B, K) shape point plus a manifest.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ARTIFACT_SHAPES, read_admission


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    Rust side unwraps with to_tuple1())."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_shape(b: int, k: int) -> str:
    q = jax.ShapeDtypeStruct((b,), jnp.int32)
    l = jax.ShapeDtypeStruct((k,), jnp.int32)
    s = jax.ShapeDtypeStruct((4,), jnp.int32)
    return to_hlo_text(jax.jit(read_admission).lower(q, l, s))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"model": "read_admission", "abi": "v1", "artifacts": []}
    for b, k in ARTIFACT_SHAPES:
        name = f"read_admission_b{b}_k{k}.hlo.txt"
        path = os.path.join(args.out, name)
        text = lower_shape(b, k)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append({"file": name, "b": b, "k": k})
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
