"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must produce bit-identical (integer/bool) results against the functions
here, across the shape/dtype sweep in ``python/tests/test_kernel.py``.

The semantics mirror LeaseGuard's read-admission rule (paper §3.3 / §7.1):
a read of key ``k`` on a new leader that holds an *inherited* lease is
admitted iff no entry in the limbo region touches ``k``.  Keys are
represented by 32-bit hashes (the Rust coordinator hashes key strings and
folds; collisions only ever *reject* a read, never wrongly admit one —
see rust/src/runtime/).
"""

from __future__ import annotations

import jax.numpy as jnp

# Sentinel hash used to pad the limbo-hash vector up to the compiled K.
# The Rust side reserves this value (it remaps any real key hashing to the
# sentinel onto a fixed substitute), so padding slots can never match a
# query.
PAD_SENTINEL = -2147483648  # i32::MIN


def limbo_conflict_ref(query_hashes: jnp.ndarray, limbo_hashes: jnp.ndarray) -> jnp.ndarray:
    """Reference conflict mask.

    Args:
      query_hashes: int32[B] — hashes of keys the queued reads touch.
      limbo_hashes: int32[K] — hashes of keys written in the limbo region,
        padded with PAD_SENTINEL.

    Returns:
      bool[B] — True where the read conflicts with the limbo region
      (i.e. must be rejected while awaiting a lease).
    """
    q = query_hashes.reshape(-1, 1)
    l = limbo_hashes.reshape(1, -1)
    valid = l != jnp.int32(PAD_SENTINEL)
    return jnp.any((q == l) & valid, axis=1)


def read_admission_ref(
    query_hashes: jnp.ndarray,
    limbo_hashes: jnp.ndarray,
    commit_age_us: jnp.ndarray,
    delta_us: jnp.ndarray,
    has_own_term_commit: jnp.ndarray,
) -> jnp.ndarray:
    """Reference for the full Layer-2 admission decision (paper Fig 2,
    ClientRead lines 17-26).

    A read is admitted iff:
      * the newest committed entry is < delta old (lease valid), AND
      * either the leader has committed in its own term (no limbo region)
        or the read does not conflict with the limbo region.

    Args:
      query_hashes: int32[B].
      limbo_hashes: int32[K] (PAD_SENTINEL-padded; ignored when
        ``has_own_term_commit``).
      commit_age_us: int32[] — age of the newest committed entry in
        microseconds, computed on the Rust side from interval clocks
        (conservatively: now.latest - entry.earliest).
      delta_us: int32[] — lease duration.
      has_own_term_commit: int32[] (0/1) — newest committed entry is in
        the leader's own term.

    Returns:
      bool[B] — True where the read may be served locally.
    """
    lease_valid = commit_age_us < delta_us
    conflict = limbo_conflict_ref(query_hashes, limbo_hashes)
    no_limbo_block = jnp.logical_or(has_own_term_commit != 0, jnp.logical_not(conflict))
    return jnp.logical_and(lease_valid, no_limbo_block)
