"""Layer-1 Pallas kernel: tiled limbo-region conflict mask.

Computes, for a batch of B query-key hashes and K limbo-entry key hashes,
``mask[b] = any_k(query[b] == limbo[k] and limbo[k] != PAD_SENTINEL)``.

Tiling (the TPU mapping of the paper's ``unordered_set`` check, see
DESIGN.md §Hardware-Adaptation): the grid is (B/BB, K/BK) with the K axis
innermost.  Each program instance loads a (BB,) query block and a (BK,)
limbo block into VMEM, broadcast-compares them on the VPU, and ORs the
row-reduction into the (BB,) output block, which stays resident in VMEM
across the K-axis iterations (output BlockSpec ignores the K grid index).
This is the canonical accumulate-over-inner-grid-axis Pallas pattern —
the HBM↔VMEM schedule a CUDA version would express with threadblocks.

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU performance is estimated analytically in DESIGN.md
§Perf-estimates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PAD_SENTINEL

# Default block sizes. 128 matches the TPU lane width; the VMEM footprint
# per instance is BB*4 + BK*4 + BB*4 bytes ≈ 1.5 KiB at the defaults.
DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_K = 128


def _conflict_kernel(q_ref, l_ref, o_ref):
    """One (BB, BK) tile: o[b] |= any(q[b] == l[k], valid k)."""
    j = pl.program_id(1)
    q = q_ref[...]  # (BB,)
    l = l_ref[...]  # (BK,)
    valid = l != jnp.int32(PAD_SENTINEL)
    hit = jnp.any((q[:, None] == l[None, :]) & valid[None, :], axis=1)
    hit = hit.astype(jnp.int32)

    # First K-tile initializes the accumulator, later tiles OR into it.
    @pl.when(j == 0)
    def _init():
        o_ref[...] = hit

    @pl.when(j != 0)
    def _acc():
        o_ref[...] = o_ref[...] | hit


@functools.partial(jax.jit, static_argnames=("block_b", "block_k"))
def limbo_conflict(
    query_hashes: jnp.ndarray,
    limbo_hashes: jnp.ndarray,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_k: int = DEFAULT_BLOCK_K,
) -> jnp.ndarray:
    """Pallas conflict mask. Shapes must be multiples of the block sizes
    (the Rust caller pads with PAD_SENTINEL / a reserved no-match hash).

    Returns int32[B] of 0/1 (bool is kept out of the kernel ABI so the
    Rust side reads a plain int32 buffer).
    """
    b, k = query_hashes.shape[0], limbo_hashes.shape[0]
    if b % block_b or k % block_k:
        raise ValueError(f"shapes ({b},{k}) must be multiples of blocks ({block_b},{block_k})")
    grid = (b // block_b, k // block_k)
    return pl.pallas_call(
        _conflict_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_k,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(query_hashes.astype(jnp.int32), limbo_hashes.astype(jnp.int32))
