"""Layer-2 correctness: read_admission model vs ref, plus AOT lowering.

Checks the full admission decision (lease age + limbo conflicts) against
the pure-jnp oracle, and that every artifact shape point lowers to valid
HLO text containing the expected entry computation.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.aot import lower_shape
from compile.kernels.ref import PAD_SENTINEL, read_admission_ref
from compile.model import ARTIFACT_SHAPES, read_admission

real_hash = st.integers(min_value=-(2**31) + 1, max_value=2**31 - 1)


def run_both(q, l, age, delta, own):
    q = np.asarray(q, np.int32)
    l = np.asarray(l, np.int32)
    scalars = np.array([age, delta, own, 0], np.int32)
    (got,) = read_admission(q, l, scalars)
    want = read_admission_ref(
        q, l, np.int32(age), np.int32(delta), np.int32(own)
    ).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(got), want)
    return np.asarray(got)


class TestAdmission:
    def test_expired_lease_rejects_all(self):
        q = np.arange(256, dtype=np.int32)
        l = np.full(128, PAD_SENTINEL, np.int32)
        got = run_both(q, l, age=2_000_000, delta=1_000_000, own=0)
        assert got.sum() == 0

    def test_own_term_commit_ignores_limbo(self):
        q = np.arange(256, dtype=np.int32)
        l = np.arange(256, 256 + 128, dtype=np.int32)
        l[:] = q[:128]  # every limbo key conflicts
        got = run_both(q, l, age=0, delta=1_000_000, own=1)
        assert got.sum() == 256

    def test_inherited_lease_blocks_conflicts_only(self):
        # The paper's Fig 9 scenario: valid inherited lease, some keys in
        # the limbo region.
        q = np.arange(256, dtype=np.int32)
        l = np.full(128, PAD_SENTINEL, np.int32)
        l[:3] = [10, 20, 30]
        got = run_both(q, l, age=100, delta=1_000_000, own=0)
        assert got.sum() == 253
        assert got[10] == 0 and got[20] == 0 and got[30] == 0

    def test_age_boundary(self):
        q = np.zeros(256, np.int32)
        l = np.full(128, PAD_SENTINEL, np.int32)
        # age == delta is NOT strictly less: lease expired (Fig 2 line 20
        # uses >, we gate admission on age < delta).
        assert run_both(q, l, age=500, delta=500, own=1).sum() == 0
        assert run_both(q, l, age=499, delta=500, own=1).sum() == 256


@settings(max_examples=40, deadline=None)
@given(
    age=st.integers(0, 2_000_000),
    delta=st.sampled_from([0, 1, 500_000, 1_000_000]),
    own=st.integers(0, 1),
    data=st.data(),
)
def test_matches_ref_random(age, delta, own, data):
    alphabet = data.draw(st.lists(real_hash, min_size=1, max_size=6, unique=True))
    q = data.draw(st.lists(st.sampled_from(alphabet), min_size=256, max_size=256))
    l = data.draw(
        st.lists(st.sampled_from(alphabet) | st.just(PAD_SENTINEL), min_size=128, max_size=128)
    )
    run_both(q, l, age, delta, own)


class TestAOT:
    def test_all_artifact_shapes_lower(self):
        for b, k in ARTIFACT_SHAPES:
            text = lower_shape(b, k)
            assert "HloModule" in text
            # admission output is an int32[B] inside a 1-tuple
            assert f"s32[{b}]" in text

    def test_hlo_has_no_custom_calls(self):
        # interpret=True must lower the Pallas kernel to plain HLO ops the
        # CPU PJRT client can execute — no Mosaic custom-calls.
        b, k = ARTIFACT_SHAPES[0]
        text = lower_shape(b, k)
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
