"""Layer-1 correctness: Pallas limbo-conflict kernel vs pure-jnp oracle.

Hypothesis sweeps shapes, block sizes, and adversarial hash values
(duplicates, sentinel collisions, full-range int32) and asserts exact
equality against ref.py — the CORE correctness signal for the kernel.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.limbo_mask import limbo_conflict
from compile.kernels.ref import PAD_SENTINEL, limbo_conflict_ref

# Any int32 except the reserved sentinel (the Rust side never emits it).
real_hash = st.integers(min_value=-(2**31) + 1, max_value=2**31 - 1)


def run_both(q, l, block_b=128, block_k=128):
    q = np.asarray(q, dtype=np.int32)
    l = np.asarray(l, dtype=np.int32)
    got = np.asarray(limbo_conflict(q, l, block_b=block_b, block_k=block_k))
    want = np.asarray(limbo_conflict_ref(q, l)).astype(np.int32)
    np.testing.assert_array_equal(got, want)
    return got


class TestBasics:
    def test_no_conflict(self):
        q = np.arange(128, dtype=np.int32)
        l = np.arange(1000, 1000 + 128, dtype=np.int32)
        got = run_both(q, l)
        assert got.sum() == 0

    def test_all_conflict(self):
        q = np.full(128, 42, dtype=np.int32)
        l = np.full(128, 42, dtype=np.int32)
        got = run_both(q, l)
        assert got.sum() == 128

    def test_single_hit_per_tile_boundary(self):
        # Hit located in the last limbo slot of the second K tile.
        q = np.arange(128, dtype=np.int32)
        l = np.full(256, PAD_SENTINEL + 1, dtype=np.int32)
        l[255] = 1077
        q[3] = 1077
        got = run_both(q, l, block_k=128)
        assert got[3] == 1 and got.sum() == 1

    def test_sentinel_padding_never_matches(self):
        # Even a query equal to the sentinel must not match padding.
        q = np.full(128, PAD_SENTINEL, dtype=np.int32)
        l = np.full(128, PAD_SENTINEL, dtype=np.int32)
        got = np.asarray(limbo_conflict(q, l))
        assert got.sum() == 0

    def test_multi_b_tiles(self):
        q = np.arange(512, dtype=np.int32)
        l = np.array([5, 200, 300, 511] + [PAD_SENTINEL] * 124, dtype=np.int32)
        got = run_both(q, l)
        assert sorted(np.nonzero(got)[0].tolist()) == [5, 200, 300, 511]

    def test_rejects_unaligned(self):
        with pytest.raises(ValueError):
            limbo_conflict(np.zeros(100, np.int32), np.zeros(128, np.int32))


@settings(max_examples=60, deadline=None)
@given(
    nb=st.integers(1, 4),
    nk=st.integers(1, 3),
    block_b=st.sampled_from([8, 32, 128]),
    block_k=st.sampled_from([8, 64, 128]),
    data=st.data(),
)
def test_matches_ref_random(nb, nk, block_b, block_k, data):
    b, k = nb * block_b, nk * block_k
    # Small alphabet to force collisions between q and l frequently.
    alphabet = data.draw(st.lists(real_hash, min_size=1, max_size=8, unique=True))
    q = data.draw(st.lists(st.sampled_from(alphabet), min_size=b, max_size=b))
    npad = data.draw(st.integers(0, k))
    l_real = data.draw(st.lists(st.sampled_from(alphabet) | real_hash, min_size=k - npad, max_size=k - npad))
    l = l_real + [PAD_SENTINEL] * npad
    run_both(q, l, block_b=block_b, block_k=block_k)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_matches_ref_full_range(data):
    # Full-range int32 values, default blocks.
    q = data.draw(st.lists(real_hash, min_size=128, max_size=128))
    l = data.draw(st.lists(real_hash, min_size=128, max_size=128))
    run_both(q, l)
