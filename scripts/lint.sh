#!/usr/bin/env bash
# Run the self-hosted determinism/protocol linter over rust/src.
#
#   scripts/lint.sh            # human-readable report, exit 1 on unwaived findings
#   scripts/lint.sh --json     # machine-readable report (same exit semantics)
#
# The linter is the `leaseguard lint` subcommand (rust/src/lint/): a
# dependency-free lexer + rule pass enforcing R1 (no wall-clock outside
# clock/real.rs, server/, client/), R2 (no HashMap/HashSet iteration in
# protocol/sim paths), R3 (no ambient RNG), R4 (panic-free wire decode),
# R5 (persist-before-route in server main_loop). Exceptions are inline
# `// lint:allow(<rule>): <reason>` waivers. The same pass also runs as
# a tier-1 test (rust/tests/lint_suite.rs), so CI fails on violations
# even if this script is skipped.
set -euo pipefail
cd "$(dirname "$0")/.."

exec cargo run --release --quiet -- lint "$@"
