#!/usr/bin/env bash
# Reproduce the perf trajectory with one command: runs the microbench
# suite and writes BENCH_micro.json at the repo root.
#
#   scripts/bench.sh                 # cargo bench path (release profile)
#   scripts/bench.sh --quick         # debug-built CLI path (slower code,
#                                    # faster build; numbers not comparable)
#
# Record before/after numbers in CHANGES.md when a PR touches hot paths.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--quick" ]]; then
    shift
    cargo run --bin leaseguard -- bench --json BENCH_micro.json "$@"
else
    cargo bench --bench micro -- --json BENCH_micro.json "$@"
fi

echo "BENCH_micro.json written at $(pwd)/BENCH_micro.json"
