#!/usr/bin/env bash
# Run the Nemesis fault-injection scenario matrix (every catalog
# scenario x {leaseguard, quorum, inconsistent}, linearizability-
# checked) and write SCENARIOS.json at the repo root.
#
#   scripts/scenarios.sh                     # release build (matrix is CPU-heavy)
#   scripts/scenarios.sh --quick             # debug build (slower runs, faster build)
#   scripts/scenarios.sh --param seed=9      # extra CLI args pass through
#
# Exits non-zero if any run violates a guarantee its mode promises.
# The output is deterministic per seed: commit SCENARIOS.json to track
# the matrix across PRs (like BENCH_micro.json).
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--quick" ]]; then
    shift
    cargo run --bin leaseguard -- scenarios --json SCENARIOS.json "$@"
else
    cargo run --release --bin leaseguard -- scenarios --json SCENARIOS.json "$@"
fi

echo "SCENARIOS.json written at $(pwd)/SCENARIOS.json"
