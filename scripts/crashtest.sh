#!/usr/bin/env bash
# Crash/restart soak: run the durability harness (rust/tests/
# crash_restart.rs) N times with distinct workload seeds. Each round
# hard-kills real server processes' threads mid-load and reboots them
# from their WAL + hard-state files; a single failed round fails the
# script.
#
#   scripts/crashtest.sh             # 5 rounds, seeds 1..5
#   scripts/crashtest.sh 20          # 20 rounds, seeds 1..20
#   scripts/crashtest.sh 3 900       # 3 rounds, seeds 901..903
#
# The harness binds real loopback ports and measures wall-clock
# recovery windows, so rounds run serially (--test-threads=1) to keep
# timing honest on loaded CI hosts.
set -euo pipefail
cd "$(dirname "$0")/.."

rounds="${1:-5}"
base="${2:-0}"

cargo build --release --tests

for ((i = 1; i <= rounds; i++)); do
    seed=$((base + i))
    echo "== crashtest round $i/$rounds (seed $seed) =="
    CRASHTEST_SEED="$seed" cargo test --release --test crash_restart -- --test-threads=1
done

echo "crashtest: $rounds round(s) passed"
