#!/usr/bin/env bash
# One-shot CI gate: everything a PR must pass, in dependency order.
#
#   scripts/ci.sh            # full gate (fmt, clippy, build, tests, smoke)
#   scripts/ci.sh --fast     # skip the real-cluster smoke run
#
# Stages:
#   1. cargo fmt --check        — formatting is not negotiable
#   2. cargo clippy -D warnings — lints are errors
#   3. cargo build --release    — lib + bin + tests compile
#   4. cargo test               — unit + integration suites (includes the
#                                 multi-Raft sharding suite)
#   5. 2-group real-cluster smoke — a short bench-cluster run with
#      groups=2 over real loopback TCP: every group must elect, serve,
#      and pass the per-shard linearizability check.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== fmt =="
cargo fmt --all -- --check

echo "== clippy =="
cargo clippy --all-targets --release -- -D warnings

echo "== build =="
cargo build --release --tests --benches

echo "== tests =="
cargo test --release

if [[ "${1:-}" != "--fast" ]]; then
    echo "== 2-group real-cluster smoke =="
    cargo run --release -- bench-cluster \
        --param groups=2 \
        --param duration_us=1000000 \
        --param interarrival_us=1000
fi

echo "ci: all gates passed"
