#!/usr/bin/env bash
# One-shot CI gate: everything a PR must pass, in dependency order.
#
#   scripts/ci.sh            # full gate (fmt, clippy, build, tests, smoke)
#   scripts/ci.sh --fast     # skip the real-cluster smoke run
#
# Stages:
#   1. cargo fmt --check        — formatting is not negotiable
#   2. leaseguard lint          — self-hosted determinism/protocol linter
#                                 (R1-R5; zero unwaived findings)
#   3. cargo clippy -D warnings — lints are errors
#   4. cargo build --release    — lib + bin + tests compile
#   5. cargo test               — unit + integration suites (includes the
#                                 multi-Raft sharding suite + lint suite)
#   6. 2-group real-cluster smoke — a short bench-cluster run with
#      groups=2 over real loopback TCP: every group must elect, serve,
#      and pass the per-shard linearizability check.
#   7. live introspection smoke — three real `serve` processes with
#      groups=2; `leaseguard stat --json` against each must return the
#      per-group lease-accounting counters, and some server must report
#      leadership of each group.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== fmt =="
cargo fmt --all -- --check

echo "== lint (self-hosted) =="
scripts/lint.sh

echo "== clippy =="
cargo clippy --all-targets --release -- -D warnings

echo "== build =="
cargo build --release --tests --benches

echo "== tests =="
cargo test --release

if [[ "${1:-}" != "--fast" ]]; then
    echo "== 2-group real-cluster smoke =="
    cargo run --release -- bench-cluster \
        --param groups=2 \
        --param duration_us=1000000 \
        --param interarrival_us=1000

    echo "== live introspection smoke (leaseguard stat) =="
    BIN=target/release/leaseguard
    PEERS="127.0.0.1:7451,127.0.0.1:7452,127.0.0.1:7453"
    PIDS=()
    cleanup() { kill "${PIDS[@]}" 2>/dev/null || true; }
    trap cleanup EXIT
    for i in 0 1 2; do
        "$BIN" serve --node "$i" --peers "$PEERS" --param groups=2 &
        PIDS+=($!)
    done
    # Wait for every group to elect somewhere, then check the snapshot
    # carries the lease-accounting counters per group.
    ok=""
    for _ in $(seq 1 50); do
        sleep 0.2
        combined=""
        for port in 7451 7452 7453; do
            combined+=$("$BIN" stat --addr "127.0.0.1:$port" --json 2>/dev/null || true)
        done
        if [[ "$combined" == *'"is_leader": true'* ]]; then
            ok="$combined"
            break
        fi
    done
    [[ -n "$ok" ]] || { echo "stat smoke: no group elected a leader"; exit 1; }
    for key in '"group": 1' '"reads_lease_local"' '"reads_lease_inherited"' \
               '"writes_blocked_transfer"' '"stages"' '"events"'; do
        [[ "$ok" == *"$key"* ]] || { echo "stat smoke: missing $key in snapshot"; exit 1; }
    done
    cleanup
    trap - EXIT
    echo "stat smoke: ok"
fi

echo "ci: all gates passed"
