#!/usr/bin/env bash
# One-shot CI gate: everything a PR must pass, in dependency order.
#
#   scripts/ci.sh            # full gate (fmt, clippy, build, tests, smoke)
#   scripts/ci.sh --fast     # skip the real-cluster smoke run
#
# Stages:
#   1. cargo fmt --check        — formatting is not negotiable
#   2. leaseguard lint          — self-hosted determinism/protocol linter
#                                 (R1-R5; zero unwaived findings)
#   3. cargo clippy -D warnings — lints are errors
#   4. cargo build --release    — lib + bin + tests compile
#   5. cargo test               — unit + integration suites (includes the
#                                 multi-Raft sharding suite + lint suite)
#   6. 2-group real-cluster smoke — a short bench-cluster run with
#      groups=2 over real loopback TCP: every group must elect, serve,
#      and pass the per-shard linearizability check.
#   7. live introspection smoke — three real `serve` processes with
#      groups=2; `leaseguard stat --json` against each must return the
#      per-group lease-accounting counters, and some server must report
#      leadership of each group.
#   8. compaction smoke — durable serve trio with a tiny
#      --snapshot-threshold under open-loop client load; kill -9 one
#      node and respawn it from its data dir: `stat --json` must show
#      snapshots taken while serving and a nonzero snapshot base on the
#      rebooted node (snapshot + WAL-suffix recovery, not full replay).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== fmt =="
cargo fmt --all -- --check

echo "== lint (self-hosted) =="
scripts/lint.sh

echo "== clippy =="
cargo clippy --all-targets --release -- -D warnings

echo "== build =="
cargo build --release --tests --benches

echo "== tests =="
cargo test --release

if [[ "${1:-}" != "--fast" ]]; then
    echo "== 2-group real-cluster smoke =="
    cargo run --release -- bench-cluster \
        --param groups=2 \
        --param duration_us=1000000 \
        --param interarrival_us=1000

    echo "== live introspection smoke (leaseguard stat) =="
    BIN=target/release/leaseguard
    PEERS="127.0.0.1:7451,127.0.0.1:7452,127.0.0.1:7453"
    PIDS=()
    cleanup() { kill "${PIDS[@]}" 2>/dev/null || true; }
    trap cleanup EXIT
    for i in 0 1 2; do
        "$BIN" serve --node "$i" --peers "$PEERS" --param groups=2 &
        PIDS+=($!)
    done
    # Wait for every group to elect somewhere, then check the snapshot
    # carries the lease-accounting counters per group.
    ok=""
    for _ in $(seq 1 50); do
        sleep 0.2
        combined=""
        for port in 7451 7452 7453; do
            combined+=$("$BIN" stat --addr "127.0.0.1:$port" --json 2>/dev/null || true)
        done
        if [[ "$combined" == *'"is_leader": true'* ]]; then
            ok="$combined"
            break
        fi
    done
    [[ -n "$ok" ]] || { echo "stat smoke: no group elected a leader"; exit 1; }
    for key in '"group": 1' '"reads_lease_local"' '"reads_lease_inherited"' \
               '"writes_blocked_transfer"' '"stages"' '"events"'; do
        [[ "$ok" == *"$key"* ]] || { echo "stat smoke: missing $key in snapshot"; exit 1; }
    done
    cleanup
    trap - EXIT
    echo "stat smoke: ok"

    echo "== compaction smoke (snapshot + kill/respawn recovery) =="
    # Three durable servers with a tiny compaction threshold, an
    # open-loop client writing far past it, then a hard kill + respawn
    # of one server from its data dir: `stat` must show snapshots taken
    # while serving, and the respawned process must report a nonzero
    # log base immediately after recovery (snapshot + WAL suffix, not a
    # full-history replay).
    CPEERS="127.0.0.1:7461,127.0.0.1:7462,127.0.0.1:7463"
    CDATA=$(mktemp -d)
    CPIDS=()
    ccleanup() { kill "${CPIDS[@]}" 2>/dev/null || true; rm -rf "$CDATA"; }
    trap ccleanup EXIT
    for i in 0 1 2; do
        "$BIN" serve --node "$i" --peers "$CPEERS" \
            --data-dir "$CDATA/n$i" --fsync group --snapshot-threshold 16 &
        CPIDS+=($!)
    done
    "$BIN" client --peers "$CPEERS" \
        --param duration_us=1500000 --param interarrival_us=500
    taken=""
    for _ in $(seq 1 50); do
        sleep 0.2
        if "$BIN" stat --addr 127.0.0.1:7461 --json 2>/dev/null \
                | grep -Eq '"snapshots_taken": [1-9]'; then
            taken=yes
            break
        fi
    done
    [[ -n "$taken" ]] || { echo "compaction smoke: no snapshot taken under load"; exit 1; }
    # Hard kill node 0 and respawn it from its data dir on the same port.
    kill -9 "${CPIDS[0]}" 2>/dev/null || true
    wait "${CPIDS[0]}" 2>/dev/null || true
    sleep 0.5
    "$BIN" serve --node 0 --peers "$CPEERS" \
        --data-dir "$CDATA/n0" --fsync group --snapshot-threshold 16 &
    CPIDS[0]=$!
    recovered=""
    for _ in $(seq 1 50); do
        sleep 0.2
        if "$BIN" stat --addr 127.0.0.1:7461 --json 2>/dev/null \
                | grep -Eq '"last_snapshot_index": [1-9]'; then
            recovered=yes
            break
        fi
    done
    [[ -n "$recovered" ]] || { echo "compaction smoke: respawned node shows no snapshot base"; exit 1; }
    ccleanup
    trap - EXIT
    echo "compaction smoke: ok"
fi

echo "ci: all gates passed"
