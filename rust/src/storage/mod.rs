//! Crash durability for the real (TCP) cluster.
//!
//! Raft's correctness argument assumes `currentTerm`, `votedFor`, and
//! the log survive a crash (§5); in LeaseGuard the stakes are higher
//! still because the timestamped log *is* the lease (paper §3). This
//! module is the real-mode implementation of [`crate::raft::DurableState`]:
//!
//! * [`wal`] — CRC-framed append-only log of entry appends and
//!   conflict truncations, recovered by longest-valid-prefix scan;
//! * [`hardstate`] — tiny atomically-rewritten `(term, voted_for)` file;
//! * [`crate::snap::file`] — atomically-written state-machine snapshots
//!   that bound both directory size and recovery time;
//! * [`Storage`] — the façade the server drives: record mutations as
//!   they happen, then [`Storage::sync`] as the durability barrier
//!   before any externalization (vote cast, append acked, entry sent),
//!   and [`Storage::install_snapshot`] when the node compacts.
//!
//! With compaction the directory holds snapshots plus WAL *segments*
//! (`wal`, `wal-<base>`, … — see [`crate::snap::file`] for naming), and
//! recovery is: load the newest fully-valid snapshot, then replay every
//! segment in ascending base order on top of it (records at or below
//! the snapshot boundary are skipped), opening the highest-based
//! segment for appends. A torn newest snapshot is skipped entirely, so
//! the fallback is automatic: the previous snapshot plus a longer
//! replay reconstructs the identical state.
//!
//! The simulator keeps using the in-memory `DurableState` directly —
//! virtual time has no disks — so everything here is real-path only.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::str::FromStr;

use crate::raft::log::{Entry, Log};
use crate::raft::types::{Index, Term};
use crate::raft::DurableState;
use crate::snap::file as snapfile;
use crate::snap::Snapshot;
use crate::NodeId;

pub mod hardstate;
pub mod wal;

use wal::{Wal, WalRecord};

/// When does an append become durable relative to externalization?
///
/// * `Always` — fsync inside every WAL append / hard-state write. The
///   textbook setting; one fsync per record.
/// * `Group` — buffer within an event-loop batch, one flush+fsync
///   barrier before the batch's outputs are routed. Same safety (nothing
///   externalized before it is durable), far fewer fsyncs under load.
/// * `Never` — write but never fsync. Survives process kill (the kernel
///   still has the pages) but not power loss; for tests and benchmarks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    Always,
    Group,
    Never,
}

impl FsyncPolicy {
    /// Whether this policy issues fsync calls at all.
    pub fn fsyncs(self) -> bool {
        !matches!(self, FsyncPolicy::Never)
    }
}

impl FromStr for FsyncPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "group" => Ok(FsyncPolicy::Group),
            "never" | "none" => Ok(FsyncPolicy::Never),
            _ => Err(format!("bad fsync policy {s:?} (always|group|never)")),
        }
    }
}

/// On-disk durable state for one node: snapshots plus WAL segments plus
/// `<dir>/hard_state`.
pub struct Storage {
    dir: PathBuf,
    wal: Wal,
    /// Base index of the live WAL segment (file `segment_name(seg_base)`).
    seg_base: Index,
    policy: FsyncPolicy,
    /// Hard state as last durably written — lets us skip rewrites when a
    /// batch leaves `(term, voted_for)` unchanged.
    hs: (Term, Option<NodeId>),
}

impl Storage {
    /// Open (creating the directory if needed) and recover. The returned
    /// [`DurableState`] is what [`crate::raft::Node::recover`] boots
    /// from; its log dirty-tracking is cleared so recovery itself is
    /// never re-persisted.
    ///
    /// Recovery order: newest fully-valid snapshot (torn/corrupt files
    /// silently yield to the one before — [`snapfile::load_newest`]),
    /// then every WAL segment in ascending base order replayed on top
    /// (records the snapshot already covers are skipped), the highest
    /// segment staying open for appends.
    pub fn open(dir: &Path, policy: FsyncPolicy) -> io::Result<(Storage, DurableState)> {
        fs::create_dir_all(dir)?;
        let snapshot = snapfile::load_newest(dir)?;
        let mut log = match &snapshot {
            Some(s) => Log::with_base(s.meta.last_index, s.meta.last_term, s.meta.last_written_at),
            None => Log::default(),
        };
        let segments = snapfile::list_segments(dir)?;
        let live = segments.last().copied().unwrap_or(0);
        for &base in &segments {
            if base == live {
                break; // the live segment opens (and self-repairs) below
            }
            let bytes = fs::read(dir.join(snapfile::segment_name(base)))?;
            let (replayed, _) = wal::replay_into(&bytes, log);
            log = replayed;
        }
        let (wal, mut log) =
            Wal::open_into(&dir.join(snapfile::segment_name(live)), policy, log)?;
        let (hs_term, voted_for) = hardstate::read(dir);
        // The log can be ahead of the hard-state file only in the
        // torn-write window where the entries were never acked, but a
        // term can never exceed what the log proves: take the max.
        let current_term = hs_term.max(log.last_term());
        log.take_dirty(); // replayed entries are already on disk
        let storage =
            Storage { dir: dir.to_path_buf(), wal, seg_base: live, policy, hs: (current_term, voted_for) };
        Ok((storage, DurableState { current_term, voted_for, log, snapshot }))
    }

    /// Persist a snapshot and rotate the WAL. Called by the driver when
    /// it drains [`crate::raft::Node::take_pending_snap`], *before* the
    /// batch's outputs are routed (persist-before-route — externalized
    /// protocol state must never outlive its durable basis):
    ///
    /// 1. write the snapshot file atomically (tmp + fsync + rename +
    ///    dir fsync, same discipline as [`hardstate`]);
    /// 2. start a fresh segment at the snapshot boundary seeded with the
    ///    log's surviving suffix (compaction discarded the old segment's
    ///    dirty bookkeeping — the rewrite makes the suffix whole again);
    /// 3. prune snapshots and segments no recovery can need (always
    ///    keeping the previous snapshot as the torn-newest fallback).
    pub fn install_snapshot(&mut self, snap: &Snapshot, log: &Log) -> io::Result<()> {
        snapfile::write(&self.dir, snap, self.policy)?;
        let base = snap.meta.last_index;
        if base <= self.seg_base {
            // Re-delivery of a boundary we already rotated past (e.g. a
            // follower re-installing after a duplicate transfer):
            // nothing to rotate, but pruning may still reclaim space.
            return snapfile::prune(&self.dir, self.seg_base, self.policy);
        }
        let path = self.dir.join(snapfile::segment_name(base));
        // A leftover wal-<base> can only be debris from a rotation that
        // crashed before completing; the in-memory suffix supersedes it.
        match fs::remove_file(&path) {
            Ok(()) => {}
            Err(e) if e.kind() == io::ErrorKind::NotFound => {}
            Err(e) => return Err(e),
        }
        let (mut wal, _) = Wal::open_into(&path, self.policy, Log::default())?;
        for (i, e) in log.iter_range(base, log.last_index()) {
            wal.append(&WalRecord::Append { index: i, entry: *e })?;
        }
        wal.sync()?;
        if self.policy.fsyncs() {
            fs::File::open(&self.dir)?.sync_all()?;
        }
        self.wal = wal;
        self.seg_base = base;
        snapfile::prune(&self.dir, base, self.policy)
    }

    /// Base index of the live WAL segment (== the newest snapshot's
    /// boundary once a rotation has happened).
    pub fn segment_base(&self) -> Index {
        self.seg_base
    }

    /// Record a hard-state change. No-op when unchanged since the last
    /// durable write; otherwise immediately rewritten (atomic tmp +
    /// rename — term bumps and votes are rare, so there is nothing to
    /// batch).
    pub fn persist_hard_state(&mut self, term: Term, voted_for: Option<NodeId>) -> io::Result<()> {
        if self.hs == (term, voted_for) {
            return Ok(());
        }
        hardstate::write(&self.dir, term, voted_for, self.policy)?;
        self.hs = (term, voted_for);
        Ok(())
    }

    /// Record one log append at `index` (buffered under `Group`).
    pub fn append(&mut self, index: Index, entry: &Entry) -> io::Result<()> {
        self.wal.append(&WalRecord::Append { index, entry: *entry })
    }

    /// Record a conflict truncation: drop entries after `after`.
    pub fn truncate(&mut self, after: Index) -> io::Result<()> {
        self.wal.append(&WalRecord::Truncate { after })
    }

    /// Durability barrier: everything recorded so far is on disk when
    /// this returns. No-op if nothing is pending.
    pub fn sync(&mut self) -> io::Result<()> {
        self.wal.sync()
    }

    /// Split-barrier first half (see [`wal::Wal::flush`]): flush
    /// buffered WAL records to the kernel, return whether any were
    /// pending. Under [`FsyncPolicy::Always`] appends self-sync, so
    /// this reports false and the caller's shared sync is skipped.
    pub fn flush(&mut self) -> io::Result<bool> {
        self.wal.flush()
    }

    /// Split-barrier second half: the caller has issued a shared sync
    /// covering this WAL's flushed records.
    pub fn mark_synced(&mut self) {
        self.wal.mark_synced()
    }

    /// The WAL's file handle (for `syncfs`).
    pub fn wal_file(&self) -> &fs::File {
        self.wal.file()
    }
}

/// Per-group durable storage for a multi-Raft process, namespaced as
/// `<data-dir>/g<id>/{wal,hard_state}`.
///
/// The point of this type over `Vec<Storage>` is [`MultiStorage::barrier`]:
/// the persist-before-route rule requires every group's batch-buffered
/// records to be durable before any of the batch's outputs are routed,
/// but issuing one fdatasync per group would make G groups G× as
/// expensive per batch. All group WALs live on one filesystem, so the
/// barrier flushes each dirty WAL's buffers and then issues a single
/// `syncfs(2)` — hosting 16 groups costs ~1 sync per event batch, not
/// 16. (On non-Linux targets it falls back to per-dirty-file
/// `fdatasync`.)
pub struct MultiStorage {
    groups: Vec<Storage>,
    policy: FsyncPolicy,
    /// Shared syncs issued by [`MultiStorage::barrier`] — observable so
    /// tests (and the figure-11 driver) can assert the cross-group
    /// batching actually holds at ~1 sync per batch.
    syncs: u64,
}

impl MultiStorage {
    /// Open (creating as needed) and recover every group. Returns the
    /// handle plus one [`DurableState`] per group, in group order.
    pub fn open(
        dir: &Path,
        groups: usize,
        policy: FsyncPolicy,
    ) -> io::Result<(MultiStorage, Vec<DurableState>)> {
        let mut stores = Vec::with_capacity(groups);
        let mut durable = Vec::with_capacity(groups);
        for g in 0..groups {
            let (s, d) = Storage::open(&dir.join(format!("g{g}")), policy)?;
            stores.push(s);
            durable.push(d);
        }
        Ok((MultiStorage { groups: stores, policy, syncs: 0 }, durable))
    }

    pub fn group(&mut self, g: usize) -> &mut Storage {
        &mut self.groups[g]
    }

    pub fn len(&self) -> usize {
        self.groups.len()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    /// Cross-group durability barrier: after this returns, every record
    /// appended to any group since the last barrier is durable. One
    /// shared sync covers all dirty groups.
    pub fn barrier(&mut self) -> io::Result<()> {
        let mut any_dirty = false;
        for s in &mut self.groups {
            any_dirty |= s.flush()?;
        }
        if !any_dirty {
            return Ok(());
        }
        if self.policy.fsyncs() {
            self.shared_sync()?;
            self.syncs += 1;
        }
        for s in &mut self.groups {
            s.mark_synced();
        }
        Ok(())
    }

    /// Shared syncs issued so far (one per dirty barrier).
    pub fn syncs(&self) -> u64 {
        self.syncs
    }

    #[cfg(target_os = "linux")]
    fn shared_sync(&mut self) -> io::Result<()> {
        use std::os::unix::io::AsRawFd;
        // All group dirs share one filesystem; syncfs on any of the WAL
        // fds makes every group's flushed records durable at once.
        extern "C" {
            fn syncfs(fd: i32) -> i32;
        }
        let fd = self.groups[0].wal_file().as_raw_fd();
        if unsafe { syncfs(fd) } != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    #[cfg(not(target_os = "linux"))]
    fn shared_sync(&mut self) -> io::Result<()> {
        // No syncfs: fall back to one fdatasync per group WAL (hard
        // state files self-sync on write).
        for s in &mut self.groups {
            s.wal_file().sync_data()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::TimeInterval;
    use crate::kv::Command;
    use crate::testkit::TempDir;

    fn e(term: u64) -> Entry {
        Entry { term, command: Command::Noop, written_at: TimeInterval::exact(term as i64) }
    }

    #[test]
    fn full_state_roundtrip() {
        let d = TempDir::new("storage-roundtrip");
        {
            let (mut s, ds) = Storage::open(d.path(), FsyncPolicy::Group).unwrap();
            assert_eq!(ds.current_term, 0);
            assert!(ds.voted_for.is_none());
            s.persist_hard_state(3, Some(1)).unwrap();
            s.append(1, &e(1)).unwrap();
            s.append(2, &e(3)).unwrap();
            s.sync().unwrap();
        }
        let (_, ds) = Storage::open(d.path(), FsyncPolicy::Group).unwrap();
        assert_eq!(ds.current_term, 3);
        assert_eq!(ds.voted_for, Some(1));
        assert_eq!(ds.log.last_index(), 2);
        assert_eq!(ds.log.last_term(), 3);
    }

    #[test]
    fn recovered_log_is_not_marked_dirty() {
        let d = TempDir::new("storage-clean");
        {
            let (mut s, _) = Storage::open(d.path(), FsyncPolicy::Never).unwrap();
            s.append(1, &e(1)).unwrap();
            s.sync().unwrap();
        }
        let (_, mut ds) = Storage::open(d.path(), FsyncPolicy::Never).unwrap();
        assert_eq!(ds.log.take_dirty(), None, "replay must not look like new writes");
    }

    #[test]
    fn term_never_below_log_term() {
        // Hard-state lost (crash before first vote persisted the term
        // the log already carries): term is reconstructed from the log.
        let d = TempDir::new("storage-hsmax");
        {
            let (mut s, _) = Storage::open(d.path(), FsyncPolicy::Group).unwrap();
            s.append(1, &e(5)).unwrap();
            s.sync().unwrap();
        }
        std::fs::remove_file(d.path().join(hardstate::FILE)).ok();
        let (_, ds) = Storage::open(d.path(), FsyncPolicy::Group).unwrap();
        assert_eq!(ds.current_term, 5);
    }

    #[test]
    fn multi_storage_namespaces_groups_and_recovers() {
        let d = TempDir::new("multi-storage");
        {
            let (mut m, ds) = MultiStorage::open(d.path(), 4, FsyncPolicy::Group).unwrap();
            assert_eq!(ds.len(), 4);
            m.group(0).append(1, &e(1)).unwrap();
            m.group(2).append(1, &e(7)).unwrap();
            m.group(2).persist_hard_state(7, Some(2)).unwrap();
            m.barrier().unwrap();
        }
        // Per-group directories exist on disk.
        for g in 0..4 {
            assert!(d.path().join(format!("g{g}")).join("wal").exists(), "g{g}/wal");
        }
        let (_, ds) = MultiStorage::open(d.path(), 4, FsyncPolicy::Group).unwrap();
        assert_eq!(ds[0].log.last_index(), 1);
        assert_eq!(ds[1].log.last_index(), 0);
        assert_eq!(ds[2].log.last_index(), 1);
        assert_eq!(ds[2].current_term, 7);
        assert_eq!(ds[2].voted_for, Some(2));
        assert_eq!(ds[3].log.last_index(), 0);
    }

    #[test]
    fn barrier_issues_one_shared_sync_for_many_dirty_groups() {
        let d = TempDir::new("multi-barrier");
        let (mut m, _) = MultiStorage::open(d.path(), 16, FsyncPolicy::Group).unwrap();
        for g in 0..16 {
            m.group(g).append(1, &e(1)).unwrap();
        }
        m.barrier().unwrap();
        assert_eq!(m.syncs(), 1, "16 dirty groups must cost one shared sync");
        // A clean barrier costs nothing.
        m.barrier().unwrap();
        assert_eq!(m.syncs(), 1);
        // Next dirty batch: one more.
        m.group(3).append(2, &e(2)).unwrap();
        m.barrier().unwrap();
        assert_eq!(m.syncs(), 2);
    }

    #[test]
    fn always_policy_self_syncs_so_barrier_is_free() {
        let d = TempDir::new("multi-always");
        let (mut m, _) = MultiStorage::open(d.path(), 2, FsyncPolicy::Always).unwrap();
        m.group(0).append(1, &e(1)).unwrap();
        m.barrier().unwrap();
        assert_eq!(m.syncs(), 0, "per-append fsync leaves nothing for the barrier");
    }

    // ---------------------------------------------- snapshots & rotation

    fn put_entry(term: u64, i: u64) -> Entry {
        Entry {
            term,
            command: Command::Put { key: i as u32, value: i, payload_bytes: 0 },
            written_at: TimeInterval::exact(i as i64 * 100),
        }
    }

    /// Append entries up to `upto`, then snapshot+rotate at `snap_at`.
    fn grow_and_snapshot(
        s: &mut Storage,
        log: &mut Log,
        store: &mut crate::kv::Store,
        upto: u64,
        snap_at: u64,
    ) {
        for i in (log.last_index() + 1)..=upto {
            let e = put_entry(1, i);
            log.append(e);
            s.append(i, &e).unwrap();
        }
        s.sync().unwrap();
        while store.applied() < snap_at {
            let i = store.applied() + 1;
            store.apply(&Command::Put { key: i as u32, value: i, payload_bytes: 0 });
        }
        log.compact_to(snap_at);
        let snap = crate::snap::encode(
            store,
            crate::snap::SnapMeta {
                group: 0,
                last_index: log.base(),
                last_term: log.base_term(),
                last_written_at: log.base_written_at(),
                applied: store.applied(),
            },
        );
        s.install_snapshot(&snap, log).unwrap();
    }

    #[test]
    fn snapshot_rotation_bounds_recovery_to_the_suffix() {
        let d = TempDir::new("storage-snap-rotate");
        {
            let (mut s, _) = Storage::open(d.path(), FsyncPolicy::Never).unwrap();
            let mut log = Log::default();
            let mut store = crate::kv::Store::new();
            grow_and_snapshot(&mut s, &mut log, &mut store, 10, 6);
            assert_eq!(s.segment_base(), 6);
        }
        let (s2, ds) = Storage::open(d.path(), FsyncPolicy::Never).unwrap();
        assert_eq!(s2.segment_base(), 6);
        let snap = ds.snapshot.expect("snapshot recovered");
        assert_eq!(snap.meta.last_index, 6);
        assert_eq!(ds.log.base(), 6);
        assert_eq!(ds.log.last_index(), 10, "suffix rode the fresh segment");
        for i in 7..=10u64 {
            assert_eq!(ds.log.get(i).unwrap().command, put_entry(1, i).command);
        }
        let c = crate::snap::decode(&snap.data).unwrap();
        assert_eq!(c.meta.applied, 6);
        assert_eq!(c.pairs.len(), 6);
    }

    #[test]
    fn torn_newest_snapshot_falls_back_to_previous_plus_longer_replay() {
        let d = TempDir::new("storage-snap-fallback");
        {
            let (mut s, _) = Storage::open(d.path(), FsyncPolicy::Never).unwrap();
            let mut log = Log::default();
            let mut store = crate::kv::Store::new();
            grow_and_snapshot(&mut s, &mut log, &mut store, 5, 4);
            grow_and_snapshot(&mut s, &mut log, &mut store, 9, 8);
            // Retention: two snapshots, and the base-0 segment that only
            // recovery-from-before-snapshot-4 would need is gone.
            assert_eq!(snapfile::list(d.path()).unwrap(), vec![4, 8]);
            assert_eq!(snapfile::list_segments(d.path()).unwrap(), vec![4, 8]);
        }
        // Intact: recovery uses the newest snapshot.
        let (_, ds) = Storage::open(d.path(), FsyncPolicy::Never).unwrap();
        assert_eq!(ds.snapshot.as_ref().unwrap().meta.last_index, 8);
        assert_eq!(ds.log.base(), 8);
        assert_eq!(ds.log.last_index(), 9);
        // Tear the newest snapshot: silent fallback to snapshot 4 plus a
        // longer replay over both segments — same log tail, older base.
        let p = d.path().join(snapfile::snap_name(8));
        let full = fs::read(&p).unwrap();
        fs::write(&p, &full[..full.len() / 3]).unwrap();
        let (_, ds) = Storage::open(d.path(), FsyncPolicy::Never).unwrap();
        assert_eq!(ds.snapshot.as_ref().unwrap().meta.last_index, 4);
        assert_eq!(ds.log.base(), 4);
        assert_eq!(ds.log.last_index(), 9);
        for i in 5..=9u64 {
            assert_eq!(ds.log.get(i).unwrap().command, put_entry(1, i).command);
        }
    }

    #[test]
    fn appends_after_rotation_land_in_the_live_segment() {
        let d = TempDir::new("storage-snap-append");
        {
            let (mut s, _) = Storage::open(d.path(), FsyncPolicy::Never).unwrap();
            let mut log = Log::default();
            let mut store = crate::kv::Store::new();
            grow_and_snapshot(&mut s, &mut log, &mut store, 6, 6);
            // Post-rotation appends go to wal-6.
            for i in 7..=8u64 {
                let e = put_entry(2, i);
                log.append(e);
                s.append(i, &e).unwrap();
            }
            s.sync().unwrap();
        }
        let (_, ds) = Storage::open(d.path(), FsyncPolicy::Never).unwrap();
        assert_eq!(ds.log.base(), 6);
        assert_eq!(ds.log.last_index(), 8);
        assert_eq!(ds.log.get(8).unwrap().term, 2);
        assert_eq!(ds.current_term, 2, "term refloored from the recovered suffix");
    }

    #[test]
    fn fsync_policy_parses() {
        assert_eq!("always".parse::<FsyncPolicy>(), Ok(FsyncPolicy::Always));
        assert_eq!("group".parse::<FsyncPolicy>(), Ok(FsyncPolicy::Group));
        assert_eq!("never".parse::<FsyncPolicy>(), Ok(FsyncPolicy::Never));
        assert_eq!("none".parse::<FsyncPolicy>(), Ok(FsyncPolicy::Never));
        assert!("sometimes".parse::<FsyncPolicy>().is_err());
    }
}
