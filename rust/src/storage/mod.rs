//! Crash durability for the real (TCP) cluster.
//!
//! Raft's correctness argument assumes `currentTerm`, `votedFor`, and
//! the log survive a crash (§5); in LeaseGuard the stakes are higher
//! still because the timestamped log *is* the lease (paper §3). This
//! module is the real-mode implementation of [`crate::raft::DurableState`]:
//!
//! * [`wal`] — CRC-framed append-only log of entry appends and
//!   conflict truncations, recovered by longest-valid-prefix scan;
//! * [`hardstate`] — tiny atomically-rewritten `(term, voted_for)` file;
//! * [`Storage`] — the façade the server drives: record mutations as
//!   they happen, then [`Storage::sync`] as the durability barrier
//!   before any externalization (vote cast, append acked, entry sent).
//!
//! The simulator keeps using the in-memory `DurableState` directly —
//! virtual time has no disks — so everything here is real-path only.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::str::FromStr;

use crate::raft::log::Entry;
use crate::raft::types::{Index, Term};
use crate::raft::DurableState;
use crate::NodeId;

pub mod hardstate;
pub mod wal;

use wal::{Wal, WalRecord};

/// When does an append become durable relative to externalization?
///
/// * `Always` — fsync inside every WAL append / hard-state write. The
///   textbook setting; one fsync per record.
/// * `Group` — buffer within an event-loop batch, one flush+fsync
///   barrier before the batch's outputs are routed. Same safety (nothing
///   externalized before it is durable), far fewer fsyncs under load.
/// * `Never` — write but never fsync. Survives process kill (the kernel
///   still has the pages) but not power loss; for tests and benchmarks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    Always,
    Group,
    Never,
}

impl FsyncPolicy {
    /// Whether this policy issues fsync calls at all.
    pub fn fsyncs(self) -> bool {
        !matches!(self, FsyncPolicy::Never)
    }
}

impl FromStr for FsyncPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "group" => Ok(FsyncPolicy::Group),
            "never" | "none" => Ok(FsyncPolicy::Never),
            _ => Err(format!("bad fsync policy {s:?} (always|group|never)")),
        }
    }
}

/// On-disk durable state for one node: `<dir>/wal` + `<dir>/hard_state`.
pub struct Storage {
    dir: PathBuf,
    wal: Wal,
    policy: FsyncPolicy,
    /// Hard state as last durably written — lets us skip rewrites when a
    /// batch leaves `(term, voted_for)` unchanged.
    hs: (Term, Option<NodeId>),
}

impl Storage {
    /// Open (creating the directory if needed) and recover. The returned
    /// [`DurableState`] is what [`crate::raft::Node::recover`] boots
    /// from; its log dirty-tracking is cleared so recovery itself is
    /// never re-persisted.
    pub fn open(dir: &Path, policy: FsyncPolicy) -> io::Result<(Storage, DurableState)> {
        fs::create_dir_all(dir)?;
        let (wal, mut log) = Wal::open(&dir.join("wal"), policy)?;
        let (hs_term, voted_for) = hardstate::read(dir);
        // The log can be ahead of the hard-state file only in the
        // torn-write window where the entries were never acked, but a
        // term can never exceed what the log proves: take the max.
        let current_term = hs_term.max(log.last_term());
        log.take_dirty(); // replayed entries are already on disk
        let storage = Storage { dir: dir.to_path_buf(), wal, policy, hs: (current_term, voted_for) };
        Ok((storage, DurableState { current_term, voted_for, log }))
    }

    /// Record a hard-state change. No-op when unchanged since the last
    /// durable write; otherwise immediately rewritten (atomic tmp +
    /// rename — term bumps and votes are rare, so there is nothing to
    /// batch).
    pub fn persist_hard_state(&mut self, term: Term, voted_for: Option<NodeId>) -> io::Result<()> {
        if self.hs == (term, voted_for) {
            return Ok(());
        }
        hardstate::write(&self.dir, term, voted_for, self.policy)?;
        self.hs = (term, voted_for);
        Ok(())
    }

    /// Record one log append at `index` (buffered under `Group`).
    pub fn append(&mut self, index: Index, entry: &Entry) -> io::Result<()> {
        self.wal.append(&WalRecord::Append { index, entry: *entry })
    }

    /// Record a conflict truncation: drop entries after `after`.
    pub fn truncate(&mut self, after: Index) -> io::Result<()> {
        self.wal.append(&WalRecord::Truncate { after })
    }

    /// Durability barrier: everything recorded so far is on disk when
    /// this returns. No-op if nothing is pending.
    pub fn sync(&mut self) -> io::Result<()> {
        self.wal.sync()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::TimeInterval;
    use crate::kv::Command;
    use crate::testkit::TempDir;

    fn e(term: u64) -> Entry {
        Entry { term, command: Command::Noop, written_at: TimeInterval::exact(term as i64) }
    }

    #[test]
    fn full_state_roundtrip() {
        let d = TempDir::new("storage-roundtrip");
        {
            let (mut s, ds) = Storage::open(d.path(), FsyncPolicy::Group).unwrap();
            assert_eq!(ds.current_term, 0);
            assert!(ds.voted_for.is_none());
            s.persist_hard_state(3, Some(1)).unwrap();
            s.append(1, &e(1)).unwrap();
            s.append(2, &e(3)).unwrap();
            s.sync().unwrap();
        }
        let (_, ds) = Storage::open(d.path(), FsyncPolicy::Group).unwrap();
        assert_eq!(ds.current_term, 3);
        assert_eq!(ds.voted_for, Some(1));
        assert_eq!(ds.log.last_index(), 2);
        assert_eq!(ds.log.last_term(), 3);
    }

    #[test]
    fn recovered_log_is_not_marked_dirty() {
        let d = TempDir::new("storage-clean");
        {
            let (mut s, _) = Storage::open(d.path(), FsyncPolicy::Never).unwrap();
            s.append(1, &e(1)).unwrap();
            s.sync().unwrap();
        }
        let (_, mut ds) = Storage::open(d.path(), FsyncPolicy::Never).unwrap();
        assert_eq!(ds.log.take_dirty(), None, "replay must not look like new writes");
    }

    #[test]
    fn term_never_below_log_term() {
        // Hard-state lost (crash before first vote persisted the term
        // the log already carries): term is reconstructed from the log.
        let d = TempDir::new("storage-hsmax");
        {
            let (mut s, _) = Storage::open(d.path(), FsyncPolicy::Group).unwrap();
            s.append(1, &e(5)).unwrap();
            s.sync().unwrap();
        }
        std::fs::remove_file(d.path().join(hardstate::FILE)).ok();
        let (_, ds) = Storage::open(d.path(), FsyncPolicy::Group).unwrap();
        assert_eq!(ds.current_term, 5);
    }

    #[test]
    fn fsync_policy_parses() {
        assert_eq!("always".parse::<FsyncPolicy>(), Ok(FsyncPolicy::Always));
        assert_eq!("group".parse::<FsyncPolicy>(), Ok(FsyncPolicy::Group));
        assert_eq!("never".parse::<FsyncPolicy>(), Ok(FsyncPolicy::Never));
        assert_eq!("none".parse::<FsyncPolicy>(), Ok(FsyncPolicy::Never));
        assert!("sometimes".parse::<FsyncPolicy>().is_err());
    }
}
