//! CRC-framed, length-prefixed append-only write-ahead log for Raft
//! log entries.
//!
//! Record layout on disk (all integers little-endian):
//!
//! ```text
//! record  := u32 payload_len | u32 crc32(payload) | payload
//! payload := u8 tag
//!            tag 1 (Append):   u64 index | entry (wire encoding)
//!            tag 2 (Truncate): u64 after_index
//! ```
//!
//! Recovery scans from the start and keeps the **longest valid prefix**:
//! the scan stops at a partial header, a partial payload (torn tail
//! write), a CRC mismatch, an undecodable payload, or an index
//! discontinuity — whatever survives up to that point is the recovered
//! log, and the file is truncated back to it so subsequent appends never
//! interleave with garbage. An `Append` at an index the log already has
//! implies truncation first (Raft's truncate-on-conflict replayed from
//! disk); an explicit `Truncate` record covers conflict truncations that
//! are not immediately re-filled.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::fs::{File, OpenOptions};
use std::io::{self, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use crate::raft::log::{Entry, Log};
use crate::raft::types::Index;
use crate::server::wire::{Dec, Enc};

use super::FsyncPolicy;

const REC_APPEND: u8 = 1;
const REC_TRUNCATE: u8 = 2;

/// Refuse absurd records during recovery (corrupt length field). A wire
/// entry is ~40 bytes; 1 MiB leaves generous headroom.
const MAX_RECORD_BYTES: usize = 1 << 20;

/// One durable WAL operation.
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    Append { index: Index, entry: Entry },
    Truncate { after: Index },
}

/// CRC-32 (IEEE 802.3, poly 0xEDB88320), table built at compile time —
/// no crates, no runtime init.
const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = crc_table();

pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Append handle over the WAL file. Writes are buffered; [`Wal::sync`]
/// is the durability barrier (flush + fdatasync per the policy).
pub struct Wal {
    w: BufWriter<File>,
    policy: FsyncPolicy,
    /// Bytes written since the last durability barrier.
    dirty: bool,
    /// Reusable payload-encode scratch.
    enc: Enc,
}

impl Wal {
    /// Open (creating if absent) and recover: returns the handle plus
    /// the longest-valid-prefix [`Log`]. The file is truncated back to
    /// that prefix so a torn tail can never corrupt later appends.
    pub fn open(path: &Path, policy: FsyncPolicy) -> io::Result<(Wal, Log)> {
        Wal::open_into(path, policy, Log::new())
    }

    /// As [`Wal::open`], but replay on top of `log` — a log already
    /// positioned at this segment's snapshot base (or further along,
    /// when chaining frozen segments after a torn-snapshot fallback).
    pub fn open_into(path: &Path, policy: FsyncPolicy, log: Log) -> io::Result<(Wal, Log)> {
        let mut file = OpenOptions::new().read(true).write(true).create(true).open(path)?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        let (log, valid_len) = replay_into(&bytes, log);
        if valid_len < bytes.len() as u64 {
            file.set_len(valid_len)?;
            if policy.fsyncs() {
                file.sync_data()?;
            }
        }
        file.seek(SeekFrom::Start(valid_len))?;
        let wal =
            Wal { w: BufWriter::with_capacity(64 << 10, file), policy, dirty: false, enc: Enc::new() };
        Ok((wal, log))
    }

    /// Append one record. Under [`FsyncPolicy::Always`] this is a full
    /// durability barrier by itself; otherwise call [`Wal::sync`] before
    /// acting on the record (group sync).
    pub fn append(&mut self, rec: &WalRecord) -> io::Result<()> {
        self.enc.reset();
        encode_record(rec, &mut self.enc);
        let payload = &self.enc.buf;
        self.w.write_all(&(payload.len() as u32).to_le_bytes())?;
        self.w.write_all(&crc32(payload).to_le_bytes())?;
        self.w.write_all(payload)?;
        self.dirty = true;
        if matches!(self.policy, FsyncPolicy::Always) {
            self.sync()?;
        }
        Ok(())
    }

    /// Durability barrier: flush buffered records and (policy
    /// permitting) fdatasync. No-op when nothing was written since the
    /// last barrier.
    pub fn sync(&mut self) -> io::Result<()> {
        if !self.dirty {
            return Ok(());
        }
        self.w.flush()?;
        if self.policy.fsyncs() {
            self.w.get_ref().sync_data()?;
        }
        self.dirty = false;
        Ok(())
    }

    /// First half of a split barrier: push buffered records to the
    /// kernel, without the fsync. Returns whether this WAL had pending
    /// records. Used by the cross-group barrier, which flushes every
    /// dirty group's WAL and then issues ONE filesystem-wide sync
    /// instead of one fdatasync per group.
    pub fn flush(&mut self) -> io::Result<bool> {
        if !self.dirty {
            return Ok(false);
        }
        self.w.flush()?;
        Ok(true)
    }

    /// Second half of a split barrier: the caller has made the flushed
    /// records durable by other means (e.g. `syncfs`).
    pub fn mark_synced(&mut self) {
        self.dirty = false;
    }

    /// The underlying file (for `syncfs` on its filesystem).
    pub fn file(&self) -> &File {
        self.w.get_ref()
    }
}

fn encode_record(rec: &WalRecord, e: &mut Enc) {
    match rec {
        WalRecord::Append { index, entry } => {
            e.u8(REC_APPEND);
            e.u64(*index);
            e.entry(entry);
        }
        WalRecord::Truncate { after } => {
            e.u8(REC_TRUNCATE);
            e.u64(*after);
        }
    }
}

fn decode_record(payload: &[u8]) -> Option<WalRecord> {
    let mut d = Dec::new(payload);
    let rec = match d.u8().ok()? {
        REC_APPEND => WalRecord::Append { index: d.u64().ok()?, entry: d.entry().ok()? },
        REC_TRUNCATE => WalRecord::Truncate { after: d.u64().ok()? },
        _ => return None,
    };
    if !d.done() {
        return None; // trailing bytes: corrupt payload
    }
    Some(rec)
}

/// Scan `bytes`, applying every valid record in order on top of `log`;
/// returns the recovered log and the byte length of the valid prefix.
/// Never panics: any malformed suffix — torn tail, bad CRC, bad
/// payload, index gap, or a truncate pointing past the recovered tip —
/// simply ends the scan.
///
/// Snapshot chaining: records at or below `log.base()` describe state
/// the snapshot already captures and are skipped (not errors — a frozen
/// pre-snapshot segment legitimately overlaps the snapshot prefix).
pub fn replay_into(bytes: &[u8], mut log: Log) -> (Log, u64) {
    let mut pos = 0usize;
    loop {
        let Some(hdr) = bytes.get(pos..pos + 8) else { break };
        let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        if len > MAX_RECORD_BYTES {
            break;
        }
        let Some(payload) = bytes.get(pos + 8..pos + 8 + len) else { break };
        if crc32(payload) != crc {
            break;
        }
        let Some(rec) = decode_record(payload) else { break };
        match rec {
            WalRecord::Append { index, entry } => {
                if index == 0 || index > log.last_index() + 1 {
                    break; // gap: cannot have been written by a correct node
                }
                if index > log.base() {
                    if index <= log.last_index() {
                        log.truncate_after(index - 1); // conflict overwrite
                    }
                    log.append(entry);
                }
            }
            WalRecord::Truncate { after } => {
                if after > log.last_index() {
                    break; // forward truncate: cannot have been written by a correct node
                }
                log.truncate_after(after);
            }
        }
        pos += 8 + len;
    }
    (log, pos as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::TimeInterval;
    use crate::kv::Command;

    fn e(term: u64, t: i64) -> Entry {
        Entry {
            term,
            command: Command::Put { key: term as u32, value: t as u64, payload_bytes: 0 },
            written_at: TimeInterval::exact(t),
        }
    }

    fn tmp(name: &str) -> crate::testkit::TempDir {
        crate::testkit::TempDir::new(name)
    }

    #[test]
    fn crc32_known_vector() {
        // Standard check value for CRC-32/ISO-HDLC over "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_and_reopen() {
        let d = tmp("wal-roundtrip");
        let p = d.path().join("wal");
        {
            let (mut w, log) = Wal::open(&p, FsyncPolicy::Group).unwrap();
            assert_eq!(log.last_index(), 0);
            for i in 1..=5u64 {
                w.append(&WalRecord::Append { index: i, entry: e(1, i as i64) }).unwrap();
            }
            w.sync().unwrap();
        }
        let (_, log) = Wal::open(&p, FsyncPolicy::Group).unwrap();
        assert_eq!(log.last_index(), 5);
        assert_eq!(log.get(3).unwrap().written_at.earliest, 3);
    }

    #[test]
    fn conflict_overwrite_replays_truncation() {
        let d = tmp("wal-conflict");
        let p = d.path().join("wal");
        {
            let (mut w, _) = Wal::open(&p, FsyncPolicy::Group).unwrap();
            for i in 1..=4u64 {
                w.append(&WalRecord::Append { index: i, entry: e(1, i as i64) }).unwrap();
            }
            // New leader overwrites from index 3.
            w.append(&WalRecord::Truncate { after: 2 }).unwrap();
            w.append(&WalRecord::Append { index: 3, entry: e(2, 30) }).unwrap();
            w.sync().unwrap();
        }
        let (_, log) = Wal::open(&p, FsyncPolicy::Group).unwrap();
        assert_eq!(log.last_index(), 3);
        assert_eq!(log.get(3).unwrap().term, 2);
        assert_eq!(log.get(2).unwrap().term, 1);
    }

    #[test]
    fn torn_tail_recovers_prefix_and_truncates_file() {
        let d = tmp("wal-torn");
        let p = d.path().join("wal");
        {
            let (mut w, _) = Wal::open(&p, FsyncPolicy::Group).unwrap();
            for i in 1..=3u64 {
                w.append(&WalRecord::Append { index: i, entry: e(1, i as i64) }).unwrap();
            }
            w.sync().unwrap();
        }
        // Tear the tail mid-record.
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() - 7]).unwrap();
        let (_, log) = Wal::open(&p, FsyncPolicy::Group).unwrap();
        assert_eq!(log.last_index(), 2, "torn third record must be dropped");
        // The file was truncated back to the valid prefix; appends resume
        // cleanly.
        let flen = std::fs::metadata(&p).unwrap().len();
        let (mut w, _) = Wal::open(&p, FsyncPolicy::Group).unwrap();
        w.append(&WalRecord::Append { index: 3, entry: e(2, 33) }).unwrap();
        w.sync().unwrap();
        assert!(std::fs::metadata(&p).unwrap().len() > flen);
        let (_, log) = Wal::open(&p, FsyncPolicy::Group).unwrap();
        assert_eq!(log.last_index(), 3);
        assert_eq!(log.get(3).unwrap().term, 2);
    }

    #[test]
    fn mid_file_corruption_stops_scan_without_panic() {
        let d = tmp("wal-crc");
        let p = d.path().join("wal");
        {
            let (mut w, _) = Wal::open(&p, FsyncPolicy::Group).unwrap();
            for i in 1..=5u64 {
                w.append(&WalRecord::Append { index: i, entry: e(1, i as i64) }).unwrap();
            }
            w.sync().unwrap();
        }
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let (_, log) = Wal::open(&p, FsyncPolicy::Group).unwrap();
        assert!(log.last_index() < 5, "corrupted record and successors dropped");
    }

    #[test]
    fn forward_truncate_record_is_rejected_at_prefix_boundary() {
        // Regression: a corrupt-but-CRC-valid Truncate whose `after`
        // points PAST the recovered prefix cannot have been written by a
        // correct node (truncations only ever rewind). It must end the
        // scan — not be applied blindly — and everything after it must
        // be dropped with the file truncated back to the valid prefix.
        let d = tmp("wal-fwd-trunc");
        let p = d.path().join("wal");
        {
            let (mut w, _) = Wal::open(&p, FsyncPolicy::Group).unwrap();
            for i in 1..=3u64 {
                w.append(&WalRecord::Append { index: i, entry: e(1, i as i64) }).unwrap();
            }
            // The poison pill: truncate "after 9" on a 3-entry log.
            w.append(&WalRecord::Truncate { after: 9 }).unwrap();
            // A record behind the pill: must NOT survive recovery.
            w.append(&WalRecord::Append { index: 4, entry: e(2, 44) }).unwrap();
            w.sync().unwrap();
        }
        let (_, log) = Wal::open(&p, FsyncPolicy::Group).unwrap();
        assert_eq!(log.last_index(), 3, "forward truncate must stop the scan");
        assert_eq!(log.get(3).unwrap().term, 1);
        // The file was rewound to the 3-record valid prefix.
        let (_, log) = Wal::open(&p, FsyncPolicy::Group).unwrap();
        assert_eq!(log.last_index(), 3);
    }

    #[test]
    fn replay_into_skips_snapshot_covered_prefix() {
        // A frozen pre-snapshot segment overlaps the snapshot prefix;
        // chained replay must skip the covered records and apply the
        // tail on top of the snapshot base.
        let d = tmp("wal-chain");
        let p = d.path().join("wal");
        {
            let (mut w, _) = Wal::open(&p, FsyncPolicy::Group).unwrap();
            for i in 1..=6u64 {
                w.append(&WalRecord::Append { index: i, entry: e(1, i as i64) }).unwrap();
            }
            // An old conflict entirely below the future snapshot point.
            w.append(&WalRecord::Truncate { after: 4 }).unwrap();
            w.append(&WalRecord::Append { index: 5, entry: e(2, 55) }).unwrap();
            w.sync().unwrap();
        }
        let bytes = std::fs::read(&p).unwrap();
        // Snapshot at index 5 (term 2): replay the same segment on top.
        let base = Log::with_base(5, 2, TimeInterval::exact(55));
        let (log, _) = replay_into(&bytes, base);
        assert_eq!(log.base(), 5);
        assert_eq!(log.last_index(), 5, "post-truncate tip, prefix from snapshot");
        // And a base further back replays the suffix normally.
        let base = Log::with_base(3, 1, TimeInterval::exact(3));
        let (log, _) = replay_into(&bytes, base);
        assert_eq!(log.last_index(), 5);
        assert_eq!(log.get(5).unwrap().term, 2);
        assert_eq!(log.get(4).unwrap().term, 1);
    }

    #[test]
    fn empty_file_recovers_empty_log() {
        let d = tmp("wal-empty");
        let p = d.path().join("wal");
        std::fs::write(&p, b"").unwrap();
        let (_, log) = Wal::open(&p, FsyncPolicy::Group).unwrap();
        assert_eq!(log.last_index(), 0);
    }
}
