//! Atomically-rewritten hard-state file for `(current_term, voted_for)`.
//!
//! Layout (little-endian): `u32 crc32(payload) | payload`, where
//! `payload := u64 term | u8 has_vote | u32 vote`. The file is tiny and
//! rewritten whole on every change: write `hard_state.tmp`, fsync it,
//! `rename` over `hard_state`, fsync the directory. Under our crash
//! model (process kill, or power loss with fsync enabled) a reader sees
//! either the old file or the new one, never a mix.
//!
//! A missing or corrupt file reads as `(term 0, no vote)`. That is the
//! conservative default for `voted_for` the same way an empty WAL is for
//! the log: the atomic rewrite means corruption here implies the write
//! never reported durable, so no vote built on it was ever sent.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::fs::{self, File};
use std::io::{self, Write};
use std::path::Path;

use crate::raft::types::Term;
use crate::NodeId;

use super::wal::crc32;
use super::FsyncPolicy;

pub const FILE: &str = "hard_state";
const TMP: &str = "hard_state.tmp";

/// Read the hard state; any missing/short/corrupt file is `(0, None)`.
pub fn read(dir: &Path) -> (Term, Option<NodeId>) {
    let Ok(bytes) = fs::read(dir.join(FILE)) else { return (0, None) };
    parse(&bytes).unwrap_or((0, None))
}

fn parse(bytes: &[u8]) -> Option<(Term, Option<NodeId>)> {
    if bytes.len() != 4 + 13 {
        return None;
    }
    let crc = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    let payload = &bytes[4..];
    if crc32(payload) != crc {
        return None;
    }
    let term = u64::from_le_bytes(payload[0..8].try_into().unwrap());
    let voted_for = match payload[8] {
        0 => None,
        1 => Some(u32::from_le_bytes(payload[9..13].try_into().unwrap()) as NodeId),
        _ => return None,
    };
    Some((term, voted_for))
}

/// Durably replace the hard state (tmp + fsync + rename + dir fsync,
/// with the fsyncs subject to `policy`).
pub fn write(dir: &Path, term: Term, voted_for: Option<NodeId>, policy: FsyncPolicy) -> io::Result<()> {
    let mut payload = [0u8; 13];
    payload[0..8].copy_from_slice(&term.to_le_bytes());
    if let Some(v) = voted_for {
        payload[8] = 1;
        payload[9..13].copy_from_slice(&(v as u32).to_le_bytes());
    }
    let mut bytes = [0u8; 17];
    bytes[0..4].copy_from_slice(&crc32(&payload).to_le_bytes());
    bytes[4..].copy_from_slice(&payload);

    let tmp = dir.join(TMP);
    let mut f = File::create(&tmp)?;
    f.write_all(&bytes)?;
    if policy.fsyncs() {
        f.sync_data()?;
    }
    drop(f);
    fs::rename(&tmp, dir.join(FILE))?;
    if policy.fsyncs() {
        // Persist the rename itself.
        File::open(dir)?.sync_all()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::TempDir;

    #[test]
    fn roundtrip() {
        let d = TempDir::new("hs-roundtrip");
        assert_eq!(read(d.path()), (0, None));
        write(d.path(), 7, Some(2), FsyncPolicy::Always).unwrap();
        assert_eq!(read(d.path()), (7, Some(2)));
        write(d.path(), 8, None, FsyncPolicy::Group).unwrap();
        assert_eq!(read(d.path()), (8, None));
    }

    #[test]
    fn half_written_file_reads_as_default() {
        let d = TempDir::new("hs-torn");
        write(d.path(), 9, Some(1), FsyncPolicy::Always).unwrap();
        let full = fs::read(d.path().join(FILE)).unwrap();
        // Simulate a torn direct write (not possible via the tmp+rename
        // path, but the reader must still never panic).
        for cut in 0..full.len() {
            fs::write(d.path().join(FILE), &full[..cut]).unwrap();
            assert_eq!(read(d.path()), (0, None), "cut at {cut}");
        }
        // Bit flips are caught by the CRC.
        for i in 0..full.len() {
            let mut bad = full.clone();
            bad[i] ^= 0x40;
            fs::write(d.path().join(FILE), &bad).unwrap();
            assert_eq!(read(d.path()), (0, None), "flip at byte {i}");
        }
    }
}
