//! # LeaseGuard — Raft leader leases done right (reproduction)
//!
//! A from-scratch reproduction of *"LeaseGuard: Raft Leases Done Right"*
//! (Davis, Demirbas, Deng; MongoDB Research, 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — a complete Raft implementation with the
//!   LeaseGuard lease protocol and every consistency mechanism the paper
//!   evaluates (quorum checks, Ongaro leases, unoptimized log leases,
//!   deferred-commit writes, inherited-lease reads, and no consistency),
//!   a deterministic discrete-event simulator, a threaded TCP server
//!   ("LogCabin-equivalent" testbed), an omniscient linearizability
//!   checker, and one experiment driver per paper figure.
//! * **Layer 2/1 (python/, build-time only)** — the batched
//!   read-admission model (lease-age + limbo-conflict) with a Pallas
//!   conflict-mask kernel, AOT-lowered to `artifacts/*.hlo.txt` and
//!   executed from [`runtime`] via the PJRT CPU client. Python never
//!   runs on the request path.
//!
//! Entry points: the `leaseguard` binary (`rust/src/main.rs`), the
//! `examples/`, and the per-figure benches in `rust/benches/`.
//!
//! Module map (see `DESIGN.md` for the full system inventory):
//!
//! | module | role |
//! |---|---|
//! | [`bench`] | hot-path microbench suite (BENCH_micro.json trajectory) |
//! | [`prob`] | seeded PRNG + the paper's probability distributions |
//! | [`clock`] | bounded-uncertainty clocks (§2.2, §4.3) |
//! | [`sim`] | deterministic event loop + simulated network (§6.1) |
//! | [`raft`] | the Raft substrate (log, elections, replication) |
//! | [`lease`] | LeaseGuard + Ongaro leases + consistency modes (§3, §7.1) |
//! | [`kv`] | append-only-list KV state machine (§6.1) |
//! | [`workload`] | open-loop workload generators (§6.3-§6.6) |
//! | [`history`], [`linearizability`] | client histories + checker (§6.2) |
//! | [`metrics`], [`report`] | histograms, time series, figure rendering |
//! | [`obs`] | observability: flight recorder, metrics registry, live introspection |
//! | [`runtime`] | PJRT artifact loading + batched read admission |
//! | [`server`], [`client`] | real-mode TCP cluster + open-loop client (§7) |
//! | [`shard`] | multi-Raft sharding: ShardMap keyspace partition + per-group routing |
//! | [`snap`] | state-machine snapshots + log compaction (bounded storage, fast catch-up) |
//! | [`storage`] | real-mode WAL + hard-state durability (crash recovery) |
//! | [`cluster`] | in-process simulated replica set harness |
//! | [`figures`] | one driver per paper figure (Figs 5-11) |
//! | [`config`], [`cli`] | params system + hand-rolled CLI |
//! | [`lint`] | self-hosted determinism/protocol linter (`leaseguard lint`) |
//! | [`testkit`] | mini property-testing framework (proptest substitute) |

pub mod bench;
pub mod cli;
pub mod clock;
pub mod cluster;
pub mod config;
pub mod figures;
pub mod history;
pub mod kv;
pub mod lease;
pub mod linearizability;
pub mod lint;
pub mod metrics;
pub mod obs;
pub mod prob;
pub mod raft;
pub mod report;
pub mod runtime;
pub mod server;
pub mod client;
pub mod shard;
pub mod sim;
pub mod snap;
pub mod storage;
pub mod testkit;
pub mod workload;

/// Microseconds since an arbitrary epoch: the one time unit used across
/// the simulator, the clocks, and the protocol (the paper works in ms/µs;
/// µs granularity covers both the 50µs clock bounds and 10s leases).
pub type Micros = i64;

/// Node identifier within a replica set (0..n).
pub type NodeId = usize;
