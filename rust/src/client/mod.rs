//! Open-loop async client for the real-mode cluster (the paper's
//! enhanced LogCabin client, §7.1: "the client's offered load always
//! matched our intended intensity, no matter whether the servers
//! experienced high latency or hit a throughput ceiling").
//!
//! One writer thread issues requests exactly on schedule; one reader
//! thread per server connection completes them. Leader discovery mirrors
//! the simulator's client: believed leader, else round-robin probing;
//! any reply other than NotLeader pins the belief. Three failure
//! mechanisms keep the client honest across server crashes:
//!
//! * **Per-target redial with backoff** — a dead connection slot is
//!   retried (exponential backoff, capped) instead of being written off
//!   for the rest of the run, so a crashed-and-restarted server serves
//!   this client again;
//! * **Per-target fail streaks** — a deposed leader answering NoLease
//!   forever can only burn its own streak counter, not have it reset by
//!   successes from the real leader;
//! * **RPC deadlines** — ops pending longer than `params.op_timeout_us`
//!   are failed client-side (ambiguous for writes, exactly like the
//!   simulator's timeout semantics) so a silent server cannot strand
//!   operations in `pending` until the end of the run.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::collections::HashMap;
use std::io::Write as _;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::clock::real::RealClock;
use crate::config::Params;
use crate::history::{History, HistoryEntry, OpKind};
use crate::metrics::{Histogram, TimeSeries};
use crate::prob::Rng;
use crate::raft::{FailReason, OpResult};
use crate::server::server::SharedApplies;
use crate::server::transport::{write_frame, FrameReader};
use crate::server::wire::{self, ClientReq, Enc, Frame};
use crate::shard::{GroupId, ShardMap};
use crate::workload::{OpSpec, Workload};
use crate::Micros;

/// Result of one open-loop client run (mirrors the simulator's report).
#[derive(Debug)]
pub struct ClientReport {
    pub t0: Micros,
    pub series: TimeSeries,
    pub read_latency: Histogram,
    pub write_latency: Histogram,
    pub history: History,
    pub sent: u64,
    pub completed: u64,
}

struct Pending {
    key: u32,
    /// The Raft group this op's key routes to (fixed by the ShardMap).
    group: GroupId,
    write_value: Option<u64>,
    start_ts: Micros,
    target: usize,
}

struct Shared {
    pending: Mutex<HashMap<u64, Pending>>,
    results: Mutex<Vec<(u64, OpResult, Micros, Micros)>>, // op, result, exec, end
    /// Believed leader per group (usize::MAX = unknown). Each group
    /// elects independently, so leader discovery, pinning, and
    /// un-pinning are all per group: losing group 3's leader must not
    /// discard a perfectly good belief about group 0.
    believed_leader: Vec<AtomicUsize>,
    /// Per-(group, target) consecutive non-NotLeader failures, indexed
    /// `g * n_servers + target` (give up on a believed leader after a
    /// bound — a deposed leader can answer NoLease indefinitely). A
    /// success from server A must not excuse server B's streak, nor a
    /// success in group 0 excuse the same server's streak in group 1.
    fail_streaks: Vec<AtomicUsize>,
    n_servers: usize,
    done: AtomicBool,
}

/// Give up on a believed leader after this many consecutive failures.
const FAIL_STREAK_LIMIT: usize = 50;
/// Redial backoff: base and cap (µs). Connections to dead loopback
/// servers fail fast, so the writer pays at most a connect attempt per
/// due slot per op.
const REDIAL_BASE_US: Micros = 2_000;
const REDIAL_CAP_US: Micros = 100_000;
/// How often (in issued ops) the writer sweeps `pending` for ops past
/// their RPC deadline.
const DEADLINE_SWEEP_EVERY: u64 = 128;

/// One outgoing connection slot. `None` stream = down; redialed with
/// backoff instead of staying dead for the rest of the run.
struct Slot {
    stream: Option<TcpStream>,
    next_dial: Micros,
    backoff_us: Micros,
}

fn spawn_reader(stream: TcpStream, sh: Arc<Shared>) -> JoinHandle<()> {
    std::thread::spawn(move || {
        // Buffered reads + reusable scratch per connection.
        let mut frames = FrameReader::new(stream);
        while let Ok(Some(body)) = frames.next_frame() {
            let Ok(Frame::ClientResp(resp)) = wire::decode(body) else { break };
            let end = RealClock::monotonic_us();
            // Live leader discovery: NotLeader un-pins the belief; any
            // other reply pins the target. Ops already failed by the
            // deadline sweep are gone from `pending`: their late replies
            // influence neither belief nor the history (no double
            // completion).
            let tgt = sh
                .pending
                .lock()
                .unwrap()
                .get(&resp.op)
                .map(|p| (p.group as usize, p.target));
            if let Some((g, t)) = tgt {
                let streak = g * sh.n_servers + t;
                match &resp.result {
                    OpResult::Failed(FailReason::NotLeader)
                    | OpResult::Failed(FailReason::Timeout) => {
                        let _ = sh.believed_leader[g].compare_exchange(
                            t,
                            usize::MAX,
                            Ordering::Relaxed,
                            Ordering::Relaxed,
                        );
                    }
                    OpResult::Failed(_) => {
                        // The target led but couldn't serve; give up
                        // after a persistent streak.
                        if sh.fail_streaks[streak].fetch_add(1, Ordering::Relaxed)
                            >= FAIL_STREAK_LIMIT
                        {
                            sh.fail_streaks[streak].store(0, Ordering::Relaxed);
                            let _ = sh.believed_leader[g].compare_exchange(
                                t,
                                usize::MAX,
                                Ordering::Relaxed,
                                Ordering::Relaxed,
                            );
                        }
                    }
                    _ => {
                        sh.fail_streaks[streak].store(0, Ordering::Relaxed);
                        sh.believed_leader[g].store(t, Ordering::Relaxed);
                    }
                }
            }
            sh.results.lock().unwrap().push((resp.op, resp.result, resp.exec_us, end));
            if sh.done.load(Ordering::Relaxed) {
                break;
            }
        }
    })
}

/// (Re)connect a down slot if its backoff has elapsed. Returns whether
/// the slot is usable.
fn ensure_connected(
    slot: &mut Slot,
    addr: &str,
    shared: &Arc<Shared>,
    readers: &mut Vec<JoinHandle<()>>,
) -> bool {
    if slot.stream.is_some() {
        return true;
    }
    let now = RealClock::monotonic_us();
    if now < slot.next_dial {
        return false;
    }
    let conn = match addr.parse() {
        Ok(sa) => TcpStream::connect_timeout(&sa, Duration::from_millis(50)),
        Err(_) => TcpStream::connect(addr), // hostname: let std resolve
    };
    match conn {
        Ok(s) => {
            s.set_nodelay(true).ok();
            let Ok(r) = s.try_clone() else { return false };
            readers.push(spawn_reader(r, shared.clone()));
            slot.stream = Some(s);
            slot.backoff_us = REDIAL_BASE_US;
            true
        }
        Err(_) => {
            slot.next_dial = RealClock::monotonic_us() + slot.backoff_us;
            slot.backoff_us = (slot.backoff_us * 2).min(REDIAL_CAP_US);
            false
        }
    }
}

/// Run an open-loop workload against `addrs` for `params.duration_us`.
/// `applies` is the in-process apply log for linearizability checking
/// (None when servers run out of process).
pub fn run_open_loop(
    addrs: &[String],
    params: &Params,
    applies: Option<SharedApplies>,
) -> std::io::Result<ClientReport> {
    let n_servers = addrs.len();
    // The client's copy of the canonical keyspace partition; must agree
    // with the servers' (both derive it from the same Params).
    let map = ShardMap::new(params.groups);
    let groups = map.groups();
    let shared = Arc::new(Shared {
        pending: Mutex::new(HashMap::new()),
        results: Mutex::new(Vec::new()),
        believed_leader: (0..groups).map(|_| AtomicUsize::new(usize::MAX)).collect(),
        fail_streaks: (0..groups * n_servers).map(|_| AtomicUsize::new(0)).collect(),
        n_servers,
        done: AtomicBool::new(false),
    });

    // One connection slot per server; a reader thread per live
    // connection. Slots that fail to connect are retried during the run.
    let mut writers: Vec<Slot> = Vec::new();
    let mut readers: Vec<JoinHandle<()>> = Vec::new();
    for addr in addrs {
        let mut slot = Slot { stream: None, next_dial: Micros::MIN, backoff_us: REDIAL_BASE_US };
        ensure_connected(&mut slot, addr, &shared, &mut readers);
        writers.push(slot);
    }

    let t0 = RealClock::monotonic_us();
    let mut rng = Rng::new(params.seed ^ 0xC11E17);
    let mut workload = Workload::from_params(params, &mut rng);
    let schedule: Vec<OpSpec> = workload.schedule(params.duration_us);
    // Per-group probe cursors: when group g's leader is unknown the
    // writer round-robins that group's probes independently.
    let mut probe = vec![0usize; groups];
    let mut sent: u64 = 0;
    let mut op_id: u64 = 0;
    // Ops failed client-side by the deadline sweep (op, pending, end):
    // kept writer-local so a late server reply can't double-complete
    // them (`pending.remove` already returned None for it).
    let mut deadline_failed: Vec<(u64, Pending, Micros)> = Vec::new();
    // Reusable request-encode buffer: the open-loop writer allocates no
    // fresh frame buffer per operation.
    let mut enc = Enc::new();

    for spec in &schedule {
        // Open loop: issue exactly at t0 + spec.at.
        let due = t0 + spec.at;
        loop {
            let now = RealClock::monotonic_us();
            if now >= due {
                break;
            }
            let gap = due - now;
            if gap > 200 {
                std::thread::sleep(Duration::from_micros((gap - 100) as u64));
            } else {
                std::hint::spin_loop();
            }
        }
        op_id += 1;
        let op = op_id;
        // RPC deadline sweep: fail ops stuck past op_timeout_us so a
        // silent server (crashed mid-request) can't strand them.
        if op_id % DEADLINE_SWEEP_EVERY == 0 {
            let now = RealClock::monotonic_us();
            let mut pend = shared.pending.lock().unwrap();
            let expired: Vec<u64> = pend
                .iter()
                .filter(|(_, p)| now - p.start_ts > params.op_timeout_us)
                .map(|(&o, _)| o)
                .collect();
            for o in expired {
                let p = pend.remove(&o).expect("expired op is pending");
                let _ = shared.believed_leader[p.group as usize].compare_exchange(
                    p.target,
                    usize::MAX,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                );
                deadline_failed.push((o, p, now));
            }
        }
        let group = map.group_of(spec.key);
        let target = {
            let b = shared.believed_leader[group as usize].load(Ordering::Relaxed);
            if b < n_servers {
                b
            } else {
                let p = &mut probe[group as usize];
                *p = (*p + 1) % n_servers;
                *p
            }
        };
        let start = RealClock::monotonic_us();
        shared.pending.lock().unwrap().insert(
            op,
            Pending {
                key: spec.key,
                group,
                write_value: spec.write_value,
                start_ts: start,
                target,
            },
        );
        let req = Frame::ClientReq(ClientReq {
            op,
            key: spec.key,
            write_value: spec.write_value,
            payload: vec![0xA5; spec.payload_bytes as usize],
        });
        let ok = ensure_connected(&mut writers[target], &addrs[target], &shared, &mut readers)
            && {
                let w = writers[target].stream.as_mut().expect("connected slot has stream");
                enc.reset();
                wire::encode_into(&req, &mut enc);
                let ok = write_frame(w, &enc.buf).is_ok();
                if !ok {
                    // Drop the broken stream; the slot redials with
                    // backoff on a later op.
                    writers[target].stream = None;
                    writers[target].next_dial = RealClock::monotonic_us() + REDIAL_BASE_US;
                }
                ok
            };
        if !ok {
            // Server unreachable (crashed): fast-fail the op, probe on.
            let _ = shared.believed_leader[group as usize].compare_exchange(
                target,
                usize::MAX,
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
            let end = RealClock::monotonic_us();
            shared
                .results
                .lock()
                .unwrap()
                .push((op, OpResult::Failed(FailReason::Timeout), end, end));
        } else {
            sent += 1;
        }
    }

    // Grace period for stragglers, then collect.
    std::thread::sleep(Duration::from_millis(300));
    shared.done.store(true, Ordering::Relaxed);
    for w in writers.iter_mut() {
        if let Some(s) = &mut w.stream {
            let _ = s.flush();
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }

    // Build the report.
    let mut series = TimeSeries::new(params.bucket_us, params.duration_us);
    let mut read_latency = Histogram::new();
    let mut write_latency = Histogram::new();
    let mut history = History::new();
    if let Some(a) = &applies {
        for &(k, v, t) in a.lock().unwrap().iter() {
            history.applies.record(k, v, t);
        }
    }
    let results = std::mem::take(&mut *shared.results.lock().unwrap());
    let mut completed = 0u64;
    let mut pending = shared.pending.lock().unwrap();
    // Belief updates happened implicitly through probing during the run;
    // here we only assemble metrics/history.
    for (op, result, exec, end) in results {
        let Some(p) = pending.remove(&op) else { continue };
        completed += 1;
        let is_read = p.write_value.is_none();
        let success = result.is_ok();
        series.record(is_read, (end - t0).max(0), success);
        if success {
            let lat = end - p.start_ts;
            if is_read {
                read_latency.record(lat);
            } else {
                write_latency.record(lat);
            }
        }
        let (kind, exec_ts) = match (&result, p.write_value) {
            (OpResult::ReadOk(v), _) => (OpKind::Read { result: (**v).clone() }, Some(exec)),
            (_, Some(v)) => (OpKind::Append { value: v }, None),
            (_, None) => (OpKind::Read { result: Vec::new() }, None),
        };
        history.entries.push(HistoryEntry {
            op,
            key: p.key,
            kind,
            start_ts: p.start_ts,
            end_ts: end,
            execution_ts: exec_ts,
            success,
            fail: match result {
                OpResult::Failed(r) => Some(r),
                _ => None,
            },
        });
    }
    // Ops failed by the deadline sweep, then ops still unanswered at the
    // end of the run: both are timeouts (ambiguous writes).
    let now = RealClock::monotonic_us();
    let leftovers = pending.drain().map(|(op, p)| (op, p, now));
    for (op, p, end) in deadline_failed.into_iter().chain(leftovers) {
        let is_read = p.write_value.is_none();
        series.record(is_read, (end - t0).max(0), false);
        history.entries.push(HistoryEntry {
            op,
            key: p.key,
            kind: match p.write_value {
                Some(v) => OpKind::Append { value: v },
                None => OpKind::Read { result: Vec::new() },
            },
            start_ts: p.start_ts,
            end_ts: end,
            execution_ts: None,
            success: false,
            fail: Some(FailReason::Timeout),
        });
    }
    drop(pending);

    Ok(ClientReport {
        t0,
        series,
        read_latency,
        write_latency,
        history,
        sent,
        completed,
    })
}

/// Fetch a live [`crate::obs::StatusSnapshot`] from one server: sends a
/// `StatusReq` asking for the last `tail` flight-recorder events per
/// group and blocks (with a 5s read deadline) for the reply. Backs
/// `leaseguard stat`.
pub fn fetch_status(addr: &str, tail: u32) -> std::io::Result<crate::obs::StatusSnapshot> {
    use std::io::{Error, ErrorKind};
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut enc = Enc::new();
    wire::encode_into(&Frame::StatusReq { tail }, &mut enc);
    {
        let mut w = stream.try_clone()?;
        write_frame(&mut w, &enc.buf)?;
    }
    let mut frames = FrameReader::new(stream);
    match frames.next_frame()? {
        Some(body) => match wire::decode(body) {
            Ok(Frame::StatusResp(snap)) => Ok(*snap),
            Ok(f) => Err(Error::new(ErrorKind::InvalidData, format!("unexpected frame {f:?}"))),
            Err(e) => Err(Error::new(ErrorKind::InvalidData, e.0)),
        },
        None => Err(Error::new(ErrorKind::UnexpectedEof, "connection closed before status reply")),
    }
}
