//! Open-loop async client for the real-mode cluster (the paper's
//! enhanced LogCabin client, §7.1: "the client's offered load always
//! matched our intended intensity, no matter whether the servers
//! experienced high latency or hit a throughput ceiling").
//!
//! One writer thread issues requests exactly on schedule; one reader
//! thread per server connection completes them. Leader discovery mirrors
//! the simulator's client: believed leader, else round-robin probing;
//! any reply other than NotLeader pins the belief.

use std::collections::HashMap;
use std::io::Write as _;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::clock::real::RealClock;
use crate::config::Params;
use crate::history::{History, HistoryEntry, OpKind};
use crate::metrics::{Histogram, TimeSeries};
use crate::prob::Rng;
use crate::raft::{FailReason, OpResult};
use crate::server::server::SharedApplies;
use crate::server::transport::{write_frame, FrameReader};
use crate::server::wire::{self, ClientReq, Enc, Frame};
use crate::workload::{OpSpec, Workload};
use crate::Micros;

/// Result of one open-loop client run (mirrors the simulator's report).
#[derive(Debug)]
pub struct ClientReport {
    pub t0: Micros,
    pub series: TimeSeries,
    pub read_latency: Histogram,
    pub write_latency: Histogram,
    pub history: History,
    pub sent: u64,
    pub completed: u64,
}

struct Pending {
    key: u32,
    write_value: Option<u64>,
    start_ts: Micros,
    target: usize,
}

struct Shared {
    pending: Mutex<HashMap<u64, Pending>>,
    results: Mutex<Vec<(u64, OpResult, Micros, Micros)>>, // op, result, exec, end
    believed_leader: AtomicUsize, // usize::MAX = unknown
    /// Consecutive failures against the believed leader (give up after
    /// a bound — a deposed leader can answer NoLease indefinitely).
    fail_streak: AtomicUsize,
    done: AtomicBool,
}

/// Run an open-loop workload against `addrs` for `params.duration_us`.
/// `applies` is the in-process apply log for linearizability checking
/// (None when servers run out of process).
pub fn run_open_loop(
    addrs: &[String],
    params: &Params,
    applies: Option<SharedApplies>,
) -> std::io::Result<ClientReport> {
    let shared = Arc::new(Shared {
        pending: Mutex::new(HashMap::new()),
        results: Mutex::new(Vec::new()),
        believed_leader: AtomicUsize::new(usize::MAX),
        fail_streak: AtomicUsize::new(0),
        done: AtomicBool::new(false),
    });

    // One connection per server; reader thread each.
    let mut writers: Vec<Option<TcpStream>> = Vec::new();
    let mut readers = Vec::new();
    for addr in addrs {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                let r = s.try_clone()?;
                let sh = shared.clone();
                readers.push(std::thread::spawn(move || {
                    // Buffered reads + reusable scratch per connection.
                    let mut frames = FrameReader::new(r);
                    while let Ok(Some(body)) = frames.next_frame() {
                        let Ok(Frame::ClientResp(resp)) = wire::decode(body) else { break };
                        let end = RealClock::monotonic_us();
                        // Live leader discovery: NotLeader un-pins the
                        // belief; any other reply pins the target.
                        let tgt =
                            sh.pending.lock().unwrap().get(&resp.op).map(|p| p.target);
                        if let Some(t) = tgt {
                            match &resp.result {
                                OpResult::Failed(FailReason::NotLeader)
                                | OpResult::Failed(FailReason::Timeout) => {
                                    let _ = sh.believed_leader.compare_exchange(
                                        t,
                                        usize::MAX,
                                        Ordering::Relaxed,
                                        Ordering::Relaxed,
                                    );
                                }
                                OpResult::Failed(_) => {
                                    // The target led but couldn't serve;
                                    // give up after a persistent streak.
                                    if sh.fail_streak.fetch_add(1, Ordering::Relaxed) >= 50 {
                                        sh.fail_streak.store(0, Ordering::Relaxed);
                                        let _ = sh.believed_leader.compare_exchange(
                                            t,
                                            usize::MAX,
                                            Ordering::Relaxed,
                                            Ordering::Relaxed,
                                        );
                                    }
                                }
                                _ => {
                                    sh.fail_streak.store(0, Ordering::Relaxed);
                                    sh.believed_leader.store(t, Ordering::Relaxed);
                                }
                            }
                        }
                        sh.results.lock().unwrap().push((resp.op, resp.result, resp.exec_us, end));
                        if sh.done.load(Ordering::Relaxed) {
                            break;
                        }
                    }
                }));
                writers.push(Some(s));
            }
            Err(_) => writers.push(None),
        }
    }

    let t0 = RealClock::monotonic_us();
    let mut rng = Rng::new(params.seed ^ 0xC11E17);
    let mut workload = Workload::from_params(params, &mut rng);
    let schedule: Vec<OpSpec> = workload.schedule(params.duration_us);
    let n_servers = addrs.len();
    let mut probe = 0usize;
    let mut sent: u64 = 0;
    let mut op_id: u64 = 0;
    // Reusable request-encode buffer: the open-loop writer allocates no
    // fresh frame buffer per operation.
    let mut enc = Enc::new();

    for spec in &schedule {
        // Open loop: issue exactly at t0 + spec.at.
        let due = t0 + spec.at;
        loop {
            let now = RealClock::monotonic_us();
            if now >= due {
                break;
            }
            let gap = due - now;
            if gap > 200 {
                std::thread::sleep(Duration::from_micros((gap - 100) as u64));
            } else {
                std::hint::spin_loop();
            }
        }
        op_id += 1;
        let op = op_id;
        let target = {
            let b = shared.believed_leader.load(Ordering::Relaxed);
            if b < n_servers {
                b
            } else {
                probe = (probe + 1) % n_servers;
                probe
            }
        };
        let start = RealClock::monotonic_us();
        shared.pending.lock().unwrap().insert(
            op,
            Pending { key: spec.key, write_value: spec.write_value, start_ts: start, target },
        );
        let req = Frame::ClientReq(ClientReq {
            op,
            key: spec.key,
            write_value: spec.write_value,
            payload: vec![0xA5; spec.payload_bytes as usize],
        });
        let ok = match &mut writers[target] {
            Some(w) => {
                enc.reset();
                wire::encode_into(&req, &mut enc);
                write_frame(w, &enc.buf).is_ok()
            }
            None => false,
        };
        if !ok {
            // Server unreachable (crashed): fast-fail the op, probe on.
            writers[target] = None;
            shared.believed_leader.store(usize::MAX, Ordering::Relaxed);
            let end = RealClock::monotonic_us();
            shared
                .results
                .lock()
                .unwrap()
                .push((op, OpResult::Failed(FailReason::Timeout), end, end));
        } else {
            sent += 1;
        }
    }

    // Grace period for stragglers, then collect.
    std::thread::sleep(Duration::from_millis(300));
    shared.done.store(true, Ordering::Relaxed);
    for w in writers.iter_mut() {
        if let Some(s) = w {
            let _ = s.flush();
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }

    // Build the report.
    let mut series = TimeSeries::new(params.bucket_us, params.duration_us);
    let mut read_latency = Histogram::new();
    let mut write_latency = Histogram::new();
    let mut history = History::new();
    if let Some(a) = &applies {
        for &(k, v, t) in a.lock().unwrap().iter() {
            history.applies.record(k, v, t);
        }
    }
    let results = std::mem::take(&mut *shared.results.lock().unwrap());
    let mut completed = 0u64;
    let mut pending = shared.pending.lock().unwrap();
    // Belief updates happened implicitly through probing during the run;
    // here we only assemble metrics/history.
    for (op, result, exec, end) in results {
        let Some(p) = pending.remove(&op) else { continue };
        completed += 1;
        let is_read = p.write_value.is_none();
        let success = result.is_ok();
        series.record(is_read, (end - t0).max(0), success);
        if success {
            let lat = end - p.start_ts;
            if is_read {
                read_latency.record(lat);
            } else {
                write_latency.record(lat);
            }
        }
        let (kind, exec_ts) = match (&result, p.write_value) {
            (OpResult::ReadOk(v), _) => (OpKind::Read { result: v.clone() }, Some(exec)),
            (_, Some(v)) => (OpKind::Append { value: v }, None),
            (_, None) => (OpKind::Read { result: Vec::new() }, None),
        };
        history.entries.push(HistoryEntry {
            op,
            key: p.key,
            kind,
            start_ts: p.start_ts,
            end_ts: end,
            execution_ts: exec_ts,
            success,
            fail: match result {
                OpResult::Failed(r) => Some(r),
                _ => None,
            },
        });
    }
    // Unanswered ops: timeouts (ambiguous writes).
    let now = RealClock::monotonic_us();
    for (op, p) in pending.drain() {
        let is_read = p.write_value.is_none();
        series.record(is_read, (now - t0).max(0), false);
        history.entries.push(HistoryEntry {
            op,
            key: p.key,
            kind: match p.write_value {
                Some(v) => OpKind::Append { value: v },
                None => OpKind::Read { result: Vec::new() },
            },
            start_ts: p.start_ts,
            end_ts: now,
            execution_ts: None,
            success: false,
            fail: Some(FailReason::Timeout),
        });
    }
    drop(pending);

    Ok(ClientReport {
        t0,
        series,
        read_latency,
        write_latency,
        history,
        sent,
        completed,
    })
}
