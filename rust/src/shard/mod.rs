//! Multi-Raft sharding: partition the keyspace across independent
//! lease-guarded Raft groups hosted in one process.
//!
//! Two pieces live here:
//!
//! - [`ShardMap`] — the hash partition from key to [`GroupId`]. It is
//!   tiny (just the group count) but it is *protocol*: client and
//!   servers must agree on it byte-for-byte, so it serializes with a
//!   magic + version header and rejects anything it does not
//!   recognize. Every router in the system (server main loop, real
//!   client, simulator) derives its routing from a `ShardMap` built
//!   from the same `Params`, never from an ad-hoc `%`.
//!
//! - [`ShardRouter`] — owns the per-group `Node` state machines of one
//!   process and dispatches timers, peer messages, and client ops to
//!   the right group. The per-group protocol (leases, limbo, deferred
//!   commits) is untouched; the router only multiplexes.
//!
//! Groups are deliberately capped at 64 so per-group leader/commit
//! status fits in one `u64` bitmask (see
//! [`crate::obs::Registry::leader_groups`]).

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use crate::clock::TimeInterval;
use crate::raft::{Node, Output};

/// Identifies one Raft group within a process. Groups are dense:
/// `0..params.groups`.
pub type GroupId = u32;

/// Hard cap on groups per process (status bitmasks are u64).
pub const MAX_GROUPS: usize = 64;

/// Serialization header: magic + layout version. Bump the version when
/// the partition function or encoding changes; old peers then reject
/// the map instead of silently mis-routing keys.
pub const SHARDMAP_MAGIC: [u8; 4] = *b"SMAP";
pub const SHARDMAP_VERSION: u8 = 1;

/// Fibonacci-hash multiplier (2^32 / φ). Spreads consecutive keys —
/// the workload generator draws them from a small dense range — across
/// groups instead of clustering them into one.
const HASH_MUL: u32 = 0x9E37_79B1;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShardMapError {
    BadMagic,
    BadVersion(u8),
    Truncated,
    BadGroupCount(u32),
}

impl std::fmt::Display for ShardMapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardMapError::BadMagic => write!(f, "shard map: bad magic"),
            ShardMapError::BadVersion(v) => write!(f, "shard map: unsupported version {v}"),
            ShardMapError::Truncated => write!(f, "shard map: truncated"),
            ShardMapError::BadGroupCount(g) => {
                write!(f, "shard map: group count {g} outside 1..={MAX_GROUPS}")
            }
        }
    }
}

impl std::error::Error for ShardMapError {}

/// The canonical keyspace partition: `group_of(key)` is a pure
/// function of the key and the group count, identical on every client
/// and server that shares the same map.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardMap {
    groups: u32,
}

impl ShardMap {
    /// Panics if `groups` is outside `1..=MAX_GROUPS`; config
    /// validation enforces the same bound earlier with a proper error.
    pub fn new(groups: usize) -> Self {
        assert!(
            (1..=MAX_GROUPS).contains(&groups),
            "groups must be in 1..={MAX_GROUPS}, got {groups}"
        );
        ShardMap { groups: groups as u32 }
    }

    pub fn groups(&self) -> usize {
        self.groups as usize
    }

    /// Key → group. Multiplicative (Fibonacci) hash then modulo; with
    /// one group this is constant 0, so single-group deployments are
    /// bit-identical to the pre-sharding code.
    pub fn group_of(&self, key: u32) -> GroupId {
        key.wrapping_mul(HASH_MUL) % self.groups
    }

    /// Wire form: magic, version, group count (LE).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(9);
        b.extend_from_slice(&SHARDMAP_MAGIC);
        b.push(SHARDMAP_VERSION);
        b.extend_from_slice(&self.groups.to_le_bytes());
        b
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self, ShardMapError> {
        if b.len() < 4 {
            return Err(ShardMapError::Truncated);
        }
        if b[0..4] != SHARDMAP_MAGIC {
            return Err(ShardMapError::BadMagic);
        }
        let rest = &b[4..];
        if rest.is_empty() {
            return Err(ShardMapError::Truncated);
        }
        if rest[0] != SHARDMAP_VERSION {
            return Err(ShardMapError::BadVersion(rest[0]));
        }
        let rest = &rest[1..];
        if rest.len() < 4 {
            return Err(ShardMapError::Truncated);
        }
        let groups = u32::from_le_bytes([rest[0], rest[1], rest[2], rest[3]]);
        if groups == 0 || groups as usize > MAX_GROUPS {
            return Err(ShardMapError::BadGroupCount(groups));
        }
        Ok(ShardMap { groups })
    }
}

/// Derive a per-group RNG seed from the process seed. Group 0 uses the
/// seed unchanged so a 1-group deployment replays exactly the
/// single-group histories the determinism tests pinned.
pub fn group_seed(seed: u64, g: GroupId) -> u64 {
    seed ^ ((g as u64) << 20)
}

/// One process's worth of Raft groups plus the map that routes into
/// them. The router never interprets protocol messages — it only picks
/// which `Node` sees them.
pub struct ShardRouter {
    map: ShardMap,
    nodes: Vec<Node>,
}

impl ShardRouter {
    pub fn new(map: ShardMap, nodes: Vec<Node>) -> Self {
        assert_eq!(map.groups(), nodes.len(), "one Node per group");
        ShardRouter { map, nodes }
    }

    pub fn map(&self) -> &ShardMap {
        &self.map
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn group_for_key(&self, key: u32) -> GroupId {
        self.map.group_of(key)
    }

    pub fn node(&self, g: GroupId) -> &Node {
        &self.nodes[g as usize]
    }

    pub fn node_mut(&mut self, g: GroupId) -> &mut Node {
        &mut self.nodes[g as usize]
    }

    /// Route a client key to its group's node.
    pub fn node_for_key_mut(&mut self, key: u32) -> (GroupId, &mut Node) {
        let g = self.map.group_of(key);
        (g, &mut self.nodes[g as usize])
    }

    pub fn iter(&self) -> impl Iterator<Item = (GroupId, &Node)> {
        self.nodes.iter().enumerate().map(|(g, n)| (g as GroupId, n))
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = (GroupId, &mut Node)> {
        self.nodes.iter_mut().enumerate().map(|(g, n)| (g as GroupId, n))
    }

    /// Restart every group of this process at `now` (a process crash
    /// takes all its groups down together). Returns the per-group
    /// outputs tagged with their group.
    pub fn restart_all(&mut self, now: TimeInterval) -> Vec<(GroupId, Vec<Output>)> {
        self.iter_mut().map(|(g, n)| (g, n.restart(now))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_group_maps_everything_to_zero() {
        let m = ShardMap::new(1);
        for k in 0..1000u32 {
            assert_eq!(m.group_of(k), 0);
        }
    }

    #[test]
    fn partition_is_total_and_in_range() {
        for groups in [2usize, 3, 4, 7, 16, 64] {
            let m = ShardMap::new(groups);
            for k in 0..10_000u32 {
                assert!((m.group_of(k) as usize) < groups);
            }
        }
    }

    #[test]
    fn partition_is_reasonably_balanced() {
        // The workload draws keys from a small dense range; the hash
        // must not funnel them into few groups.
        let groups = 8;
        let m = ShardMap::new(groups);
        let mut counts = vec![0usize; groups];
        let keys = 1000;
        for k in 0..keys as u32 {
            counts[m.group_of(k) as usize] += 1;
        }
        let ideal = keys / groups;
        for (g, &c) in counts.iter().enumerate() {
            assert!(
                c > ideal / 2 && c < ideal * 2,
                "group {g} holds {c} of {keys} keys (ideal {ideal})"
            );
        }
    }

    #[test]
    fn roundtrip_bytes() {
        for groups in [1usize, 2, 16, 64] {
            let m = ShardMap::new(groups);
            let b = m.to_bytes();
            assert_eq!(ShardMap::from_bytes(&b).unwrap(), m);
        }
    }

    #[test]
    fn rejects_bad_magic_version_truncation() {
        let good = ShardMap::new(4).to_bytes();

        let mut bad = good.clone();
        bad[0] = b'X';
        assert_eq!(ShardMap::from_bytes(&bad), Err(ShardMapError::BadMagic));

        let mut bad = good.clone();
        bad[4] = SHARDMAP_VERSION + 1;
        assert_eq!(
            ShardMap::from_bytes(&bad),
            Err(ShardMapError::BadVersion(SHARDMAP_VERSION + 1))
        );

        for cut in 0..good.len() {
            assert!(
                ShardMap::from_bytes(&good[..cut]).is_err(),
                "prefix of {cut} bytes must not decode"
            );
        }

        let mut zero = good.clone();
        zero[5..9].copy_from_slice(&0u32.to_le_bytes());
        assert_eq!(ShardMap::from_bytes(&zero), Err(ShardMapError::BadGroupCount(0)));
    }

    #[test]
    fn group_seed_preserves_group_zero() {
        assert_eq!(group_seed(12345, 0), 12345);
        assert_ne!(group_seed(12345, 1), 12345);
        // Distinct groups get distinct seeds.
        let seeds: std::collections::HashSet<u64> =
            (0..64).map(|g| group_seed(7, g)).collect();
        assert_eq!(seeds.len(), 64);
    }
}
