//! The replicated state machine: an append-only-list key-value store
//! (paper §6.1 — "write(key, value) permits a client to append value to
//! the append-only list associated with key, and read(key) returns the
//! values appended to this list, in order. We use append-only lists
//! because they are ideal for checking ... linearizability").
//!
//! Raft's layer separation is preserved (paper §7.1): the consensus layer
//! knows nothing about keys; the state machine knows nothing about terms
//! or indexes. The one LeaseGuard-motivated addition is
//! [`Store::set_limbo_region`], the paper's
//! `StateMachine::setLimboRegion(vector<Entry>)`: while a new leader
//! waits for a lease, the store can reject reads of keys touched by
//! limbo entries in O(1) — or in batch via the XLA admission engine
//! ([`crate::runtime`]).

pub mod store;

pub use store::Store;

/// A state-machine command carried in a log entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Command {
    /// No-op: written by a new leader at term start and for lease
    /// renewal (§5.1). Touches no keys.
    Noop,
    /// Planned-handover lease relinquishment (§5.1).
    EndLease,
    /// Append `value` to the list at `key`. `payload_bytes` models the
    /// client payload size (the real server transfers that many bytes;
    /// the store keeps the token only).
    Put { key: u32, value: u64, payload_bytes: u32 },
}

impl Command {
    /// The key this command touches, if any (limbo-region bookkeeping).
    pub fn key(&self) -> Option<u32> {
        match self {
            Command::Put { key, .. } => Some(*key),
            _ => None,
        }
    }
}
