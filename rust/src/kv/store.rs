//! Append-only-list store + limbo-region read gate (paper §6.1, §7.1).

use std::collections::{BTreeMap, BTreeSet};

use crate::raft::types::Values;

use super::Command;

/// Result of asking the store to execute a read while a limbo region is
/// installed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReadOutcome {
    /// The key is unaffected by the limbo region; values returned.
    Values(Values),
    /// §3.3: "key affected by limbo region" — caller must reject.
    LimboConflict,
}

/// The key-value state machine. Values are opaque u64 tokens (the real
/// server transfers full payloads on the wire but the store retains
/// tokens; see `kv::Command::Put`).
///
/// Value lists are `Arc`-shared: `read` is a pointer clone, not a
/// vector copy (the read path is the system's hottest — every lease
/// read ends here). Applies copy-on-write via `Arc::make_mut`, which
/// degenerates to a plain push while no read result holds the list.
#[derive(Debug, Clone)]
pub struct Store {
    /// BTreeMap (lint R2 + snapshots): iterated both to feed the
    /// admission engine and to serialize state-machine snapshots, so
    /// the order must be deterministic across processes and replays.
    data: BTreeMap<u32, Values>,
    applied: u64,
    /// Keys written by limbo-region entries (paper §7.1's
    /// `unordered_set<string>`); empty = no limbo restriction.
    /// BTreeSet (lint R2): [`Store::limbo_keys`] iterates it to feed
    /// the admission engine, so the hash order must be deterministic.
    limbo_keys: BTreeSet<u32>,
    /// Shared empty list returned for absent keys, so a miss is also
    /// just a pointer clone.
    empty: Values,
}

impl Default for Store {
    fn default() -> Self {
        Store {
            data: BTreeMap::new(),
            applied: 0,
            limbo_keys: BTreeSet::new(),
            empty: Values::default(),
        }
    }
}

impl Store {
    pub fn new() -> Self {
        Store::default()
    }

    /// Apply one committed command (called in log order).
    pub fn apply(&mut self, cmd: &Command) {
        self.applied += 1;
        if let Command::Put { key, value, .. } = cmd {
            let list = self.data.entry(*key).or_default();
            std::sync::Arc::make_mut(list).push(*value);
        }
    }

    /// Unrestricted read (no limbo check) — used when the leader has
    /// committed in its own term, and by the linearizability oracle.
    /// Allocation-free: clones an `Arc`, never the vector.
    pub fn read(&self, key: u32) -> Values {
        self.data.get(&key).cloned().unwrap_or_else(|| self.empty.clone())
    }

    /// Read through the limbo gate (§3.3): reject if `key` is affected.
    pub fn read_gated(&self, key: u32) -> ReadOutcome {
        if self.limbo_keys.contains(&key) {
            ReadOutcome::LimboConflict
        } else {
            ReadOutcome::Values(self.read(key))
        }
    }

    /// Install the limbo region: the paper's
    /// `StateMachine::setLimboRegion(vector<Entry>)` — pass the commands
    /// of entries in `(commitIndex, lastIndexAtElection]`. An empty slice
    /// clears the restriction (lease acquired).
    pub fn set_limbo_region<'a, I: IntoIterator<Item = &'a Command>>(&mut self, cmds: I) {
        self.limbo_keys.clear();
        for c in cmds {
            if let Some(k) = c.key() {
                self.limbo_keys.insert(k);
            }
        }
    }

    pub fn limbo_key_count(&self) -> usize {
        self.limbo_keys.len()
    }

    /// Limbo keys as a slice-able iterator (fed to the XLA admission
    /// engine as hashes).
    pub fn limbo_keys(&self) -> impl Iterator<Item = u32> + '_ {
        self.limbo_keys.iter().copied()
    }

    pub fn has_limbo_region(&self) -> bool {
        !self.limbo_keys.is_empty()
    }

    /// Number of commands applied (= lastApplied when driven by a node).
    pub fn applied(&self) -> u64 {
        self.applied
    }

    /// Reset to empty (crash recovery: volatile state machine is rebuilt
    /// by re-applying the log as entries re-commit).
    pub fn reset(&mut self) {
        self.data.clear();
        self.applied = 0;
        self.limbo_keys.clear();
    }

    pub fn key_count(&self) -> usize {
        self.data.len()
    }

    /// All key → value-list pairs in ascending key order — the
    /// deterministic walk behind state-machine snapshot encoding.
    pub fn entries_sorted(&self) -> impl Iterator<Item = (u32, &Values)> {
        self.data.iter().map(|(k, v)| (*k, v))
    }

    /// Replace the whole state machine from decoded snapshot contents
    /// (snapshot install / recovery). Volatile lease bookkeeping — the
    /// limbo region — is deliberately *not* part of a snapshot and is
    /// cleared: it is re-derived at the next election from the live log.
    pub fn install(&mut self, data: Vec<(u32, Values)>, applied: u64) {
        self.data = data.into_iter().collect();
        self.applied = applied;
        self.limbo_keys.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn put(key: u32, value: u64) -> Command {
        Command::Put { key, value, payload_bytes: 0 }
    }

    #[test]
    fn append_only_lists_in_order() {
        let mut s = Store::new();
        s.apply(&put(1, 10));
        s.apply(&put(1, 11));
        s.apply(&put(2, 20));
        assert_eq!(*s.read(1), vec![10, 11]);
        assert_eq!(*s.read(2), vec![20]);
        assert_eq!(*s.read(3), Vec::<u64>::new());
        assert_eq!(s.applied(), 3);
    }

    #[test]
    fn noop_touches_nothing() {
        let mut s = Store::new();
        s.apply(&Command::Noop);
        s.apply(&Command::EndLease);
        assert_eq!(s.key_count(), 0);
        assert_eq!(s.applied(), 2);
    }

    #[test]
    fn limbo_gate_blocks_only_affected_keys() {
        let mut s = Store::new();
        s.apply(&put(1, 10));
        s.apply(&put(2, 20));
        s.set_limbo_region([put(2, 99), Command::Noop].iter());
        assert_eq!(s.read_gated(1), ReadOutcome::Values(vec![10].into()));
        assert_eq!(s.read_gated(2), ReadOutcome::LimboConflict);
        // Unknown keys unaffected by limbo read fine.
        assert_eq!(s.read_gated(7), ReadOutcome::Values(vec![].into()));
        assert_eq!(s.limbo_key_count(), 1);
    }

    #[test]
    fn clearing_limbo_restores_reads() {
        let mut s = Store::new();
        s.set_limbo_region([put(5, 1)].iter());
        assert!(s.has_limbo_region());
        s.set_limbo_region([].iter());
        assert!(!s.has_limbo_region());
        assert_eq!(s.read_gated(5), ReadOutcome::Values(vec![].into()));
    }

    #[test]
    fn reads_are_shared_snapshots() {
        let mut s = Store::new();
        s.apply(&put(1, 10));
        let snap = s.read(1);
        s.apply(&put(1, 11)); // copy-on-write: the snapshot is unaffected
        assert_eq!(*snap, vec![10]);
        assert_eq!(*s.read(1), vec![10, 11]);
        // A read is a pointer clone of the stored list, not a copy.
        let cur = s.read(1);
        assert_eq!(std::sync::Arc::strong_count(&cur), 2);
    }

    #[test]
    fn snapshot_roundtrip_via_install() {
        let mut s = Store::new();
        s.apply(&put(3, 30));
        s.apply(&put(1, 10));
        s.apply(&put(1, 11));
        s.set_limbo_region([put(1, 0)].iter());
        let pairs: Vec<(u32, Values)> =
            s.entries_sorted().map(|(k, v)| (k, v.clone())).collect();
        assert_eq!(pairs.iter().map(|(k, _)| *k).collect::<Vec<_>>(), vec![1, 3]);
        let mut t = Store::new();
        t.install(pairs, s.applied());
        assert_eq!(*t.read(1), vec![10, 11]);
        assert_eq!(*t.read(3), vec![30]);
        assert_eq!(t.applied(), 3);
        assert!(!t.has_limbo_region(), "limbo is volatile, never installed");
    }

    #[test]
    fn reset_clears_everything() {
        let mut s = Store::new();
        s.apply(&put(1, 1));
        s.set_limbo_region([put(1, 2)].iter());
        s.reset();
        assert_eq!(s.applied(), 0);
        assert_eq!(*s.read(1), Vec::<u64>::new());
        assert!(!s.has_limbo_region());
    }
}
