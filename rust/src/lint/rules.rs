//! The rule visitors for `leaseguard lint`.
//!
//! Each rule walks the token stream produced by [`super::lexer`] and
//! emits [`Finding`]s. Rules are deliberately token-pattern matchers,
//! not type-checked analyses: they over-approximate (a flagged site
//! that is actually fine takes a `// lint:allow(<rule>): <reason>`
//! waiver, which is the point — every exception becomes documented).
//!
//! Rule catalog (see DESIGN.md for the prose version):
//! - **R1** wall-clock reads (`Instant::now` / `SystemTime::now`)
//!   outside `clock/real.rs`, `server/`, `client/`.
//! - **R2** iteration over `HashMap`/`HashSet` in protocol/sim paths
//!   (`sim/`, `raft/`, `shard/`, `lease/`, `cluster/`, `kv/`,
//!   `history.rs`, `linearizability.rs`) — unordered iteration feeding
//!   a history or report is exactly the nondeterminism class the
//!   fixed-seed replay tests exist to catch.
//! - **R3** ambient randomness (`thread_rng`, `rand::random`,
//!   `RandomState`) anywhere — all randomness flows from seeded
//!   `prob.rs` generators.
//! - **R4** panic paths (`unwrap()` / `expect()` / `panic!` /
//!   slice-indexing) in the untrusted-input decoders `server/wire.rs`
//!   and `snap/mod.rs` (snapshot bytes arrive over the wire too).
//! - **R5** routing discipline in `server/server.rs::main_loop`: every
//!   `router.handle(..)` must be preceded by a `persist_all` since the
//!   previous route (persist-before-route), and no direct
//!   `write_frame` outside that discipline.
//!
//! Plus two meta rules about waivers themselves: **W0** malformed
//! waiver (unknown rule name or missing reason) and **W1** waiver that
//! matched no finding (stale — delete it).
//!
//! All rules skip `#[cfg(test)]` regions: tests may use wall clocks,
//! unwraps and hash iteration freely.

use super::lexer::{lex, Kind, Tok};

/// One lint finding. `waived` carries the waiver reason when an inline
/// `// lint:allow(<rule>): <reason>` covers the site.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    /// Path relative to the lint root, `/`-separated.
    pub file: String,
    pub line: usize,
    /// What was matched, e.g. `Instant::now` or `pending.iter()`.
    pub what: String,
    /// Why the rule exists (one line, stable per rule).
    pub why: &'static str,
    pub waived: Option<String>,
}

const WHY_R1: &str = "wall-clock reads outside clock/real.rs, server/, client/ break simulated-time determinism";
const WHY_R2: &str = "HashMap/HashSet iteration order is nondeterministic; use BTreeMap/BTreeSet or collect-and-sort";
const WHY_R3: &str = "ambient RNG bypasses the seeded prob.rs plumbing; replays stop being reproducible";
const WHY_R4: &str = "every wire byte is untrusted; decode must return errors, never panic";
const WHY_R5: &str = "main_loop must persist (persist_all) before routing (router.handle): persist-before-route durability";
const WHY_W0: &str = "waiver is malformed: expected `lint:allow(<R1..R5>): <non-empty reason>`";
const WHY_W1: &str = "waiver matched no finding on its own or the next line; delete or move it";

/// A parsed `// lint:allow(<rule>): <reason>` comment.
struct Waiver {
    rule: String,
    line: usize,
    reason: String,
    used: bool,
}

/// Lint one file's source text. `relpath` is the path relative to the
/// lint root (e.g. `raft/node.rs`), `/`-separated — rules are scoped
/// by it. This is the unit-testable entry point; [`super::lint_tree`]
/// drives it over a directory.
pub fn lint_source(relpath: &str, src: &str) -> Vec<Finding> {
    let toks = strip_test_regions(&lex(src));
    let mut waivers = collect_waivers(&toks);
    // Code-only view: comments out of the way so adjacency patterns
    // like `.unwrap(` match across a trailing comment.
    let code: Vec<&Tok> = toks.iter().filter(|t| t.kind != Kind::Comment).collect();

    let mut findings = Vec::new();
    if !r1_exempt(relpath) {
        r1_wall_clock(relpath, &code, &mut findings);
    }
    if r2_in_scope(relpath) {
        r2_hash_iteration(relpath, &code, &mut findings);
    }
    r3_ambient_rng(relpath, &code, &mut findings);
    if relpath == "server/wire.rs" || relpath == "snap/mod.rs" {
        r4_panic_paths(relpath, &code, &mut findings);
    }
    if relpath == "server/server.rs" {
        r5_persist_before_route(relpath, &code, &mut findings);
    }

    // Apply waivers: a waiver covers findings of its rule on its own
    // line and the next line.
    for f in &mut findings {
        if let Some(w) = waivers
            .iter_mut()
            .find(|w| w.rule == f.rule && (w.line == f.line || w.line + 1 == f.line))
        {
            w.used = true;
            f.waived = Some(w.reason.clone());
        }
    }
    // Meta rules: malformed (already emitted by collect_waivers via
    // empty rule) and unused waivers.
    for w in &waivers {
        if w.rule == "W0" {
            findings.push(Finding {
                rule: "W0",
                file: relpath.to_string(),
                line: w.line,
                what: w.reason.clone(),
                why: WHY_W0,
                waived: None,
            });
        } else if !w.used {
            findings.push(Finding {
                rule: "W1",
                file: relpath.to_string(),
                line: w.line,
                what: format!("unused lint:allow({})", w.rule),
                why: WHY_W1,
                waived: None,
            });
        }
    }
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

const RULES: [&str; 5] = ["R1", "R2", "R3", "R4", "R5"];

/// Extract waivers from comment tokens. Malformed ones come back with
/// `rule == "W0"` and the problem description in `reason`.
fn collect_waivers(toks: &[Tok]) -> Vec<Waiver> {
    let mut out = Vec::new();
    for t in toks {
        if t.kind != Kind::Comment {
            continue;
        }
        // A waiver must BEGIN the comment (after `//`/`//!`/`/*`
        // decoration) — prose that merely mentions `lint:allow(...)`,
        // like this module's own docs, is not a waiver.
        let body = t.text.trim_end_matches("*/").trim_start_matches(['/', '*', '!']).trim();
        let Some(rest) = body.strip_prefix("lint:allow(") else { continue };
        let Some(close) = rest.find(')') else {
            out.push(Waiver {
                rule: "W0".into(),
                line: t.line,
                reason: "unclosed lint:allow(".into(),
                used: false,
            });
            continue;
        };
        let rule = rest[..close].trim().to_string();
        let after = &rest[close + 1..];
        let reason = after.strip_prefix(':').map(|r| r.trim().to_string());
        match (RULES.contains(&rule.as_str()), reason) {
            (true, Some(r)) if !r.is_empty() => {
                out.push(Waiver { rule, line: t.line, reason: r, used: false });
            }
            (false, _) => out.push(Waiver {
                rule: "W0".into(),
                line: t.line,
                reason: format!("unknown rule `{rule}` in lint:allow"),
                used: false,
            }),
            (true, _) => out.push(Waiver {
                rule: "W0".into(),
                line: t.line,
                reason: format!("lint:allow({rule}) has no reason"),
                used: false,
            }),
        }
    }
    out
}

/// Drop every `#[cfg(test)] <item>` region from the stream. The item
/// is brace-matched (`mod tests { … }`, `fn t() { … }`); attribute-only
/// items ending in `;` are skipped to the `;`.
fn strip_test_regions(toks: &[Tok]) -> Vec<Tok> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if is_cfg_test_attr(toks, i) {
            // Skip the attribute itself (7 tokens: # [ cfg ( test ) ]).
            i += 7;
            // Skip the attributed item: to matching `}` or to `;`,
            // whichever structure appears first.
            let mut depth = 0usize;
            while i < toks.len() {
                match toks[i].text.as_str() {
                    "{" => depth += 1,
                    "}" => {
                        depth = depth.saturating_sub(1);
                        if depth == 0 {
                            i += 1;
                            break;
                        }
                    }
                    ";" if depth == 0 => {
                        i += 1;
                        break;
                    }
                    _ => {}
                }
                i += 1;
            }
        } else {
            out.push(toks[i].clone());
            i += 1;
        }
    }
    out
}

fn is_cfg_test_attr(toks: &[Tok], i: usize) -> bool {
    let texts = ["#", "[", "cfg", "(", "test", ")", "]"];
    toks.len() >= i + texts.len() && texts.iter().enumerate().all(|(k, s)| toks[i + k].text == *s)
}

// ---------------------------------------------------------------- R1

fn r1_exempt(relpath: &str) -> bool {
    relpath == "clock/real.rs" || relpath.starts_with("server/") || relpath.starts_with("client/")
}

fn r1_wall_clock(relpath: &str, code: &[&Tok], findings: &mut Vec<Finding>) {
    for w in code.windows(3) {
        if w[1].text == "::"
            && w[2].text == "now"
            && (w[0].text == "Instant" || w[0].text == "SystemTime")
        {
            findings.push(Finding {
                rule: "R1",
                file: relpath.to_string(),
                line: w[2].line,
                what: format!("{}::now", w[0].text),
                why: WHY_R1,
                waived: None,
            });
        }
    }
}

// ---------------------------------------------------------------- R2

fn r2_in_scope(relpath: &str) -> bool {
    const DIRS: [&str; 6] = ["sim/", "raft/", "shard/", "lease/", "cluster/", "kv/"];
    DIRS.iter().any(|d| relpath.starts_with(d))
        || relpath == "history.rs"
        || relpath == "linearizability.rs"
}

const ITER_METHODS: [&str; 8] =
    ["iter", "iter_mut", "keys", "values", "values_mut", "drain", "into_iter", "retain"];

fn r2_hash_iteration(relpath: &str, code: &[&Tok], findings: &mut Vec<Finding>) {
    let hashy = collect_hash_typed_idents(code);
    // `name.iter()` and friends, where `name` is hash-typed.
    for i in 2..code.len() {
        if code[i].text == "("
            && code[i - 1].kind == Kind::Ident
            && ITER_METHODS.contains(&code[i - 1].text.as_str())
            && code[i - 2].text == "."
            && i >= 3
            && code[i - 3].kind == Kind::Ident
            && hashy.contains(&code[i - 3].text)
        {
            findings.push(Finding {
                rule: "R2",
                file: relpath.to_string(),
                line: code[i - 1].line,
                what: format!("{}.{}()", code[i - 3].text, code[i - 1].text),
                why: WHY_R2,
                waived: None,
            });
        }
    }
    // `for pat in <expr ending in hash-typed name> {` — the implicit
    // IntoIterator case with no method call to anchor on.
    let mut i = 0;
    while i < code.len() {
        if code[i].text == "for" {
            if let Some(f) = r2_check_for_loop(relpath, code, i, &hashy) {
                findings.push(f);
            }
        }
        i += 1;
    }
}

/// First pass: which identifiers in this file are (probably) HashMaps
/// or HashSets? Matches type annotations (`name: HashMap<..>` up to a
/// top-level `,;){=`) and initializers (`let [mut] name =
/// HashMap::new/with_capacity/from(..)`).
fn collect_hash_typed_idents(code: &[&Tok]) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for i in 0..code.len() {
        // `name : <type tokens containing HashMap/HashSet>`
        if code[i].kind == Kind::Ident && i + 2 < code.len() && code[i + 1].text == ":" {
            let mut angle = 0i32;
            for t in code.iter().skip(i + 2).take(40) {
                match t.text.as_str() {
                    "<" => angle += 1,
                    ">" => angle -= 1,
                    "," | ";" | ")" | "{" | "=" if angle <= 0 => break,
                    "HashMap" | "HashSet" => {
                        out.push(code[i].text.clone());
                        break;
                    }
                    _ => {}
                }
            }
        }
        // `let [mut] name = HashMap::…` / `HashSet::…`
        if code[i].text == "let" {
            let mut j = i + 1;
            if j < code.len() && code[j].text == "mut" {
                j += 1;
            }
            if j + 2 < code.len()
                && code[j].kind == Kind::Ident
                && code[j + 1].text == "="
                && (code[j + 2].text == "HashMap" || code[j + 2].text == "HashSet")
            {
                out.push(code[j].text.clone());
            }
        }
    }
    out.sort();
    out.dedup();
    out
}

/// `for pat in expr {` where `expr` is a plain place expression
/// (idents, `.`, `&`, `mut` — no calls) ending in a hash-typed name.
fn r2_check_for_loop(
    relpath: &str,
    code: &[&Tok],
    for_idx: usize,
    hashy: &[String],
) -> Option<Finding> {
    // Find `in` before the body `{`, at bracket depth 0 (destructuring
    // patterns like `for (k, v) in …` put the `in` after a `)`).
    let mut depth = 0i32;
    let mut in_idx = None;
    for (j, t) in code.iter().enumerate().skip(for_idx + 1).take(30) {
        match t.text.as_str() {
            "(" | "[" => depth += 1,
            ")" | "]" => depth -= 1,
            "in" if depth == 0 => {
                in_idx = Some(j);
                break;
            }
            "{" => break, // `impl Trait for Type {` — not a loop
            _ => {}
        }
    }
    let in_idx = in_idx?;
    // Expr tokens: from after `in` to the body `{` at depth 0.
    let mut expr: Vec<&Tok> = Vec::new();
    depth = 0;
    for t in code.iter().skip(in_idx + 1).take(30) {
        match t.text.as_str() {
            "(" | "[" => depth += 1,
            ")" | "]" => depth -= 1,
            "{" if depth == 0 => break,
            _ => {}
        }
        expr.push(t);
    }
    // Plain place expression only — anything with a call or index is
    // either covered by the method check or too complex to judge here.
    if expr.is_empty()
        || !expr.iter().all(|t| t.kind == Kind::Ident || t.text == "." || t.text == "&")
    {
        return None;
    }
    let last = expr.last()?;
    if last.kind == Kind::Ident && hashy.contains(&last.text) {
        return Some(Finding {
            rule: "R2",
            file: relpath.to_string(),
            line: code[for_idx].line,
            what: format!("for … in {}", last.text),
            why: WHY_R2,
            waived: None,
        });
    }
    None
}

// ---------------------------------------------------------------- R3

fn r3_ambient_rng(relpath: &str, code: &[&Tok], findings: &mut Vec<Finding>) {
    for (i, t) in code.iter().enumerate() {
        if t.kind != Kind::Ident {
            continue;
        }
        let hit = match t.text.as_str() {
            "thread_rng" | "RandomState" => true,
            "random" => {
                i >= 2 && code[i - 1].text == "::" && code[i - 2].text == "rand"
            }
            _ => false,
        };
        if hit {
            let what =
                if t.text == "random" { "rand::random".to_string() } else { t.text.clone() };
            findings.push(Finding {
                rule: "R3",
                file: relpath.to_string(),
                line: t.line,
                what,
                why: WHY_R3,
                waived: None,
            });
        }
    }
}

// ---------------------------------------------------------------- R4

fn is_keyword(s: &str) -> bool {
    matches!(
        s,
        "let" | "mut" | "in" | "if" | "else" | "match" | "return" | "as" | "ref" | "move"
    )
}

fn r4_panic_paths(relpath: &str, code: &[&Tok], findings: &mut Vec<Finding>) {
    for (i, t) in code.iter().enumerate() {
        let what: Option<String> = match t.text.as_str() {
            // `.unwrap(` / `.expect(` — exact ident, so `unwrap_or` is
            // fine (it lexes as one ident and never matches).
            "unwrap" | "expect"
                if i >= 1
                    && code[i - 1].text == "."
                    && code.get(i + 1).map(|n| n.text == "(").unwrap_or(false) =>
            {
                Some(format!(".{}()", t.text))
            }
            "panic" | "unreachable" | "assert" | "debug_assert"
                if code.get(i + 1).map(|n| n.text == "!").unwrap_or(false) =>
            {
                Some(format!("{}!", t.text))
            }
            // Slice indexing: `[` after an ident, `]`, or `)`. This
            // shape excludes `vec![`, attributes `#[`, array literals
            // `= [`, and keyword-led patterns like `let [b] = …`.
            "[" if i >= 1
                && ((code[i - 1].kind == Kind::Ident && !is_keyword(&code[i - 1].text))
                    || code[i - 1].text == "]"
                    || code[i - 1].text == ")") =>
            {
                Some(format!("{}[..] indexing", code[i - 1].text))
            }
            _ => None,
        };
        if let Some(what) = what {
            findings.push(Finding {
                rule: "R4",
                file: relpath.to_string(),
                line: t.line,
                what,
                why: WHY_R4,
                waived: None,
            });
        }
    }
}

// ---------------------------------------------------------------- R5

fn r5_persist_before_route(relpath: &str, code: &[&Tok], findings: &mut Vec<Finding>) {
    // Locate `fn main_loop` and brace-match its body.
    let mut start = None;
    for i in 0..code.len().saturating_sub(1) {
        if code[i].text == "fn" && code[i + 1].text == "main_loop" {
            start = Some(i);
            break;
        }
    }
    let Some(start) = start else {
        findings.push(Finding {
            rule: "R5",
            file: relpath.to_string(),
            line: 1,
            what: "fn main_loop not found (renamed? update the linter's R5 anchor)".to_string(),
            why: WHY_R5,
            waived: None,
        });
        return;
    };
    // Body = first `{` after the signature to its matching `}`.
    let mut i = start;
    while i < code.len() && code[i].text != "{" {
        i += 1;
    }
    let body_start = i;
    let mut depth = 0i32;
    let mut body_end = code.len();
    while i < code.len() {
        match code[i].text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    body_end = i;
                    break;
                }
            }
            _ => {}
        }
        i += 1;
    }
    let body = &code[body_start..body_end];

    // Discipline: every `router.handle(` needs a `persist_all` since
    // the previous route.
    let mut persisted = false;
    for (j, t) in body.iter().enumerate() {
        if t.text == "persist_all" {
            persisted = true;
        } else if t.text == "router"
            && body.get(j + 1).map(|n| n.text == ".").unwrap_or(false)
            && body.get(j + 2).map(|n| n.text == "handle").unwrap_or(false)
        {
            if !persisted {
                findings.push(Finding {
                    rule: "R5",
                    file: relpath.to_string(),
                    line: t.line,
                    what: "router.handle without persist_all since previous route".to_string(),
                    why: WHY_R5,
                    waived: None,
                });
            }
            persisted = false;
        } else if t.text == "write_frame" {
            findings.push(Finding {
                rule: "R5",
                file: relpath.to_string(),
                line: t.line,
                what: "direct write_frame inside main_loop".to_string(),
                why: WHY_R5,
                waived: None,
            });
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn unwaived(relpath: &str, src: &str) -> Vec<Finding> {
        lint_source(relpath, src).into_iter().filter(|f| f.waived.is_none()).collect()
    }

    #[test]
    fn r1_flags_wall_clock_outside_allowed_paths() {
        let src = "fn f() { let t = Instant::now(); }";
        let f = unwaived("raft/node.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "R1");
        assert_eq!(f[0].what, "Instant::now");
        // Allowed paths: clock/real.rs, server/, client/.
        assert!(unwaived("clock/real.rs", src).is_empty());
        assert!(unwaived("server/transport.rs", src).is_empty());
        assert!(unwaived("client/mod.rs", src).is_empty());
        // SystemTime too, including fully-qualified paths.
        let sys = "fn f() { let t = std::time::SystemTime::now(); }";
        assert_eq!(unwaived("sim/event_loop.rs", sys).len(), 1);
    }

    #[test]
    fn r1_waiver_suppresses_and_is_marked_used() {
        let src = "// lint:allow(R1): bench timing is real time by definition\nlet t0 = Instant::now();";
        let all = lint_source("figures/fig8.rs", src);
        assert_eq!(all.len(), 1, "{all:?}");
        assert!(all[0].waived.is_some());
        // No W1 (waiver used), no unwaived findings.
        assert!(all.iter().all(|f| f.rule != "W1"));
    }

    #[test]
    fn r2_flags_hash_iteration_by_annotation_and_initializer() {
        let src = "struct S { m: HashMap<u32, u64> }\n\
                   impl S { fn f(&self) { for k in self.m.keys() { use_(k); } } }";
        let f = unwaived("raft/node.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "R2");
        assert!(f[0].what.contains("m.keys"));

        let src2 = "fn f() { let mut seen = HashMap::new(); for (k, v) in seen { g(k, v); } }";
        let f2 = unwaived("history.rs", src2);
        assert_eq!(f2.len(), 1, "{f2:?}");
        assert!(f2[0].what.contains("for … in seen"));
    }

    #[test]
    fn r2_out_of_scope_and_non_hash_receivers_pass() {
        let src = "struct S { m: HashMap<u32, u64> }\n\
                   impl S { fn f(&self) { for k in self.m.keys() { use_(k); } } }";
        // Not a protocol path: no finding.
        assert!(unwaived("obs/registry.rs", src).is_empty());
        // BTreeMap iteration in scope: fine.
        let ordered = "struct S { m: BTreeMap<u32, u64> }\n\
                       impl S { fn f(&self) { for k in self.m.keys() { use_(k); } } }";
        assert!(unwaived("raft/node.rs", ordered).is_empty());
        // Non-iterating HashMap use (index/len/insert): fine.
        let touch = "struct S { m: HashMap<u32, u64> }\n\
                     impl S { fn f(&mut self) { self.m.insert(1, 2); let n = self.m.len(); } }";
        assert!(unwaived("raft/node.rs", touch).is_empty());
    }

    #[test]
    fn r3_flags_ambient_rng_everywhere() {
        assert_eq!(unwaived("obs/registry.rs", "let r = thread_rng();")[0].rule, "R3");
        assert_eq!(unwaived("report.rs", "let x: u8 = rand::random();")[0].rule, "R3");
        assert_eq!(unwaived("kv/store.rs", "let s = RandomState::new();")[0].rule, "R3");
        // `random` alone (not rand::random) is not flagged.
        assert!(unwaived("report.rs", "let x = self.random;").is_empty());
    }

    #[test]
    fn r4_flags_panic_paths_only_in_wire() {
        let src = "fn d(b: &[u8]) -> u8 { let x = b[0]; opt.unwrap(); r.expect(\"m\"); panic!(\"no\"); x }";
        let f = unwaived("server/wire.rs", src);
        let rules: Vec<&str> = f.iter().map(|x| x.rule).collect();
        assert_eq!(rules, vec!["R4", "R4", "R4", "R4"], "{f:?}");
        // The snapshot codec decodes wire bytes too: same scope.
        let f = unwaived("snap/mod.rs", src);
        assert_eq!(f.iter().filter(|x| x.rule == "R4").count(), 4, "{f:?}");
        // Same code in another file: not R4's business.
        assert!(unwaived("raft/log.rs", src).is_empty());
        // unwrap_or / vec![ / #[attr] are not flagged.
        let ok = "#[derive(Debug)] fn d() { let v = vec![1]; x.unwrap_or(0); }";
        assert!(unwaived("server/wire.rs", ok).is_empty(), "{:?}", unwaived("server/wire.rs", ok));
    }

    #[test]
    fn r5_checks_persist_before_route() {
        let good = "fn main_loop() { pending.push(op); persist_all(); router.handle(op); }";
        assert!(unwaived("server/server.rs", good).is_empty());
        let bad = "fn main_loop() { router.handle(op); }";
        let f = unwaived("server/server.rs", bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "R5");
        // Two routes, one persist: second route is flagged.
        let half = "fn main_loop() { persist_all(); router.handle(a); router.handle(b); }";
        assert_eq!(unwaived("server/server.rs", half).len(), 1);
        // Renamed main_loop trips the anchor guard.
        let renamed = "fn run_loop() { persist_all(); router.handle(a); }";
        let f2 = unwaived("server/server.rs", renamed);
        assert_eq!(f2.len(), 1);
        assert!(f2[0].what.contains("not found"));
        // write_frame inside main_loop is flagged (waiverable).
        let wf = "fn main_loop() { persist_all(); router.handle(a); write_frame(s, f); }";
        assert_eq!(unwaived("server/server.rs", wf).len(), 1);
    }

    #[test]
    fn cfg_test_regions_are_skipped() {
        let src = "fn prod() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn t() { let x = Instant::now(); thread_rng(); }\n\
                   }";
        assert!(unwaived("raft/node.rs", src).is_empty());
    }

    #[test]
    fn strings_and_comments_never_fire() {
        let src = "fn f() { let s = \"Instant::now() thread_rng()\"; }\n// Instant::now in prose\n";
        assert!(unwaived("raft/node.rs", src).is_empty());
    }

    #[test]
    fn w0_malformed_and_w1_unused_waivers() {
        // Missing reason.
        let f = lint_source("raft/node.rs", "// lint:allow(R1)\nlet t = Instant::now();");
        assert!(f.iter().any(|x| x.rule == "W0"), "{f:?}");
        // Unknown rule.
        let f2 = lint_source("raft/node.rs", "// lint:allow(R9): nope\n");
        assert!(f2.iter().any(|x| x.rule == "W0"));
        // Unused (nothing to waive on the next line).
        let f3 = lint_source("raft/node.rs", "// lint:allow(R1): stale\nlet x = 1;");
        assert!(f3.iter().any(|x| x.rule == "W1"), "{f3:?}");
    }

    #[test]
    fn waiver_on_same_line_works() {
        let src = "let t = Instant::now(); // lint:allow(R1): trailing waiver\n";
        let f = lint_source("raft/node.rs", src);
        assert_eq!(f.len(), 1);
        assert!(f[0].waived.is_some());
    }
}
