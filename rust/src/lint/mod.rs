//! Self-hosted determinism & protocol-discipline linter.
//!
//! `leaseguard lint [--root DIR] [--json]` walks a Rust source tree
//! (default: the crate's own `rust/src/`) with a dependency-free
//! hand-rolled lexer and enforces the repo's determinism and protocol
//! invariants as machine-checked rules R1–R5 (see [`rules`] for the
//! catalog). Exceptions are documented inline with
//! `// lint:allow(<rule>): <reason>` waivers; the waiver itself is
//! checked (W0 malformed, W1 unused).
//!
//! Self-hosting: a tier-1 test (`rust/tests/lint_suite.rs`) runs
//! [`lint_tree`] over `rust/src/` and fails on any unwaived finding,
//! so the invariants hold on every commit without needing clippy or
//! network access.

pub mod lexer;
pub mod rules;

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

pub use rules::{lint_source, Finding};

/// Aggregate result of linting a tree.
#[derive(Debug)]
pub struct Report {
    /// All findings, waived and not, ordered by (file, line, rule).
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl Report {
    /// Findings not covered by a waiver — the ones that fail the run.
    pub fn unwaived(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.waived.is_none())
    }

    pub fn unwaived_count(&self) -> usize {
        self.unwaived().count()
    }

    /// Human-readable report: unwaived findings in full, waived ones as
    /// a one-line audit trail, then a summary.
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        for f in self.unwaived() {
            let _ = writeln!(s, "{}: {}:{}: {}", f.rule, f.file, f.line, f.what);
            let _ = writeln!(s, "    note: {}", f.why);
        }
        let waived: Vec<&Finding> = self.findings.iter().filter(|f| f.waived.is_some()).collect();
        if !waived.is_empty() {
            let _ = writeln!(s, "waivers in effect ({}):", waived.len());
            for f in &waived {
                let _ = writeln!(
                    s,
                    "    {} {}:{} {} — {}",
                    f.rule,
                    f.file,
                    f.line,
                    f.what,
                    f.waived.as_deref().unwrap_or("")
                );
            }
        }
        let _ = writeln!(
            s,
            "lint: {} file(s), {} finding(s), {} waived, {} unwaived",
            self.files_scanned,
            self.findings.len(),
            waived.len(),
            self.unwaived_count()
        );
        s
    }

    /// Machine-readable report (hand-rolled JSON; no serde in-tree).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"files_scanned\": ");
        let _ = write!(s, "{}", self.files_scanned);
        let _ = write!(s, ",\n  \"unwaived\": {}", self.unwaived_count());
        s.push_str(",\n  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "\n    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"what\": \"{}\", \"why\": \"{}\", \"waived\": ",
                json_escape(f.rule),
                json_escape(&f.file),
                f.line,
                json_escape(&f.what),
                json_escape(f.why)
            );
            match &f.waived {
                Some(r) => {
                    let _ = write!(s, "\"{}\"}}", json_escape(r));
                }
                None => s.push_str("null}"),
            }
        }
        s.push_str("\n  ]\n}\n");
        s
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Lint every `.rs` file under `root`. Files are visited in sorted
/// relative-path order so the report (and its JSON) is deterministic.
pub fn lint_tree(root: &Path) -> std::io::Result<Report> {
    let mut files = Vec::new();
    collect_rs_files(root, root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    let files_scanned = files.len();
    for rel in files {
        let src = std::fs::read_to_string(root.join(&rel))?;
        let rel_str = rel
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        findings.extend(lint_source(&rel_str, &src));
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(Report { findings, files_scanned })
}

fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(root, &path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            if let Ok(rel) = path.strip_prefix(root) {
                out.push(rel.to_path_buf());
            }
        }
    }
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn mini_report() -> Report {
        let findings = lint_source(
            "raft/node.rs",
            "// lint:allow(R1): legit reason\nlet a = Instant::now();\nlet b = Instant::now();\n",
        );
        Report { findings, files_scanned: 1 }
    }

    #[test]
    fn report_counts_waived_vs_unwaived() {
        let r = mini_report();
        assert_eq!(r.findings.len(), 2);
        assert_eq!(r.unwaived_count(), 1);
        let text = r.render_text();
        assert!(text.contains("R1: raft/node.rs:3"), "{text}");
        assert!(text.contains("waivers in effect (1)"), "{text}");
    }

    #[test]
    fn json_shape_and_escaping() {
        let r = mini_report();
        let j = r.to_json();
        assert!(j.contains("\"unwaived\": 1"), "{j}");
        assert!(j.contains("\"rule\": \"R1\""));
        assert!(j.contains("\"waived\": null"));
        assert!(j.contains("\"waived\": \"legit reason\""));
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
