//! Hand-rolled Rust lexer for the self-hosted linter (no `syn` — the
//! container is offline). Produces a flat token stream with line
//! numbers: enough structure for the per-rule visitors in
//! [`super::rules`], which match on token *sequences* rather than a
//! real AST.
//!
//! Handled: line/doc comments, nested block comments, string / raw
//! string / byte-string / char literals (including the `'a'`-char vs
//! `'a`-lifetime ambiguity), numbers (without eating `..` ranges),
//! identifiers, and multi-char operators that matter for matching
//! (`::`). Everything else is a single-char punct. Comments are kept as
//! tokens because waivers (`// lint:allow(...)`) live in them.

/// One lexed token.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tok {
    pub kind: Kind,
    /// Source text. For comments, the full text including delimiters.
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    /// `'a`, `'static`, `'_`
    Lifetime,
    /// String / char / byte / numeric literal.
    Literal,
    /// `//…` or `/*…*/`, text preserved for waiver parsing.
    Comment,
    /// `::` or a single punctuation character.
    Punct,
}

/// Lex `src` into tokens. Never fails: unterminated constructs are
/// closed at end-of-input (the linter must degrade gracefully on any
/// input — it runs on fixture files that are deliberately broken).
pub fn lex(src: &str) -> Vec<Tok> {
    let b: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut i = 0;
    let mut line = 1;
    while i < b.len() {
        let c = b[i];
        let start_line = line;
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => i += 1,
            '/' if i + 1 < b.len() && b[i + 1] == '/' => {
                let s = i;
                while i < b.len() && b[i] != '\n' {
                    i += 1;
                }
                toks.push(tok(Kind::Comment, &b[s..i], start_line));
            }
            '/' if i + 1 < b.len() && b[i + 1] == '*' => {
                let s = i;
                let mut depth = 1;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == '\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == '*' && i + 1 < b.len() && b[i + 1] == '/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                toks.push(tok(Kind::Comment, &b[s..i], start_line));
            }
            '"' => {
                let (end, nl) = scan_string(&b, i + 1, 0);
                toks.push(tok(Kind::Literal, &b[i..end], start_line));
                line += nl;
                i = end;
            }
            'r' | 'b' if starts_raw_or_byte_string(&b, i) => {
                let s = i;
                // Skip the prefix letters (`r`, `b`, `br`, `rb`).
                while i < b.len() && (b[i] == 'r' || b[i] == 'b') {
                    i += 1;
                }
                let mut hashes = 0;
                while i < b.len() && b[i] == '#' {
                    hashes += 1;
                    i += 1;
                }
                // Now at the opening quote.
                let raw = b[s..i].contains(&'r');
                let (end, nl) = if raw {
                    scan_raw_string(&b, i + 1, hashes)
                } else {
                    scan_string(&b, i + 1, 0)
                };
                toks.push(tok(Kind::Literal, &b[s..end], start_line));
                line += nl;
                i = end;
            }
            'b' if i + 1 < b.len() && b[i + 1] == '\'' => {
                let end = scan_char(&b, i + 2);
                toks.push(tok(Kind::Literal, &b[i..end], start_line));
                i = end;
            }
            '\'' => {
                // Char literal or lifetime? A lifetime is always
                // ident-like (`'a`, `'static`, `'_`) and is NOT followed
                // by a closing quote right after its first character.
                let next = b.get(i + 1).copied();
                let after = b.get(i + 2).copied();
                let ident_start =
                    next.map(|c| c.is_alphabetic() || c == '_').unwrap_or(false);
                if ident_start && after != Some('\'') {
                    // Lifetime: consume ident chars.
                    let s = i;
                    i += 1;
                    while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                        i += 1;
                    }
                    toks.push(tok(Kind::Lifetime, &b[s..i], start_line));
                } else {
                    let end = scan_char(&b, i + 1);
                    toks.push(tok(Kind::Literal, &b[i..end], start_line));
                    i = end;
                }
            }
            c if c.is_alphabetic() || c == '_' => {
                let s = i;
                while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                toks.push(tok(Kind::Ident, &b[s..i], start_line));
            }
            c if c.is_ascii_digit() => {
                let s = i;
                i += 1;
                while i < b.len() {
                    let d = b[i];
                    if d.is_alphanumeric() || d == '_' {
                        i += 1;
                    } else if d == '.' {
                        // Don't eat `..` ranges: `0..n` is three tokens.
                        if b.get(i + 1) == Some(&'.') {
                            break;
                        }
                        i += 1;
                    } else if (d == '+' || d == '-')
                        && matches!(b.get(i - 1), Some('e') | Some('E'))
                    {
                        // Exponent sign: `1e-9`.
                        i += 1;
                    } else {
                        break;
                    }
                }
                toks.push(tok(Kind::Literal, &b[s..i], start_line));
            }
            ':' if b.get(i + 1) == Some(&':') => {
                toks.push(tok(Kind::Punct, &b[i..i + 2], start_line));
                i += 2;
            }
            _ => {
                toks.push(tok(Kind::Punct, &b[i..i + 1], start_line));
                i += 1;
            }
        }
    }
    toks
}

fn tok(kind: Kind, chars: &[char], line: usize) -> Tok {
    Tok { kind, text: chars.iter().collect(), line }
}

/// Is `b[i]` the start of `r"`, `r#"`, `b"`, `br"`, `br#"` …?
fn starts_raw_or_byte_string(b: &[char], i: usize) -> bool {
    let mut j = i;
    let mut saw_r = false;
    let mut saw_b = false;
    while j < b.len() {
        match b[j] {
            'r' if !saw_r => {
                saw_r = true;
                j += 1;
            }
            'b' if !saw_b => {
                saw_b = true;
                j += 1;
            }
            _ => break,
        }
    }
    if j >= b.len() || j == i {
        return false;
    }
    while j < b.len() && b[j] == '#' {
        if !saw_r {
            return false; // `b#` is not a string prefix
        }
        j += 1;
    }
    j < b.len() && b[j] == '"' && (saw_r || b[j - 1] == '"' || j == i + 1)
}

/// Scan a (cooked) string body starting after the opening quote.
/// Returns (index past closing quote, newlines crossed).
fn scan_string(b: &[char], mut i: usize, _hashes: usize) -> (usize, usize) {
    let mut nl = 0;
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '\n' => {
                nl += 1;
                i += 1;
            }
            '"' => return (i + 1, nl),
            _ => i += 1,
        }
    }
    (i, nl)
}

/// Scan a raw string body; closes on `"` followed by `hashes` `#`s.
fn scan_raw_string(b: &[char], mut i: usize, hashes: usize) -> (usize, usize) {
    let mut nl = 0;
    while i < b.len() {
        if b[i] == '\n' {
            nl += 1;
            i += 1;
        } else if b[i] == '"' && b[i + 1..].iter().take(hashes).filter(|&&c| c == '#').count() == hashes {
            return (i + 1 + hashes, nl);
        } else {
            i += 1;
        }
    }
    (i, nl)
}

/// Scan a char literal body starting after the opening quote; returns
/// the index past the closing quote.
fn scan_char(b: &[char], mut i: usize) -> usize {
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '\'' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn basic_stream() {
        let toks = lex("let x: u32 = 7;");
        let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["let", "x", ":", "u32", "=", "7", ";"]);
    }

    #[test]
    fn double_colon_is_one_token() {
        let toks = lex("Instant::now()");
        let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["Instant", "::", "now", "(", ")"]);
    }

    #[test]
    fn comments_preserved_with_lines() {
        let toks = lex("a\n// lint:allow(R1): why\nb /* block\nstill */ c");
        let comments: Vec<(&str, usize)> = toks
            .iter()
            .filter(|t| t.kind == Kind::Comment)
            .map(|t| (t.text.as_str(), t.line))
            .collect();
        assert_eq!(comments[0], ("// lint:allow(R1): why", 2));
        assert!(comments[1].0.contains("block"));
        assert_eq!(comments[1].1, 3);
        // Line numbers keep counting after multi-line block comments.
        let c = toks.iter().find(|t| t.text == "c").unwrap();
        assert_eq!(c.line, 4);
    }

    #[test]
    fn nested_block_comments() {
        let toks = lex("/* outer /* inner */ still-comment */ x");
        assert_eq!(toks.len(), 2);
        assert_eq!(toks[1].text, "x");
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = lex("impl<'a> Dec<'a> { split(','); let c = 'x'; let e = '\\n'; }");
        let lifetimes: Vec<&str> =
            toks.iter().filter(|t| t.kind == Kind::Lifetime).map(|t| t.text.as_str()).collect();
        assert_eq!(lifetimes, vec!["'a", "'a"]);
        let lits: Vec<&str> =
            toks.iter().filter(|t| t.kind == Kind::Literal).map(|t| t.text.as_str()).collect();
        assert_eq!(lits, vec!["','", "'x'", "'\\n'"]);
    }

    #[test]
    fn strings_do_not_leak_tokens() {
        // `HashMap` inside a string or comment must not look like code.
        assert_eq!(idents(r#"let s = "HashMap::iter() // not code";"#), vec!["let", "s"]);
        let raw = lex("let s = r#\"no \"escape\" here\"#; y");
        assert_eq!(raw.last().unwrap().text, "y");
    }

    #[test]
    fn numbers_do_not_eat_ranges() {
        let texts: Vec<String> = lex("for i in 0..10 {}").into_iter().map(|t| t.text).collect();
        assert!(texts.contains(&"0".to_string()));
        assert!(texts.contains(&"10".to_string()));
        // Floats and exponents still lex as one literal.
        let f = lex("1e-9 0.25");
        assert_eq!(f[0].text, "1e-9");
        assert_eq!(f[1].text, "0.25");
    }

    #[test]
    fn unterminated_inputs_do_not_hang() {
        // Fixture files may be arbitrarily broken; the lexer must
        // terminate on all of them.
        for src in ["\"abc", "/* never closed", "'x", "r#\"open", "b'"] {
            let _ = lex(src);
        }
    }
}
