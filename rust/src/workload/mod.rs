//! Open-loop workload generation (paper §6.3-§6.6, §7).
//!
//! "These workload generators are *open loop*: they start requests at a
//! fixed rate regardless of the response latency" (§6.1, citing
//! Schroeder et al. [45] on why closed-loop benchmarks lie). The
//! generator yields a deterministic schedule of operations from the
//! seeded PRNG: arrival time (Poisson or fixed-rate), kind (read/write
//! mix), key (uniform or Zipf), and a globally unique value per write.

use crate::config::Params;
use crate::prob::{Rng, Zipf};
use crate::Micros;

/// One scheduled client operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpSpec {
    pub at: Micros,
    pub key: u32,
    /// None = read; Some(value) = append this unique value.
    pub write_value: Option<u64>,
    pub payload_bytes: u32,
}

impl OpSpec {
    pub fn is_read(&self) -> bool {
        self.write_value.is_none()
    }
}

/// Deterministic open-loop generator.
#[derive(Debug)]
pub struct Workload {
    rng: Rng,
    zipf: Option<Zipf>,
    num_keys: usize,
    interarrival_us: f64,
    poisson: bool,
    write_fraction: f64,
    payload_bytes: u32,
    next_at: Micros,
    next_value: u64,
}

impl Workload {
    pub fn from_params(p: &Params, rng: &mut Rng) -> Self {
        Workload {
            rng: rng.fork(),
            zipf: if p.zipf_a > 0.0 { Some(Zipf::new(p.num_keys, p.zipf_a)) } else { None },
            num_keys: p.num_keys,
            interarrival_us: p.interarrival_us,
            poisson: p.poisson_arrivals,
            write_fraction: p.write_fraction,
            payload_bytes: p.value_bytes as u32,
            next_at: 0,
            next_value: 1,
        }
    }

    /// Next operation in the schedule. Values are unique across the run
    /// (they double as operation identity for the checker).
    pub fn next(&mut self) -> OpSpec {
        let gap = if self.poisson {
            self.rng.exponential(self.interarrival_us)
        } else {
            self.interarrival_us
        };
        self.next_at += gap.max(1.0) as Micros;
        let key = match &self.zipf {
            Some(z) => z.sample(&mut self.rng) as u32,
            None => self.rng.below(self.num_keys as u64) as u32,
        };
        let write_value = if self.rng.chance(self.write_fraction) {
            let v = self.next_value;
            self.next_value += 1;
            Some(v)
        } else {
            None
        };
        OpSpec {
            at: self.next_at,
            key,
            write_value,
            payload_bytes: if write_value.is_some() { self.payload_bytes } else { 0 },
        }
    }

    /// All operations up to `duration_us`.
    pub fn schedule(&mut self, duration_us: Micros) -> Vec<OpSpec> {
        let mut v = Vec::new();
        loop {
            let op = self.next();
            if op.at > duration_us {
                return v;
            }
            v.push(op);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> Params {
        let mut p = Params::default();
        p.interarrival_us = 300.0;
        p.write_fraction = 1.0 / 3.0;
        p.num_keys = 1000;
        p
    }

    #[test]
    fn deterministic_schedule() {
        let p = params();
        let a = Workload::from_params(&p, &mut Rng::new(5)).schedule(1_000_000);
        let b = Workload::from_params(&p, &mut Rng::new(5)).schedule(1_000_000);
        assert_eq!(a, b);
        assert!(!a.is_empty());
    }

    #[test]
    fn rate_and_mix_match_params() {
        let p = params();
        let ops = Workload::from_params(&p, &mut Rng::new(7)).schedule(3_000_000);
        // ~10k ops in 3s at 300µs interarrival.
        let n = ops.len() as f64;
        assert!((n - 10_000.0).abs() < 500.0, "{n} ops");
        let writes = ops.iter().filter(|o| !o.is_read()).count() as f64;
        assert!((writes / n - 1.0 / 3.0).abs() < 0.02);
        assert!(ops.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(ops.iter().all(|o| (o.key as usize) < 1000));
    }

    #[test]
    fn write_values_unique() {
        let p = params();
        let ops = Workload::from_params(&p, &mut Rng::new(9)).schedule(2_000_000);
        let mut vals: Vec<u64> = ops.iter().filter_map(|o| o.write_value).collect();
        let n = vals.len();
        vals.sort_unstable();
        vals.dedup();
        assert_eq!(vals.len(), n);
    }

    #[test]
    fn fixed_rate_mode_exact() {
        let mut p = params();
        p.poisson_arrivals = false;
        let ops = Workload::from_params(&p, &mut Rng::new(3)).schedule(30_000);
        assert_eq!(ops.len(), 100);
        assert_eq!(ops[0].at, 300);
        assert_eq!(ops[99].at, 30_000);
    }

    #[test]
    fn zipf_skews_keys() {
        let mut p = params();
        p.zipf_a = 2.0;
        let ops = Workload::from_params(&p, &mut Rng::new(11)).schedule(10_000_000);
        let hot = ops.iter().filter(|o| o.key == 0).count() as f64 / ops.len() as f64;
        assert!((hot - 0.61).abs() < 0.03, "hottest key mass {hot}");
    }

    #[test]
    fn payload_only_on_writes() {
        let p = params();
        let ops = Workload::from_params(&p, &mut Rng::new(13)).schedule(1_000_000);
        for o in &ops {
            if o.is_read() {
                assert_eq!(o.payload_bytes, 0);
            } else {
                assert_eq!(o.payload_bytes, 1024);
            }
        }
    }
}
