//! Figure 9 (Q2, real cluster): availability around a leader crash on
//! the TCP testbed, including the Ongaro-lease comparator (§7.3).
//!
//! Paper: 30 ops/ms open loop, 1/3 writes of 1 KiB, Zipf a=0.5 over
//! 1000 keys, Δ = 1 s = 2·ET (Ongaro: ET = 1 s since it has no separate
//! Δ). Crash the leader 500 ms into the measured window. Headline: with
//! full LeaseGuard the new leader serves ~99% of reads while waiting
//! for the old lease to expire (paper: 9,930 of 10,000).
//!
//! Our single-host testbed sustains a lower offered load than the
//! paper's EC2 fleet; the default here is 2 ops/ms (scale with
//! `--param interarrival_us=...`) — the *shape* is the deliverable.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::time::Duration;

use anyhow::Result;

use crate::client::run_open_loop;
use crate::config::{ConsistencyMode, Params};
use crate::linearizability;
use crate::report::{timeline_chart, Table};
use crate::runtime::EngineHandle;

use super::realcluster::RealCluster;
use super::Scale;

pub fn params_for(base: &Params, mode: ConsistencyMode, scale: Scale) -> Params {
    let mut p = base.clone();
    p.consistency = mode;
    p.interarrival_us = (500.0 / scale.0).max(100.0);
    p.write_fraction = 1.0 / 3.0;
    p.num_keys = 1000;
    p.zipf_a = 0.5;
    p.value_bytes = 1024;
    p.election_timeout_us = 500_000;
    p.election_jitter_us = 150_000;
    p.lease_duration_us = 1_000_000;
    p.heartbeat_us = 75_000;
    p.duration_us = scale.dur(3_000_000).max(2_500_000);
    p.bucket_us = 100_000;
    p.crash_leader_at_us = 500_000;
    p
}

pub fn run(base: &Params, scale: Scale, out_dir: &str) -> Result<String> {
    let engine = if base.use_xla_admission {
        EngineHandle::spawn(std::path::Path::new(&base.artifacts_dir)).ok()
    } else {
        None
    };
    let mut out = String::new();
    let mut table = Table::new([
        "mode",
        "reads_ok[1.0,1.5s)",
        "reads_att[1.0,1.5s)",
        "writes_ok[1.5,2.0s)",
        "limbo",
        "linearizable",
    ]);
    let mut csv = Table::new(["mode", "bucket_ms", "reads_per_s", "writes_per_s"]);
    for mode in ConsistencyMode::ALL {
        let p = params_for(base, mode, scale);
        let mut cluster = RealCluster::spawn(&p, Duration::ZERO, engine.clone())?;
        let leader = cluster
            .wait_for_leader(Duration::from_secs(10))
            .ok_or_else(|| anyhow::anyhow!("no leader"))?;
        // Run the client on its own thread; crash the leader mid-run.
        let addrs = cluster.addrs.clone();
        let applies = cluster.applies.clone();
        let pc = p.clone();
        let client = std::thread::spawn(move || run_open_loop(&addrs, &pc, Some(applies)));
        std::thread::sleep(Duration::from_micros(p.crash_leader_at_us as u64));
        cluster.kill(leader);
        // Record the new leader's limbo length when it appears.
        let mut limbo = 0u64;
        for _ in 0..100 {
            for h in cluster.handles.iter().flatten() {
                if h.status.group(0).is_leader.load(std::sync::atomic::Ordering::Relaxed) {
                    limbo = limbo.max(h.status.group(0).limbo_len.get().max(0) as u64);
                }
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        let rep = client.join().expect("client thread")?;
        cluster.shutdown();
        let viol = linearizability::check(&rep.history);
        let r_wait = rep.series.window_totals(true, 1_000_000, 1_500_000);
        let w_after = rep.series.window_totals(false, 1_500_000, 2_000_000);
        table.row([
            mode.to_string(),
            r_wait.ok.to_string(),
            (r_wait.ok + r_wait.failed).to_string(),
            w_after.ok.to_string(),
            limbo.to_string(),
            if viol.is_empty() {
                "yes".into()
            } else {
                format!("VIOLATIONS({})", viol.len())
            },
        ]);
        let reads = rep.series.ok_rate_per_sec(true);
        let writes = rep.series.ok_rate_per_sec(false);
        for (i, (r, w)) in reads.iter().zip(writes.iter()).enumerate() {
            csv.row([
                mode.to_string(),
                ((i as i64) * p.bucket_us / 1000).to_string(),
                format!("{r:.0}"),
                format!("{w:.0}"),
            ]);
        }
        out.push_str(&format!(
            "\n--- {mode} (real cluster; crash at 500ms) ---\n{}",
            timeline_chart(&["reads/s", "writes/s"], &[reads, writes], p.bucket_us as f64 / 1000.0)
        ));
    }
    let _ = csv.write_csv(std::path::Path::new(out_dir).join("fig9.csv").as_path());
    Ok(format!(
        "Figure 9 — availability around a leader crash (real TCP cluster)\n{}{}",
        table.render(),
        out
    ))
}
