//! Figure 8 (Q3): effect of workload skewness on read throughput on the
//! new leader while it awaits a lease (§6.6).
//!
//! The paper stress-tests the limbo mechanism by placing exactly 100
//! entries in the limbo region and sweeping Zipf skewness a ∈ [0, 2]
//! over 1000 keys. We reproduce that construction at the node level
//! (deterministically forcing the post-election log), then stream
//! Zipf-distributed reads through the admission path and report the
//! admitted fraction — through the scalar path AND the XLA engine
//! (`use_xla_admission`), which must agree (the ablation doubles as an
//! end-to-end check of the Layer-1/2 artifact).

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use anyhow::Result;

use crate::clock::TimeInterval;
use crate::config::{ConsistencyMode, Params};
use crate::kv::Command;
use crate::prob::{Rng, Zipf};
use crate::raft::log::Entry;
use crate::raft::{Message, Node, NodeConfig, Output, TimerKind};
use crate::report::Table;
use crate::runtime::{scalar_admission, AdmissionEngine};

use super::Scale;

/// Build a term-2 leader whose limbo region holds `limbo_entries`
/// Zipf-distributed keys, with the inherited lease still valid.
pub fn limbo_leader(params: &Params, limbo_entries: usize, zipf_a: f64, seed: u64) -> Node {
    let mut cfg = NodeConfig::from_params(1, params);
    cfg.mode = ConsistencyMode::LeaseGuard;
    let t = |us| TimeInterval::exact(us);
    let (mut n, _) = Node::new(cfg, seed, t(0));
    let zipf = Zipf::new(params.num_keys, zipf_a);
    let mut rng = Rng::new(seed ^ 0xF16_8);
    // One committed term-1 entry at t=400ms (the lease basis), then
    // `limbo_entries` uncommitted term-1 writes at t=500ms.
    let mut entries = vec![Entry {
        term: 1,
        command: Command::Put { key: 0, value: 1, payload_bytes: 0 },
        written_at: t(400_000),
    }];
    for i in 0..limbo_entries {
        entries.push(Entry {
            term: 1,
            command: Command::Put {
                key: zipf.sample(&mut rng) as u32,
                value: 100 + i as u64,
                payload_bytes: 0,
            },
            written_at: t(500_000),
        });
    }
    n.on_message(
        t(500_100),
        Message::AppendEntries {
            term: 1,
            leader: 0,
            prev_index: 0,
            prev_term: 0,
            entries: entries.into(),
            leader_commit: 1,
            seq: 1,
        },
    );
    // Win term 2 at 1.0s (old lease valid until 1.5s).
    n.on_timer(t(1_100_000), TimerKind::Election);
    n.on_message(t(1_100_000), Message::VoteReply { term: 2, voter: 2, granted: true });
    assert!(n.is_leader());
    n
}

pub fn run(base: &Params, scale: Scale, out_dir: &str) -> Result<String> {
    let engine = AdmissionEngine::load(std::path::Path::new(&base.artifacts_dir)).ok();
    let engine_note = if engine.is_some() {
        "XLA admission engine: loaded"
    } else {
        "XLA admission engine: NOT FOUND (run `make artifacts`); scalar only"
    };
    let sweep = [0.0f64, 0.5, 1.0, 1.5, 2.0];
    let reads = (20_000.0 * scale.0).max(2000.0) as usize;
    let limbo_entries = 100;
    let mut table = Table::new([
        "zipf_a",
        "limbo_keys",
        "admitted_scalar_%",
        "admitted_xla_%",
        "agree",
        "admitted_after_lease_%",
    ]);
    for &a in &sweep {
        let mut node = limbo_leader(base, limbo_entries, a, 7);
        let zipf = Zipf::new(base.num_keys, a);
        let mut rng = Rng::new(99);
        let keys: Vec<u32> = (0..reads).map(|_| zipf.sample(&mut rng) as u32).collect();
        let now = TimeInterval::exact(1_200_000); // inherited lease valid
        let ops: Vec<(u64, u32)> = keys.iter().enumerate().map(|(i, &k)| (i as u64, k)).collect();

        let count_ok = |outs: &[Output]| {
            outs.iter()
                .filter(|o| {
                    matches!(o, Output::Reply { result, .. } if result.is_ok())
                })
                .count()
        };
        // Scalar path.
        let limbo_keys = node.store().limbo_key_count();
        let outs = node.client_read_batch(now, &ops, |inp| scalar_admission(inp));
        let ok_scalar = count_ok(&outs);
        // XLA engine path (fresh identical node so stats don't mix).
        let (ok_xla, agree) = match &engine {
            Some(e) => {
                let mut node2 = limbo_leader(base, limbo_entries, a, 7);
                let outs2 = node2.client_read_batch(now, &ops, |inp| e.admit(inp).unwrap());
                let ok2 = count_ok(&outs2);
                (ok2, ok2 == ok_scalar)
            }
            None => (ok_scalar, true),
        };
        // After the lease resolves (own-term commit), everything passes.
        let late = TimeInterval::exact(1_600_000);
        node.on_timer(late, TimerKind::LeaseCheck); // gate open
        let seq_ack = Message::AppendReply {
            term: node.term(),
            from: 2,
            success: true,
            match_index: node.log().last_index(),
            seq: u64::MAX / 2,
        };
        node.on_message(late, seq_ack);
        node.on_timer(TimeInterval::exact(1_600_100), TimerKind::LeaseCheck);
        let outs3 = node.client_read_batch(
            TimeInterval::exact(1_600_200),
            &ops,
            |inp| scalar_admission(inp),
        );
        let ok_after = count_ok(&outs3);
        let pct = |x: usize| 100.0 * x as f64 / reads as f64;
        table.row([
            format!("{a:.1}"),
            limbo_keys.to_string(),
            format!("{:.1}", pct(ok_scalar)),
            format!("{:.1}", pct(ok_xla)),
            if agree { "yes" } else { "NO" }.to_string(),
            format!("{:.1}", pct(ok_after)),
        ]);
    }
    let _ = table.write_csv(std::path::Path::new(out_dir).join("fig8.csv").as_path());
    Ok(format!(
        "Figure 8 — read admission on a new leader awaiting a lease vs Zipf skew \
         (100-entry limbo region, 1000 keys, {reads} reads)\n{engine_note}\n\
         expected shape: admitted fraction falls as skew rises; recovers to 100% at lease\n{}",
        table.render()
    ))
}
