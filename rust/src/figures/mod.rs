//! One driver per paper figure (the per-experiment index in DESIGN.md).
//!
//! Each driver is callable from the CLI (`leaseguard figure N`), from
//! the benches (`cargo bench --bench figN_*`), and from the examples.
//! Drivers print the paper-shaped table/series and write CSVs to
//! `results/` so they can be replotted.
//!
//! | driver | paper figure | testbed |
//! |---|---|---|
//! | [`fig5`]  | lease duration vs availability | simulator |
//! | [`fig6`]  | latency vs network latency     | simulator |
//! | [`fig7`]  | availability timeline          | simulator |
//! | [`fig8`]  | skew vs read admission         | node + XLA engine |
//! | [`fig9`]  | availability timeline          | real TCP cluster |
//! | [`fig10`] | latency vs injected delay      | real TCP cluster |
//! | [`fig11`] | scalability                    | real TCP cluster |

pub mod fig10;
pub mod fig11;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod realcluster;

use crate::config::Params;

/// Scale knob for bench/CI runs: 1.0 = paper-sized, smaller = faster.
#[derive(Debug, Clone, Copy)]
pub struct Scale(pub f64);

impl Scale {
    pub fn dur(&self, us: i64) -> i64 {
        ((us as f64) * self.0) as i64
    }
}

/// Dispatch by figure number. Returns the rendered report text.
pub fn run_figure(n: u32, params: &Params, scale: Scale, out_dir: &str) -> anyhow::Result<String> {
    match n {
        5 => Ok(fig5::run(params, scale, out_dir)),
        6 => Ok(fig6::run(params, scale, out_dir)),
        7 => Ok(fig7::run(params, scale, out_dir)),
        8 => fig8::run(params, scale, out_dir),
        9 => fig9::run(params, scale, out_dir),
        10 => fig10::run(params, scale, out_dir),
        11 => fig11::run(params, scale, out_dir),
        _ => anyhow::bail!("no figure {n}; the paper's evaluation figures are 5-11"),
    }
}
