//! In-process real cluster helper: N TCP servers + shared apply log,
//! used by Figures 9-11, the `serve_cluster` example, and the server
//! integration tests. With data directories ([`RealCluster::spawn_durable`])
//! it also supports kill + [`RealCluster::respawn`] crash-recovery
//! drills: a respawned server reboots from its WAL and hard-state file
//! on the same fixed port.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::clock::real::RealClock;
use crate::config::Params;
use crate::runtime::EngineHandle;
use crate::server::server::{Server, ServerConfig, ServerHandle, SharedApplies};
use crate::storage::FsyncPolicy;
use crate::Micros;

pub struct RealCluster {
    pub handles: Vec<Option<ServerHandle>>,
    pub addrs: Vec<String>,
    pub applies: SharedApplies,
    /// Per-server configs, kept for [`RealCluster::respawn`].
    cfgs: Vec<ServerConfig>,
}

impl RealCluster {
    /// Spawn `params.nodes` volatile servers on ephemeral loopback ports.
    pub fn spawn(
        params: &Params,
        one_way_delay: Duration,
        engine: Option<EngineHandle>,
    ) -> std::io::Result<RealCluster> {
        Self::spawn_inner(params, one_way_delay, engine, None, FsyncPolicy::Never)
    }

    /// Spawn with crash durability: server `i` persists to
    /// `data_dirs[i]`, and can be killed and respawned from it.
    pub fn spawn_durable(
        params: &Params,
        one_way_delay: Duration,
        engine: Option<EngineHandle>,
        data_dirs: &[PathBuf],
        fsync: FsyncPolicy,
    ) -> std::io::Result<RealCluster> {
        assert_eq!(data_dirs.len(), params.nodes, "one data dir per server");
        Self::spawn_inner(params, one_way_delay, engine, Some(data_dirs), fsync)
    }

    fn spawn_inner(
        params: &Params,
        one_way_delay: Duration,
        engine: Option<EngineHandle>,
        data_dirs: Option<&[PathBuf]>,
        fsync: FsyncPolicy,
    ) -> std::io::Result<RealCluster> {
        let n = params.nodes;
        let applies: SharedApplies = Arc::new(Mutex::new(Vec::new()));
        // Two-phase bind: reserve ports first so every server knows all
        // peer addresses up front.
        let mut reserved = Vec::new();
        let mut addrs = Vec::new();
        for _ in 0..n {
            let l = std::net::TcpListener::bind("127.0.0.1:0")?;
            addrs.push(l.local_addr()?.to_string());
            reserved.push(l);
        }
        drop(reserved); // release; servers re-bind the same ports
        let mut handles = Vec::new();
        let mut cfgs = Vec::new();
        for id in 0..n {
            let cfg = ServerConfig {
                id,
                peer_addrs: addrs.clone(),
                params: params.clone(),
                one_way_delay,
                engine: engine.clone(),
                applies: Some(applies.clone()),
                data_dir: data_dirs.map(|d| d[id].clone()),
                fsync,
            };
            cfgs.push(cfg.clone());
            handles.push(Some(Server::spawn(cfg)?));
        }
        Ok(RealCluster { handles, addrs, applies, cfgs })
    }

    /// Wait until some server reports leadership (with commit), up to
    /// `timeout`. Returns its index.
    ///
    /// Deadlines here use [`RealClock::monotonic_us`] — the same
    /// process-epoch monotonic timeline the servers run on — rather
    /// than ad-hoc `Instant::now()` reads (lint R1: wall-clock stays
    /// behind the clock module outside `server/`/`client/`).
    pub fn wait_for_leader(&self, timeout: Duration) -> Option<usize> {
        let deadline = RealClock::monotonic_us() + timeout.as_micros() as Micros;
        loop {
            for (i, h) in self.handles.iter().enumerate() {
                if let Some(h) = h {
                    if h.status.group(0).is_leader.load(Ordering::Relaxed)
                        && h.status.group(0).commit_index.get() >= 1
                    {
                        return Some(i);
                    }
                }
            }
            if RealClock::monotonic_us() > deadline {
                return None;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Multi-group: wait until EVERY group `0..params.groups` has a
    /// leader with commit somewhere in the cluster, up to `timeout`.
    /// Returns the per-group leader indices. Groups elect independently
    /// (their timers are independently seeded), so the leaders usually
    /// spread across servers.
    pub fn wait_for_all_leaders(&self, groups: usize, timeout: Duration) -> Option<Vec<usize>> {
        let want: u64 = if groups == 64 { u64::MAX } else { (1u64 << groups) - 1 };
        let deadline = RealClock::monotonic_us() + timeout.as_micros() as Micros;
        loop {
            let mut covered = 0u64;
            let mut leader_of = vec![usize::MAX; groups];
            for (i, h) in self.handles.iter().enumerate() {
                if let Some(h) = h {
                    let led = h.status.leader_groups() & h.status.committed_groups();
                    for (g, l) in leader_of.iter_mut().enumerate() {
                        if led & (1 << g) != 0 {
                            *l = i;
                        }
                    }
                    covered |= led;
                }
            }
            if covered & want == want {
                return Some(leader_of);
            }
            if RealClock::monotonic_us() > deadline {
                return None;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Kill server `i` (crash semantics).
    pub fn kill(&mut self, i: usize) {
        if let Some(h) = self.handles[i].take() {
            h.kill();
        }
    }

    /// Respawn a killed server from its original config — same id, same
    /// fixed port, same data dir. With durability enabled it recovers
    /// `(term, voted_for, log)` from disk; volatile servers reboot blank
    /// (exactly what a process restart gives them).
    pub fn respawn(&mut self, i: usize) -> std::io::Result<()> {
        assert!(self.handles[i].is_none(), "server {i} is still running");
        self.handles[i] = Some(Server::spawn(self.cfgs[i].clone())?);
        Ok(())
    }

    pub fn shutdown(mut self) {
        for i in 0..self.handles.len() {
            self.kill(i);
        }
    }
}

/// Port-reservation race note: between dropping the reserving listener
/// and the server re-binding, another process could steal the port. On a
/// loopback test host this is vanishingly rare; spawn() would fail fast
/// with AddrInUse and callers may simply retry.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_elect_shutdown() {
        let mut p = Params::default();
        p.nodes = 3;
        p.election_timeout_us = 150_000;
        p.election_jitter_us = 100_000;
        p.heartbeat_us = 50_000;
        let c = RealCluster::spawn(&p, Duration::ZERO, None).expect("spawn");
        let leader = c.wait_for_leader(Duration::from_secs(5));
        assert!(leader.is_some(), "no leader elected");
        c.shutdown();
    }

    #[test]
    fn multi_group_cluster_elects_every_group() {
        let mut p = Params::default();
        p.nodes = 3;
        p.groups = 4;
        p.election_timeout_us = 150_000;
        p.election_jitter_us = 100_000;
        p.heartbeat_us = 50_000;
        let c = RealCluster::spawn(&p, Duration::ZERO, None).expect("spawn");
        let leaders =
            c.wait_for_all_leaders(4, Duration::from_secs(10)).expect("all groups elect");
        assert_eq!(leaders.len(), 4);
        assert!(leaders.iter().all(|&l| l < 3), "{leaders:?}");
        c.shutdown();
    }

    #[test]
    fn durable_kill_respawn_rejoins() {
        let mut p = Params::default();
        p.nodes = 3;
        p.election_timeout_us = 150_000;
        p.election_jitter_us = 100_000;
        p.heartbeat_us = 50_000;
        let dirs: Vec<_> = (0..3).map(|i| crate::testkit::TempDir::new(&format!("rc-respawn-{i}"))).collect();
        let paths: Vec<PathBuf> = dirs.iter().map(|d| d.path().to_path_buf()).collect();
        let mut c = RealCluster::spawn_durable(&p, Duration::ZERO, None, &paths, FsyncPolicy::Group)
            .expect("spawn");
        let leader = c.wait_for_leader(Duration::from_secs(5)).expect("leader");
        let follower = (leader + 1) % 3;
        c.kill(follower);
        c.respawn(follower).expect("respawn on same port");
        // The respawned follower catches up to the cluster's term.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let t = c.handles[follower].as_ref().unwrap().status.group(0).term.get();
            if t >= 1 {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "respawned follower never heard a term");
            std::thread::sleep(Duration::from_millis(5));
        }
        c.shutdown();
    }
}
