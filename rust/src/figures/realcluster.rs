//! In-process real cluster helper: N TCP servers + shared apply log,
//! used by Figures 9-11, the `serve_cluster` example, and the server
//! integration tests.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::Params;
use crate::runtime::EngineHandle;
use crate::server::server::{Server, ServerConfig, ServerHandle, SharedApplies};

pub struct RealCluster {
    pub handles: Vec<Option<ServerHandle>>,
    pub addrs: Vec<String>,
    pub applies: SharedApplies,
}

impl RealCluster {
    /// Spawn `params.nodes` servers on ephemeral loopback ports.
    pub fn spawn(
        params: &Params,
        one_way_delay: Duration,
        engine: Option<EngineHandle>,
    ) -> std::io::Result<RealCluster> {
        let n = params.nodes;
        let applies: SharedApplies = Arc::new(Mutex::new(Vec::new()));
        // Two-phase bind: reserve ports first so every server knows all
        // peer addresses up front.
        let mut reserved = Vec::new();
        let mut addrs = Vec::new();
        for _ in 0..n {
            let l = std::net::TcpListener::bind("127.0.0.1:0")?;
            addrs.push(l.local_addr()?.to_string());
            reserved.push(l);
        }
        drop(reserved); // release; servers re-bind the same ports
        let mut handles = Vec::new();
        for id in 0..n {
            let cfg = ServerConfig {
                id,
                peer_addrs: addrs.clone(),
                params: params.clone(),
                one_way_delay,
                engine: engine.clone(),
                applies: Some(applies.clone()),
            };
            handles.push(Some(Server::spawn(cfg)?));
        }
        Ok(RealCluster { handles, addrs, applies })
    }

    /// Wait until some server reports leadership (with commit), up to
    /// `timeout`. Returns its index.
    pub fn wait_for_leader(&self, timeout: Duration) -> Option<usize> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            for (i, h) in self.handles.iter().enumerate() {
                if let Some(h) = h {
                    if h.status.is_leader.load(Ordering::Relaxed)
                        && h.status.commit_index.load(Ordering::Relaxed) >= 1
                    {
                        return Some(i);
                    }
                }
            }
            if std::time::Instant::now() > deadline {
                return None;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Kill server `i` (crash semantics).
    pub fn kill(&mut self, i: usize) {
        if let Some(h) = self.handles[i].take() {
            h.kill();
        }
    }

    pub fn shutdown(mut self) {
        for i in 0..self.handles.len() {
            self.kill(i);
        }
    }
}

/// Port-reservation race note: between dropping the reserving listener
/// and the server re-binding, another process could steal the port. On a
/// loopback test host this is vanishingly rare; spawn() would fail fast
/// with AddrInUse and callers may simply retry.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_elect_shutdown() {
        let mut p = Params::default();
        p.nodes = 3;
        p.election_timeout_us = 150_000;
        p.election_jitter_us = 100_000;
        p.heartbeat_us = 50_000;
        let c = RealCluster::spawn(&p, Duration::ZERO, None).expect("spawn");
        let leader = c.wait_for_leader(Duration::from_secs(5));
        assert!(leader.is_some(), "no leader elected");
        c.shutdown();
    }
}
