//! Figure 6 (Q1, simulation): effect of one-way network latency on
//! 90th-percentile read/write latency for the inconsistent, quorum, and
//! LeaseGuard configurations.
//!
//! Paper parameters (§6.4): lognormal latency, mean 1-10 ms with
//! variance = mean; 50 clients, half read half append, Poisson arrivals
//! averaging 100 ms apart; client-server latency zero. Expected shape:
//! quorum reads track write latency; lease/inconsistent reads are ~0.

use crate::cluster::Cluster;
use crate::config::{ConsistencyMode, Params};
use crate::report::{fmt_us, Table};

use super::Scale;

pub fn run(base: &Params, scale: Scale, out_dir: &str) -> String {
    let modes = [
        ConsistencyMode::Inconsistent,
        ConsistencyMode::Quorum,
        ConsistencyMode::LeaseGuard,
    ];
    let means_ms = [1.0f64, 2.0, 5.0, 10.0];
    let mut table = Table::new(["net_mean_ms", "mode", "read_p90", "write_p90", "reads", "writes"]);
    let mut csv = Table::new(["net_mean_ms", "mode", "read_p90_us", "write_p90_us"]);
    for &ms in &means_ms {
        for mode in modes {
            let mut p = base.clone();
            p.consistency = mode;
            p.net_mean_us = ms * 1000.0;
            p.net_variance_us2 = ms * 1_000_000.0; // variance = mean (in ms²)
            p.net_min_delay_us = 100;
            // 50 clients, one op each ~100ms apart ≈ 1 op / 2ms overall.
            p.interarrival_us = 2000.0;
            p.write_fraction = 0.5;
            p.duration_us = scale.dur(10_000_000).max(3_000_000);
            // Leases should comfortably outlast the run (steady state).
            p.lease_duration_us = 2_000_000;
            p.crash_leader_at_us = 0;
            let rep = Cluster::new(p).run();
            table.row([
                format!("{ms:.0}"),
                mode.to_string(),
                fmt_us(rep.read_latency.p90()),
                fmt_us(rep.write_latency.p90()),
                rep.read_latency.count().to_string(),
                rep.write_latency.count().to_string(),
            ]);
            csv.row([
                format!("{ms}"),
                mode.to_string(),
                rep.read_latency.p90().to_string(),
                rep.write_latency.p90().to_string(),
            ]);
        }
    }
    let _ = csv.write_csv(std::path::Path::new(out_dir).join("fig6.csv").as_path());
    format!(
        "Figure 6 — p90 latency vs one-way network latency (simulation)\n\
         expected shape: quorum read ≈ write latency; inconsistent/LeaseGuard reads ≈ 0\n{}",
        table.render()
    )
}
