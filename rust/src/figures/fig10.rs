//! Figure 10 (Q1, real cluster): p90 read/write latency vs injected
//! one-way peer delay (§7.2 — the paper uses `tc`; we inject the delay
//! in the transport's [`crate::server::transport::DelayedSender`]).
//!
//! Expected shape: writes track the injected delay in every mode;
//! quorum reads track it too (plus queueing blow-up under load);
//! inconsistent / Ongaro / LeaseGuard reads stay at sub-millisecond
//! loopback latency regardless of the delay.

use std::time::Duration;

use anyhow::Result;

use crate::client::run_open_loop;
use crate::config::{ConsistencyMode, Params};
use crate::report::{fmt_us, Table};

use super::realcluster::RealCluster;
use super::Scale;

pub fn run(base: &Params, scale: Scale, out_dir: &str) -> Result<String> {
    let modes = [
        ConsistencyMode::Inconsistent,
        ConsistencyMode::Quorum,
        ConsistencyMode::OngaroLease,
        ConsistencyMode::LeaseGuard,
    ];
    let delays_ms = [1u64, 2, 5, 10];
    let mut table =
        Table::new(["delay_ms", "mode", "read_p90", "write_p90", "reads_ok", "writes_ok"]);
    let mut csv = Table::new(["delay_ms", "mode", "read_p90_us", "write_p90_us"]);
    for &ms in &delays_ms {
        for mode in modes {
            let mut p = base.clone();
            p.consistency = mode;
            // Moderate load so queueing (not saturation) dominates.
            p.interarrival_us = (2_000.0 / scale.0).max(500.0);
            p.write_fraction = 1.0 / 3.0;
            p.value_bytes = 1024;
            p.duration_us = scale.dur(2_000_000).max(1_200_000);
            p.lease_duration_us = 2_000_000;
            p.heartbeat_us = 150_000;
            p.election_timeout_us = 800_000 + 2 * ms as i64 * 1000;
            p.crash_leader_at_us = 0;
            let cluster = RealCluster::spawn(&p, Duration::from_millis(ms), None)?;
            cluster
                .wait_for_leader(Duration::from_secs(10))
                .ok_or_else(|| anyhow::anyhow!("no leader"))?;
            let rep = run_open_loop(&cluster.addrs, &p, Some(cluster.applies.clone()))?;
            cluster.shutdown();
            table.row([
                ms.to_string(),
                mode.to_string(),
                fmt_us(rep.read_latency.p90()),
                fmt_us(rep.write_latency.p90()),
                rep.read_latency.count().to_string(),
                rep.write_latency.count().to_string(),
            ]);
            csv.row([
                ms.to_string(),
                mode.to_string(),
                rep.read_latency.p90().to_string(),
                rep.write_latency.p90().to_string(),
            ]);
        }
    }
    let _ = csv.write_csv(std::path::Path::new(out_dir).join("fig10.csv").as_path());
    Ok(format!(
        "Figure 10 — p90 latency vs injected one-way peer delay (real TCP cluster)\n\
         expected shape: quorum reads ≈ writes ≈ RTT; lease/inconsistent reads flat\n{}",
        table.render()
    ))
}
