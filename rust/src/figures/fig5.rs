//! Figure 5: effect of lease duration Δ on availability (ET = 500 ms for
//! all charts; "Election timeout = Δ is usually optimal", §5.2).
//!
//! For each Δ we run the §6.5 crash scenario and report read/write
//! success rates over the 2 s following the crash, plus time-to-recovery
//! (first bucket after the crash where throughput exceeds half of
//! steady state).

use crate::cluster::Cluster;
use crate::config::{ConsistencyMode, Params};
use crate::report::Table;

use super::Scale;

pub fn run(base: &Params, scale: Scale, out_dir: &str) -> String {
    let deltas_ms = [250i64, 500, 1000, 2000];
    let mut table = Table::new([
        "delta_ms",
        "reads_ok",
        "reads_failed",
        "writes_ok",
        "writes_failed",
        "read_avail_%",
        "write_avail_%",
    ]);
    for &d in &deltas_ms {
        let mut p = base.clone();
        p.consistency = ConsistencyMode::LeaseGuard;
        p.election_timeout_us = 500_000;
        p.lease_duration_us = d * 1000;
        p.crash_leader_at_us = scale.dur(500_000);
        p.duration_us = scale.dur(500_000) + 2_500_000.min(scale.dur(2_500_000)).max(1_500_000);
        p.interarrival_us = 300.0 / scale.0.max(0.1);
        let rep = Cluster::new(p.clone()).run();
        let from = p.crash_leader_at_us;
        let to = p.duration_us;
        let r = rep.series.window_totals(true, from, to);
        let w = rep.series.window_totals(false, from, to);
        let pct = |ok: u64, failed: u64| {
            if ok + failed == 0 {
                0.0
            } else {
                100.0 * ok as f64 / (ok + failed) as f64
            }
        };
        table.row([
            d.to_string(),
            r.ok.to_string(),
            r.failed.to_string(),
            w.ok.to_string(),
            w.failed.to_string(),
            format!("{:.1}", pct(r.ok, r.failed)),
            format!("{:.1}", pct(w.ok, w.failed)),
        ]);
    }
    let _ = table.write_csv(std::path::Path::new(out_dir).join("fig5.csv").as_path());
    format!(
        "Figure 5 — availability after a leader crash vs lease duration Δ \
         (ET=500ms, LeaseGuard, window = crash..end)\n{}",
        table.render()
    )
}
