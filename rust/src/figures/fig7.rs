//! Figure 7 (Q2, simulation): read/write availability around a leader
//! crash for all six consistency configurations.
//!
//! Paper parameters (§6.5): AWS same-subnet latency (lognormal, mean
//! 191 µs, var 391 µs²); an op every 300 µs (1/3 writes of 1 KiB);
//! 1000 uniform keys; ET = 500 ms; Δ = 1 s = 2·ET (deliberately, to
//! expose the transition window); crash at 500 ms after steady state.
//!
//! Expected shapes per §6.5: inconsistent recovers instantly at
//! election; quorum recovers with a lower spike; log-based lease is
//! dark until lease expiry (1.5 s); defer-commit shows the off-chart
//! write ack burst at lease expiry; full LeaseGuard additionally serves
//! reads from the moment of election (inherited lease).

use crate::cluster::Cluster;
use crate::config::{ConsistencyMode, Params};
use crate::linearizability;
use crate::report::{timeline_chart, Table};

use super::Scale;

pub fn params_for(base: &Params, mode: ConsistencyMode, scale: Scale) -> Params {
    let mut p = base.clone();
    p.consistency = mode;
    p.net_mean_us = 191.0;
    p.net_variance_us2 = 391.0;
    p.interarrival_us = 300.0;
    p.write_fraction = 1.0 / 3.0;
    p.num_keys = 1000;
    p.zipf_a = 0.0;
    p.election_timeout_us = 500_000;
    p.election_jitter_us = 100_000;
    p.lease_duration_us = 1_000_000; // Δ = 2·ET
    p.crash_leader_at_us = 500_000;
    p.duration_us = scale.dur(3_000_000).max(2_200_000);
    p.bucket_us = 50_000;
    p
}

pub fn run(base: &Params, scale: Scale, out_dir: &str) -> String {
    let mut out = String::new();
    let mut table = Table::new([
        "mode",
        "reads_ok[0,0.5s)",
        "reads_ok[1.0,1.5s)",
        "reads_ok[1.5,2.0s)",
        "writes_ok[1.0,1.5s)",
        "writes_ok[1.5,2.0s)",
        "limbo",
        "linearizable",
    ]);
    let mut csv = Table::new(["mode", "bucket_ms", "reads_per_s", "writes_per_s"]);
    for mode in ConsistencyMode::ALL {
        let p = params_for(base, mode, scale);
        let rep = Cluster::new(p.clone()).run();
        let viol = linearizability::check(&rep.history);
        let lin_ok = viol.is_empty();
        let w = |read, from, to| rep.series.window_totals(read, from, to).ok;
        table.row([
            mode.to_string(),
            w(true, 0, 500_000).to_string(),
            w(true, 1_000_000, 1_500_000).to_string(),
            w(true, 1_500_000, 2_000_000).to_string(),
            w(false, 1_000_000, 1_500_000).to_string(),
            w(false, 1_500_000, 2_000_000).to_string(),
            rep.limbo_len.to_string(),
            if lin_ok {
                "yes".to_string()
            } else {
                format!("VIOLATIONS({})", viol.len())
            },
        ]);
        let reads = rep.series.ok_rate_per_sec(true);
        let writes = rep.series.ok_rate_per_sec(false);
        for (i, (r, wr)) in reads.iter().zip(writes.iter()).enumerate() {
            csv.row([
                mode.to_string(),
                ((i as i64) * p.bucket_us / 1000).to_string(),
                format!("{r:.0}"),
                format!("{wr:.0}"),
            ]);
        }
        out.push_str(&format!(
            "\n--- {mode} (crash at 500ms, election ~1s, old lease expires 1.5s) ---\n{}",
            timeline_chart(&["reads/s", "writes/s"], &[reads, writes], p.bucket_us as f64 / 1000.0)
        ));
    }
    let _ = csv.write_csv(std::path::Path::new(out_dir).join("fig7.csv").as_path());
    format!(
        "Figure 7 — availability around a leader crash (simulation)\n{}{}",
        table.render(),
        out
    )
}
