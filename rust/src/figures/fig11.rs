//! Figure 11 (Q4, real cluster): scalability — achieved throughput and
//! p90 latency as offered load rises, for each consistency mechanism
//! and write ratio (§7.4).
//!
//! Paper: 5,000 → 60,000 ops/s, stopping once latency exceeds 100 ms;
//! write ratios 5% and 33%; 1 KiB values, uniform keys. Expected shape:
//! quorum hits its ceiling roughly 10× below LeaseGuard; LeaseGuard ≈
//! Ongaro ≈ inconsistent. Offered loads are scaled by `Scale` for this
//! single-host testbed.
//!
//! Beyond the paper: `--groups G` adds a multi-Raft axis, re-running the
//! sweep at group counts 1, 2, 4, …, G to show aggregate throughput
//! scaling as one process hosts many lease-guarded groups.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::time::Duration;

use anyhow::Result;

use crate::client::run_open_loop;
use crate::config::{ConsistencyMode, Params};
use crate::report::{fmt_us, Table};

use super::realcluster::RealCluster;
use super::Scale;

pub fn run(base: &Params, scale: Scale, out_dir: &str) -> Result<String> {
    let modes = [
        ConsistencyMode::Inconsistent,
        ConsistencyMode::Quorum,
        ConsistencyMode::OngaroLease,
        ConsistencyMode::LeaseGuard,
    ];
    let offered: Vec<f64> = [2_000.0, 5_000.0, 10_000.0, 20_000.0, 40_000.0]
        .iter()
        .map(|x| x * scale.0.max(0.05))
        .collect();
    let write_ratios = [0.05f64, 1.0 / 3.0];
    // Multi-Raft axis (`--groups G`): sweep group counts 1, 2, 4, …, G.
    // With G=1 (the default) this collapses to the paper's original
    // single-group figure. More groups mean more independent leaders
    // sharing the same three processes — aggregate throughput should
    // rise until the processes themselves saturate.
    let mut group_counts = vec![1usize];
    while group_counts.last().unwrap() * 2 <= base.groups {
        group_counts.push(group_counts.last().unwrap() * 2);
    }
    if *group_counts.last().unwrap() != base.groups {
        group_counts.push(base.groups);
    }
    let mut table = Table::new([
        "groups",
        "write_ratio",
        "mode",
        "offered_ops_s",
        "achieved_ops_s",
        "read_p90",
        "write_p90",
    ]);
    let mut csv = Table::new([
        "groups",
        "write_ratio",
        "mode",
        "offered_ops_s",
        "achieved_ops_s",
        "read_p90_us",
        "write_p90_us",
    ]);
    for &gc in &group_counts {
        for &wr in &write_ratios {
            for mode in modes {
                let mut saturated = false;
                for &load in &offered {
                    if saturated {
                        break;
                    }
                    let mut p = base.clone();
                    p.consistency = mode;
                    p.groups = gc;
                    p.interarrival_us = 1_000_000.0 / load;
                    p.write_fraction = wr;
                    p.value_bytes = 1024;
                    p.duration_us = 1_500_000;
                    p.lease_duration_us = 2_000_000;
                    p.heartbeat_us = 150_000;
                    p.election_timeout_us = 800_000;
                    p.crash_leader_at_us = 0;
                    let cluster = RealCluster::spawn(&p, Duration::ZERO, None)?;
                    if gc > 1 {
                        cluster
                            .wait_for_all_leaders(gc, Duration::from_secs(10))
                            .ok_or_else(|| anyhow::anyhow!("not all {gc} groups elected"))?;
                    } else {
                        cluster
                            .wait_for_leader(Duration::from_secs(10))
                            .ok_or_else(|| anyhow::anyhow!("no leader"))?;
                    }
                    let rep = run_open_loop(&cluster.addrs, &p, None)?;
                    cluster.shutdown();
                    let dur_s = p.duration_us as f64 / 1e6;
                    let achieved =
                        (rep.read_latency.count() + rep.write_latency.count()) as f64 / dur_s;
                    let p90 = rep.read_latency.p90().max(rep.write_latency.p90());
                    if p90 > 100_000 {
                        saturated = true; // paper's stop rule: latency > 100 ms
                    }
                    table.row([
                        gc.to_string(),
                        format!("{wr:.2}"),
                        mode.to_string(),
                        format!("{load:.0}"),
                        format!("{achieved:.0}"),
                        fmt_us(rep.read_latency.p90()),
                        fmt_us(rep.write_latency.p90()),
                    ]);
                    csv.row([
                        gc.to_string(),
                        format!("{wr}"),
                        mode.to_string(),
                        format!("{load:.0}"),
                        format!("{achieved:.0}"),
                        rep.read_latency.p90().to_string(),
                        rep.write_latency.p90().to_string(),
                    ]);
                }
            }
        }
    }
    let _ = csv.write_csv(std::path::Path::new(out_dir).join("fig11.csv").as_path());
    Ok(format!(
        "Figure 11 — scalability (real TCP cluster; offered load scaled ×{:.2})\n\
         expected shape: quorum ceiling ≪ others; LeaseGuard ≈ inconsistent\n{}",
        scale.0,
        table.render()
    ))
}
