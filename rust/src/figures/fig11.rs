//! Figure 11 (Q4, real cluster): scalability — achieved throughput and
//! p90 latency as offered load rises, for each consistency mechanism
//! and write ratio (§7.4).
//!
//! Paper: 5,000 → 60,000 ops/s, stopping once latency exceeds 100 ms;
//! write ratios 5% and 33%; 1 KiB values, uniform keys. Expected shape:
//! quorum hits its ceiling roughly 10× below LeaseGuard; LeaseGuard ≈
//! Ongaro ≈ inconsistent. Offered loads are scaled by `Scale` for this
//! single-host testbed.

use std::time::Duration;

use anyhow::Result;

use crate::client::run_open_loop;
use crate::config::{ConsistencyMode, Params};
use crate::report::{fmt_us, Table};

use super::realcluster::RealCluster;
use super::Scale;

pub fn run(base: &Params, scale: Scale, out_dir: &str) -> Result<String> {
    let modes = [
        ConsistencyMode::Inconsistent,
        ConsistencyMode::Quorum,
        ConsistencyMode::OngaroLease,
        ConsistencyMode::LeaseGuard,
    ];
    let offered: Vec<f64> = [2_000.0, 5_000.0, 10_000.0, 20_000.0, 40_000.0]
        .iter()
        .map(|x| x * scale.0.max(0.05))
        .collect();
    let write_ratios = [0.05f64, 1.0 / 3.0];
    let mut table = Table::new([
        "write_ratio",
        "mode",
        "offered_ops_s",
        "achieved_ops_s",
        "read_p90",
        "write_p90",
    ]);
    let mut csv = Table::new([
        "write_ratio",
        "mode",
        "offered_ops_s",
        "achieved_ops_s",
        "read_p90_us",
        "write_p90_us",
    ]);
    for &wr in &write_ratios {
        for mode in modes {
            let mut saturated = false;
            for &load in &offered {
                if saturated {
                    break;
                }
                let mut p = base.clone();
                p.consistency = mode;
                p.interarrival_us = 1_000_000.0 / load;
                p.write_fraction = wr;
                p.value_bytes = 1024;
                p.duration_us = 1_500_000;
                p.lease_duration_us = 2_000_000;
                p.heartbeat_us = 150_000;
                p.election_timeout_us = 800_000;
                p.crash_leader_at_us = 0;
                let cluster = RealCluster::spawn(&p, Duration::ZERO, None)?;
                cluster
                    .wait_for_leader(Duration::from_secs(10))
                    .ok_or_else(|| anyhow::anyhow!("no leader"))?;
                let rep = run_open_loop(&cluster.addrs, &p, None)?;
                cluster.shutdown();
                let dur_s = p.duration_us as f64 / 1e6;
                let achieved =
                    (rep.read_latency.count() + rep.write_latency.count()) as f64 / dur_s;
                let p90 = rep.read_latency.p90().max(rep.write_latency.p90());
                if p90 > 100_000 {
                    saturated = true; // paper's stop rule: latency > 100 ms
                }
                table.row([
                    format!("{wr:.2}"),
                    mode.to_string(),
                    format!("{load:.0}"),
                    format!("{achieved:.0}"),
                    fmt_us(rep.read_latency.p90()),
                    fmt_us(rep.write_latency.p90()),
                ]);
                csv.row([
                    format!("{wr}"),
                    mode.to_string(),
                    format!("{load:.0}"),
                    format!("{achieved:.0}"),
                    rep.read_latency.p90().to_string(),
                    rep.write_latency.p90().to_string(),
                ]);
            }
        }
    }
    let _ = csv.write_csv(std::path::Path::new(out_dir).join("fig11.csv").as_path());
    Ok(format!(
        "Figure 11 — scalability (real TCP cluster; offered load scaled ×{:.2})\n\
         expected shape: quorum ceiling ≪ others; LeaseGuard ≈ inconsistent\n{}",
        scale.0,
        table.render()
    ))
}
