//! Seeded pseudorandomness and the probability distributions the paper's
//! simulator uses (`prob.py` in the original): uniform, lognormal network
//! latency (§6.4), exponential interarrival / Poisson processes (§6.4),
//! and Zipf-distributed key choice (§6.6, §7.3).
//!
//! No external crates are available offline, so this module implements
//! xoshiro256++ (Blackman & Vigna) directly. Everything is deterministic
//! given a seed — the property the paper "carefully engineered" for
//! reproducibility (§6).

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

/// xoshiro256++ PRNG. Fast, 256-bit state, passes BigCrush; more than
/// adequate for workload generation and fault scheduling.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// splitmix64, used to expand a 64-bit seed into xoshiro state (the
/// initialization the xoshiro authors recommend).
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed. Equal seeds ⇒ equal streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-node / per-client
    /// generators that must not perturb each other's sequences).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Next raw 64 bits (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Lemire's multiply-shift (unbiased
    /// enough for workload generation; n ≪ 2^32 in all our uses).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box-Muller (one value per call; we discard the
    /// pair's second member to keep the stream layout simple).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential with the given mean (interarrival times of a Poisson
    /// process — the paper's client arrivals, §6.4).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        loop {
            let u = self.f64();
            if u > 0.0 {
                return -mean * u.ln();
            }
        }
    }
}

/// Lognormal distribution parameterized by its *arithmetic* mean and
/// variance — the paper specifies network latency as "lognormal with
/// means from 1-10ms and variance equal to the mean" (§6.4) and AWS
/// same-subnet latency as mean 191µs, variance 391µs² (§6.5).
#[derive(Debug, Clone, Copy)]
pub struct LogNormal {
    mu: f64,
    sigma: f64,
}

impl LogNormal {
    /// From arithmetic mean m and variance v of the distribution itself
    /// (not of the underlying normal): σ² = ln(1 + v/m²), µ = ln m − σ²/2.
    pub fn from_mean_variance(mean: f64, variance: f64) -> Self {
        assert!(mean > 0.0 && variance >= 0.0);
        let sigma2 = (1.0 + variance / (mean * mean)).ln();
        LogNormal {
            mu: mean.ln() - sigma2 / 2.0,
            sigma: sigma2.sqrt(),
        }
    }

    /// Sample one value.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        (self.mu + self.sigma * rng.normal()).exp()
    }
}

/// Zipf distribution over ranks 1..=n with exponent `a` — the paper's
/// skewness parameter (§6.6: a ∈ [0, 2]; at a=2 the hottest of 1000 keys
/// receives 61% of operations; §7.3: a=0.5 ⇒ hottest key 1.6%).
///
/// Implemented with a precomputed CDF table + binary search: n is ≤ a few
/// thousand in all experiments, so the table is tiny and sampling is
/// O(log n) with perfect accuracy.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, a: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut total = 0.0;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(a);
            cdf.push(total);
        }
        for c in cdf.iter_mut() {
            *c /= total;
        }
        // Guard against FP slack at the top.
        *cdf.last_mut().unwrap() = 1.0;
        Zipf { cdf }
    }

    /// Sample a rank in [0, n) — rank 0 is the hottest key.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // partition_point: first index with cdf[i] >= u.
        self.cdf.partition_point(|&c| c < u)
    }

    /// Probability mass of rank 0 (used by tests to validate the paper's
    /// quoted figures: 61% at a=2, 1.6% at a=0.5 over 1000 keys).
    pub fn hottest_mass(&self) -> f64 {
        self.cdf[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean = 300.0;
        let s: f64 = (0..n).map(|_| r.exponential(mean)).sum();
        let m = s / n as f64;
        assert!((m - mean).abs() / mean < 0.02, "mean {m}");
    }

    #[test]
    fn lognormal_mean_variance_roundtrip() {
        // Paper §6.4: variance equal to the mean.
        for &(mean, var) in &[(1000.0, 1000.0), (191.0, 391.0), (10_000.0, 10_000.0)] {
            let d = LogNormal::from_mean_variance(mean, var);
            let mut r = Rng::new(17);
            let n = 400_000;
            let (mut sum, mut sq) = (0.0, 0.0);
            for _ in 0..n {
                let x = d.sample(&mut r);
                assert!(x > 0.0);
                sum += x;
                sq += x * x;
            }
            let m = sum / n as f64;
            let v = sq / n as f64 - m * m;
            assert!((m - mean).abs() / mean < 0.02, "mean {m} want {mean}");
            assert!((v - var).abs() / var < 0.25, "var {v} want {var}");
        }
    }

    #[test]
    fn zipf_uniform_at_zero() {
        let z = Zipf::new(1000, 0.0);
        assert!((z.hottest_mass() - 0.001).abs() < 1e-12);
    }

    #[test]
    fn zipf_paper_quoted_masses() {
        // §6.6: at a=2 the hottest of 1000 keys gets 61% of operations.
        let z2 = Zipf::new(1000, 2.0);
        assert!(
            (z2.hottest_mass() - 0.61).abs() < 0.005,
            "a=2 hottest mass {}",
            z2.hottest_mass()
        );
        // §7.3: at a=0.5 the hottest key is chosen 1.6% of the time.
        let z05 = Zipf::new(1000, 0.5);
        assert!(
            (z05.hottest_mass() - 0.016).abs() < 0.001,
            "a=0.5 hottest mass {}",
            z05.hottest_mass()
        );
    }

    #[test]
    fn zipf_sampling_matches_mass() {
        let z = Zipf::new(100, 1.0);
        let mut r = Rng::new(23);
        let n = 200_000;
        let mut count0 = 0usize;
        for _ in 0..n {
            let k = z.sample(&mut r);
            assert!(k < 100);
            if k == 0 {
                count0 += 1;
            }
        }
        let freq = count0 as f64 / n as f64;
        assert!((freq - z.hottest_mass()).abs() < 0.01, "freq {freq}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(31);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut a = Rng::new(5);
        let mut c1 = a.fork();
        let mut c2 = a.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
