//! Layer-3 ↔ Layer-1/2 bridge: loads the AOT-compiled read-admission
//! model (`artifacts/*.hlo.txt`, produced once at build time by
//! `python/compile/aot.py`) and executes it from the coordinator's hot
//! path via the PJRT CPU client (`xla` crate).
//!
//! Interchange is HLO **text** — jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).
//!
//! [`AdmissionEngine`] is the batched form of the paper's per-read limbo
//! check (§7.1's `setLimboRegion` + lease-age gate): given the hashes of
//! the keys a queue of pending reads touches, the limbo-region key
//! hashes, the conservative age of the newest committed entry and Δ, it
//! returns an admit/reject mask in one XLA execution. The scalar
//! fallback ([`scalar_admission`]) implements the identical decision and
//! is both the correctness oracle for tests and the path used when the
//! engine is disabled (`use_xla_admission = false` — the ablation).

pub mod admission;
pub mod engine;

pub use admission::{hash_key, scalar_admission, AdmissionInputs, PAD_SENTINEL};
pub use engine::{AdmissionEngine, EngineHandle};
