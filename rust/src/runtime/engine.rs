//! PJRT execution of the AOT read-admission model.
//!
//! Artifacts are compiled **once** at engine construction (startup), so
//! the request path is: pad inputs → 3 host literals → execute → read
//! back the i32 mask. Python is never involved at runtime.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::admission::{scalar_admission, AdmissionInputs, PAD_SENTINEL};

/// One compiled (B, K) shape point.
struct Variant {
    b: usize,
    k: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// Engine runtime counters (perf pass visibility).
#[derive(Debug, Default, Clone, Copy)]
pub struct EngineStats {
    pub calls: u64,
    pub queries: u64,
    pub scalar_fallbacks: u64,
}

pub struct AdmissionEngine {
    variants: Vec<Variant>,
    stats: std::cell::Cell<EngineStats>,
}

impl AdmissionEngine {
    /// Load every `read_admission_b{B}_k{K}.hlo.txt` in `dir`, compile
    /// on the PJRT CPU client. Fails if none found (run `make artifacts`).
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        let mut variants = Vec::new();
        let rd = std::fs::read_dir(dir)
            .with_context(|| format!("artifacts dir {} (run `make artifacts`)", dir.display()))?;
        let mut paths: Vec<PathBuf> = rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
        paths.sort();
        for path in paths {
            let name = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
            let Some(bk) = parse_artifact_name(name) else { continue };
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {name}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            variants.push(Variant { b: bk.0, k: bk.1, exe });
        }
        if variants.is_empty() {
            bail!("no read_admission artifacts in {} (run `make artifacts`)", dir.display());
        }
        variants.sort_by_key(|v| (v.b, v.k));
        Ok(AdmissionEngine { variants, stats: Default::default() })
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.get()
    }

    /// Shape points available (b, k), sorted ascending.
    pub fn shapes(&self) -> Vec<(usize, usize)> {
        self.variants.iter().map(|v| (v.b, v.k)).collect()
    }

    /// Batched admission decision. Pads/chunks to the compiled shapes;
    /// falls back to the scalar path only if the limbo region exceeds
    /// every compiled K (conservatively correct either way).
    pub fn admit(&self, inp: &AdmissionInputs) -> Result<Vec<bool>> {
        let mut stats = self.stats.get();
        stats.calls += 1;
        stats.queries += inp.query_hashes.len() as u64;
        let max_k = self.variants.iter().map(|v| v.k).max().unwrap();
        if inp.limbo_hashes.len() > max_k {
            stats.scalar_fallbacks += 1;
            self.stats.set(stats);
            return Ok(scalar_admission(inp));
        }
        self.stats.set(stats);
        let mut out = Vec::with_capacity(inp.query_hashes.len());
        // Pick the smallest variant that fits the limbo region; chunk
        // queries through its B.
        let v = self
            .variants
            .iter()
            .find(|v| v.k >= inp.limbo_hashes.len())
            .expect("max_k checked above");
        let mut limbo = inp.limbo_hashes.clone();
        limbo.resize(v.k, PAD_SENTINEL);
        let age = inp.commit_age_us.clamp(0, i32::MAX as i64) as i32;
        let delta = inp.delta_us.clamp(0, i32::MAX as i64) as i32;
        let scalars = [age, delta, inp.own_term_commit as i32, 0];
        for chunk in inp.query_hashes.chunks(v.b.max(1)) {
            let mut q = chunk.to_vec();
            q.resize(v.b, PAD_SENTINEL);
            let mask = self.execute(v, &q, &limbo, &scalars)?;
            out.extend(mask.iter().take(chunk.len()).map(|&m| m != 0));
        }
        Ok(out)
    }

    fn execute(&self, v: &Variant, q: &[i32], l: &[i32], s: &[i32; 4]) -> Result<Vec<i32>> {
        let ql = xla::Literal::vec1(q);
        let ll = xla::Literal::vec1(l);
        let sl = xla::Literal::vec1(&s[..]);
        let res = v
            .exe
            .execute::<xla::Literal>(&[ql, ll, sl])
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = res[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("readback: {e:?}"))?;
        let tup = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        tup.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }
}

/// Thread-safe handle to an [`AdmissionEngine`].
///
/// PJRT client/executable types are `!Send` (they hold `Rc` internals),
/// so the engine lives on a dedicated owner thread; handles are cheap
/// to clone and serialize all executions through a channel — which also
/// matches the deployment reality that one compiled executable serves
/// the whole process.
#[derive(Clone)]
pub struct EngineHandle {
    tx: std::sync::mpsc::Sender<(AdmissionInputs, std::sync::mpsc::Sender<Result<Vec<bool>, String>>)>,
}

impl EngineHandle {
    /// Spawn the owner thread and load artifacts there. Blocks until the
    /// load finishes so failures surface immediately.
    pub fn spawn(dir: &Path) -> Result<EngineHandle> {
        let dir = dir.to_path_buf();
        let (tx, rx) = std::sync::mpsc::channel::<(
            AdmissionInputs,
            std::sync::mpsc::Sender<Result<Vec<bool>, String>>,
        )>();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<(), String>>();
        std::thread::spawn(move || {
            let engine = match AdmissionEngine::load(&dir) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            while let Ok((inp, reply)) = rx.recv() {
                let _ = reply.send(engine.admit(&inp).map_err(|e| format!("{e:#}")));
            }
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during load"))?
            .map_err(|e| anyhow!(e))?;
        Ok(EngineHandle { tx })
    }

    /// Execute one batched admission on the owner thread.
    pub fn admit(&self, inp: &AdmissionInputs) -> Result<Vec<bool>> {
        let (rtx, rrx) = std::sync::mpsc::channel();
        self.tx
            .send((inp.clone(), rtx))
            .map_err(|_| anyhow!("engine thread gone"))?;
        rrx.recv().map_err(|_| anyhow!("engine thread gone"))?.map_err(|e| anyhow!(e))
    }
}

/// Parse `read_admission_b{B}_k{K}.hlo.txt` → (B, K).
fn parse_artifact_name(name: &str) -> Option<(usize, usize)> {
    let rest = name.strip_prefix("read_admission_b")?;
    let rest = rest.strip_suffix(".hlo.txt")?;
    let (b, k) = rest.split_once("_k")?;
    Some((b.parse().ok()?, k.parse().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prob::Rng;
    use crate::runtime::admission::hash_key;

    fn engine() -> Option<AdmissionEngine> {
        let dir = Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping engine test: run `make artifacts` first");
            return None;
        }
        Some(AdmissionEngine::load(dir).expect("engine load"))
    }

    #[test]
    fn artifact_name_parsing() {
        assert_eq!(parse_artifact_name("read_admission_b256_k128.hlo.txt"), Some((256, 128)));
        assert_eq!(parse_artifact_name("manifest.json"), None);
        assert_eq!(parse_artifact_name("read_admission_bx_k1.hlo.txt"), None);
    }

    #[test]
    fn engine_matches_scalar_oracle() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(99);
        for trial in 0..20 {
            let nq = 1 + rng.below(700) as usize;
            let nl = rng.below(250) as usize;
            let keyspace = 1 + rng.below(50) as u32;
            let inp = AdmissionInputs {
                query_hashes: (0..nq).map(|_| hash_key(rng.below(keyspace as u64) as u32)).collect(),
                limbo_hashes: (0..nl).map(|_| hash_key(rng.below(keyspace as u64) as u32)).collect(),
                commit_age_us: rng.below(2_000_000) as i64,
                delta_us: 1_000_000,
                own_term_commit: rng.chance(0.3),
            };
            let got = e.admit(&inp).unwrap();
            let want = scalar_admission(&inp);
            assert_eq!(got, want, "trial {trial} nq={nq} nl={nl}");
        }
    }

    #[test]
    fn engine_handles_empty_limbo_and_chunking() {
        let Some(e) = engine() else { return };
        let inp = AdmissionInputs {
            query_hashes: (0..3000).map(hash_key).collect(),
            limbo_hashes: vec![],
            commit_age_us: 0,
            delta_us: 1_000_000,
            own_term_commit: false,
        };
        let got = e.admit(&inp).unwrap();
        assert_eq!(got.len(), 3000);
        assert!(got.iter().all(|&b| b));
    }

    #[test]
    fn engine_falls_back_when_limbo_huge() {
        let Some(e) = engine() else { return };
        let inp = AdmissionInputs {
            query_hashes: vec![hash_key(1)],
            limbo_hashes: (0..10_000).map(hash_key).collect(),
            commit_age_us: 0,
            delta_us: 1_000_000,
            own_term_commit: false,
        };
        let got = e.admit(&inp).unwrap();
        assert_eq!(got, scalar_admission(&inp));
        assert!(e.stats().scalar_fallbacks > 0);
    }
}
