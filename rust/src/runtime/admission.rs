//! Read-admission ABI shared between the scalar fallback and the XLA
//! engine. Mirrors `python/compile/model.py` exactly — the pytest suite
//! pins the Python side to `ref.py`, and `engine::tests` pins the Rust
//! execution of the artifact to [`scalar_admission`], closing the loop.

use crate::Micros;

/// Padding sentinel for unused limbo slots (i32::MIN); reserved — no
/// real key may hash to it (see [`hash_key`]).
pub const PAD_SENTINEL: i32 = i32::MIN;

/// Hash a key id into the i32 hash space of the kernel ABI.
///
/// Collisions are harmless in one direction only: a colliding read is
/// spuriously *rejected* (conservative), never wrongly admitted —
/// rejection just means the client retries after the lease resolves.
/// The sentinel is remapped so padding can never match a query.
#[inline]
pub fn hash_key(key: u32) -> i32 {
    // splitmix-style avalanche, folded to 32 bits.
    let mut z = (key as u64).wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    let h = (z ^ (z >> 31)) as u32 as i32;
    if h == PAD_SENTINEL {
        0x5EED
    } else {
        h
    }
}

/// Inputs to one batched admission decision (the Layer-2 model's ABI).
#[derive(Debug, Clone)]
pub struct AdmissionInputs {
    /// Hashes of the keys the queued reads touch (one per read).
    pub query_hashes: Vec<i32>,
    /// Hashes of keys written in the limbo region.
    pub limbo_hashes: Vec<i32>,
    /// Conservative age of the newest committed entry, µs
    /// (`now.latest - entry.earliest`).
    pub commit_age_us: Micros,
    /// Lease duration Δ, µs.
    pub delta_us: Micros,
    /// Newest committed entry is in the leader's own term (no limbo
    /// restriction applies).
    pub own_term_commit: bool,
}

/// Scalar reference implementation of the admission decision — the
/// oracle the XLA engine is tested against, and the non-engine path.
pub fn scalar_admission(inp: &AdmissionInputs) -> Vec<bool> {
    let lease_valid = inp.commit_age_us < inp.delta_us;
    inp.query_hashes
        .iter()
        .map(|q| {
            if !lease_valid {
                return false;
            }
            if inp.own_term_commit {
                return true;
            }
            !inp.limbo_hashes.iter().any(|l| l == q && *l != PAD_SENTINEL)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_never_emits_sentinel() {
        for k in 0..200_000u32 {
            assert_ne!(hash_key(k), PAD_SENTINEL);
        }
    }

    #[test]
    fn hash_distinct_for_small_keyspace() {
        // The experiments use ≤ 4096 keys; hashes must be collision-free
        // there so conflict checks are exact, not merely conservative.
        let mut hs: Vec<i32> = (0..4096).map(hash_key).collect();
        hs.sort_unstable();
        hs.dedup();
        assert_eq!(hs.len(), 4096);
    }

    #[test]
    fn scalar_rules() {
        let base = AdmissionInputs {
            query_hashes: vec![1, 2, 3],
            limbo_hashes: vec![2],
            commit_age_us: 10,
            delta_us: 100,
            own_term_commit: false,
        };
        assert_eq!(scalar_admission(&base), vec![true, false, true]);
        // Expired lease rejects all.
        let mut e = base.clone();
        e.commit_age_us = 100;
        assert_eq!(scalar_admission(&e), vec![false, false, false]);
        // Own-term commit ignores limbo.
        let mut o = base.clone();
        o.own_term_commit = true;
        assert_eq!(scalar_admission(&o), vec![true, true, true]);
        // Sentinel in limbo never matches.
        let mut s = base.clone();
        s.query_hashes = vec![PAD_SENTINEL];
        s.limbo_hashes = vec![PAD_SENTINEL];
        assert_eq!(scalar_admission(&s), vec![true]);
    }
}
