//! Mini property-based testing framework (proptest is unavailable in the
//! offline image; see DESIGN.md substitutions).
//!
//! Deliberately small: seeded case generation from [`crate::prob::Rng`],
//! a fixed case budget, and on failure a greedy *shrink* over a
//! user-supplied simplification function. Used by `rust/tests/
//! properties.rs` to explore randomized fault schedules against the
//! protocol invariants.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use crate::prob::Rng;

/// Configuration for one property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE, max_shrink_steps: 200 }
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult<T> {
    Ok { cases: usize },
    Failed { case: T, seed: u64, message: String },
}

/// Run `prop` over `cases` generated inputs. On failure, greedily apply
/// `shrink` (which proposes simpler candidates) while the property still
/// fails, then report the minimal failing case.
pub fn check<T, G, S, P>(cfg: PropConfig, mut gen: G, mut shrink: S, mut prop: P) -> PropResult<T>
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    S: FnMut(&T) -> Vec<T>,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for i in 0..cfg.cases {
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let case = gen(&mut case_rng);
        if let Err(msg) = prop(&case) {
            // Shrink.
            let mut best = case;
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in shrink(&best) {
                    steps += 1;
                    if steps >= cfg.max_shrink_steps {
                        break 'outer;
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            eprintln!(
                "property failed on case {i} (seed {case_seed:#x}) after shrinking:\n{best:?}\n{best_msg}"
            );
            return PropResult::Failed { case: best, seed: case_seed, message: best_msg };
        }
    }
    PropResult::Ok { cases: cfg.cases }
}

/// Panic unless the property holds (test-friendly wrapper).
pub fn assert_prop<T, G, S, P>(cfg: PropConfig, gen: G, shrink: S, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    S: FnMut(&T) -> Vec<T>,
    P: FnMut(&T) -> Result<(), String>,
{
    match check(cfg, gen, shrink, prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed { case, seed, message } => {
            panic!("property failed (seed {seed:#x}): {message}\nminimal case: {case:?}")
        }
    }
}

/// A unique scratch directory under the system temp dir, removed on
/// drop. For storage/recovery tests that need real files; the name is
/// disambiguated by pid + a process-wide counter so parallel test
/// threads (and stale dirs from a killed run) never collide.
pub struct TempDir {
    path: std::path::PathBuf,
}

impl TempDir {
    pub fn new(name: &str) -> Self {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir()
            .join(format!("leaseguard-{name}-{}-{n}", std::process::id()));
        // A leftover dir from a previous killed run with the same pid is
        // stale state, not ours: clear it.
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempDir { path }
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Shrinker for vectors: propose dropping halves, then single elements.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = v.len();
    if n == 0 {
        return out;
    }
    out.push(v[..n / 2].to_vec());
    out.push(v[n / 2..].to_vec());
    if n <= 16 {
        for i in 0..n {
            let mut c = v.to_vec();
            c.remove(i);
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let r = check(
            PropConfig { cases: 50, ..Default::default() },
            |rng| rng.below(100) as i64,
            |_| vec![],
            |&x| if x < 100 { Ok(()) } else { Err("too big".into()) },
        );
        assert!(matches!(r, PropResult::Ok { cases: 50 }));
    }

    #[test]
    fn failing_property_shrinks() {
        // Property: no vector contains an element >= 40. Shrinking should
        // find a near-minimal counterexample.
        let r = check(
            PropConfig { cases: 100, ..Default::default() },
            |rng| (0..rng.below(20)).map(|_| rng.below(50) as i64).collect::<Vec<_>>(),
            |v| shrink_vec(v),
            |v| {
                if v.iter().all(|&x| x < 40) {
                    Ok(())
                } else {
                    Err("element >= 40".into())
                }
            },
        );
        match r {
            PropResult::Failed { case, .. } => {
                assert!(case.len() <= 2, "shrunk to near-minimal: {case:?}");
            }
            _ => panic!("expected failure"),
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut seen = Vec::new();
            let _ = check(
                PropConfig { cases: 10, seed: 7, ..Default::default() },
                |rng| rng.next_u64(),
                |_| vec![],
                |&x| {
                    seen.push(x);
                    Ok(())
                },
            );
            seen
        };
        assert_eq!(run(), run());
    }
}
