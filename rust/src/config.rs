//! The parameter system — the paper's `params.py` (§6.1): every aspect of
//! the protocol and the simulation is controlled from one struct that can
//! be loaded from a config file and overridden from the CLI.
//!
//! File format: one `key = value` per line, `#` comments. No external
//! crates are available offline, so parsing is hand-rolled; typed access
//! goes through [`Params::set`], which validates keys and values so typos
//! fail loudly instead of silently using defaults.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::collections::BTreeMap;
use std::fmt;
use std::str::FromStr;

use crate::Micros;

/// Which mechanism guarantees (or doesn't) read consistency — the six
/// configurations evaluated in the paper (Figs 7, 9, 10, 11).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ConsistencyMode {
    /// No mechanism: local reads, may violate linearizability during
    /// elections ("inconsistent" in the figures).
    Inconsistent,
    /// Raft's default: a quorum round per read (§1, LogCabin default).
    Quorum,
    /// Ongaro §6.4.1 leases as implemented for comparison (§7.1):
    /// heartbeat-acquired lease + vote withholding, Δ = ET.
    OngaroLease,
    /// LeaseGuard log-based lease, no optimizations ("log-based lease").
    LogLease,
    /// + deferred-commit writes (§3.2) ("defer commit").
    DeferCommit,
    /// + inherited-lease reads (§3.3) — full LeaseGuard.
    LeaseGuard,
}

impl ConsistencyMode {
    pub const ALL: [ConsistencyMode; 6] = [
        ConsistencyMode::Inconsistent,
        ConsistencyMode::Quorum,
        ConsistencyMode::OngaroLease,
        ConsistencyMode::LogLease,
        ConsistencyMode::DeferCommit,
        ConsistencyMode::LeaseGuard,
    ];

    /// Does this mode gate commits/reads on log-based leases?
    pub fn uses_log_lease(self) -> bool {
        matches!(
            self,
            ConsistencyMode::LogLease | ConsistencyMode::DeferCommit | ConsistencyMode::LeaseGuard
        )
    }

    /// Deferred-commit writes enabled?
    pub fn defers_commit(self) -> bool {
        matches!(self, ConsistencyMode::DeferCommit | ConsistencyMode::LeaseGuard)
    }

    /// Inherited-lease reads enabled?
    pub fn inherited_reads(self) -> bool {
        matches!(self, ConsistencyMode::LeaseGuard)
    }
}

impl fmt::Display for ConsistencyMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            ConsistencyMode::Inconsistent => "inconsistent",
            ConsistencyMode::Quorum => "quorum",
            ConsistencyMode::OngaroLease => "ongaro",
            ConsistencyMode::LogLease => "loglease",
            ConsistencyMode::DeferCommit => "defercommit",
            ConsistencyMode::LeaseGuard => "leaseguard",
        };
        f.write_str(s)
    }
}

impl FromStr for ConsistencyMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "inconsistent" | "none" => Ok(ConsistencyMode::Inconsistent),
            "quorum" => Ok(ConsistencyMode::Quorum),
            "ongaro" | "ongarolease" => Ok(ConsistencyMode::OngaroLease),
            "loglease" | "log-lease" | "lease" => Ok(ConsistencyMode::LogLease),
            "defercommit" | "defer-commit" | "defer" => Ok(ConsistencyMode::DeferCommit),
            "leaseguard" | "lease-guard" | "full" => Ok(ConsistencyMode::LeaseGuard),
            other => Err(format!(
                "unknown consistency mode '{other}' (want one of: inconsistent, quorum, \
                 ongaro, loglease, defercommit, leaseguard)"
            )),
        }
    }
}

/// All protocol + simulation + workload parameters.
#[derive(Debug, Clone)]
pub struct Params {
    // ---- replica set / protocol ----
    pub nodes: usize,
    /// Independent Raft groups hosted per process (multi-Raft sharding).
    /// The keyspace is hash-partitioned across groups by
    /// [`crate::shard::ShardMap`]; each group runs the full protocol
    /// (its own leader, lease, limbo) unchanged. Max 64 (status
    /// bitmasks are u64).
    pub groups: usize,
    pub consistency: ConsistencyMode,
    /// Election timeout ET, µs (paper: 500 ms in sims; 12-300 ms in [42];
    /// production 1-10 s).
    pub election_timeout_us: Micros,
    /// Randomized extra election timeout spread, µs (Raft §5.2).
    pub election_jitter_us: Micros,
    /// Lease duration Δ, µs. §5.2: Δ = ET usually optimal; availability
    /// experiments use Δ = 2·ET = 1 s to expose the transition window.
    pub lease_duration_us: Micros,
    /// Leader heartbeat interval, µs.
    pub heartbeat_us: Micros,
    /// Proactively renew the lease with a no-op when the newest entry is
    /// older than this fraction of Δ (§5.1). 0 disables.
    pub lease_renew_fraction: f64,
    /// Max entries per AppendEntries message.
    pub max_entries_per_append: usize,
    /// Take a state-machine snapshot and compact the log once
    /// `commit_index - log.base()` reaches this many entries. 0 disables
    /// compaction entirely (the default): with it off, every code path
    /// is byte-identical to the pre-snapshot protocol, which is what the
    /// fixed-seed determinism guard pins.
    pub snapshot_threshold: u64,

    // ---- clocks ----
    pub clock_error_us: Micros,
    pub clock_drift: f64,
    /// §4.3 failure injection: clock bounds deliberately wrong.
    pub clock_broken: bool,

    // ---- network (simulation) ----
    pub net_mean_us: f64,
    pub net_variance_us2: f64,
    pub net_min_delay_us: Micros,
    pub net_loss: f64,

    // ---- workload ----
    /// Open-loop: one operation starts every `interarrival_us` on average.
    pub interarrival_us: f64,
    /// Poisson (true) vs fixed-rate (false) arrivals.
    pub poisson_arrivals: bool,
    pub write_fraction: f64,
    pub num_keys: usize,
    pub zipf_a: f64,
    pub value_bytes: usize,
    /// Total simulated/real duration of the experiment, µs.
    pub duration_us: Micros,
    /// Client-observed operation timeout, µs (fail-fast bound).
    pub op_timeout_us: Micros,

    // ---- fault schedule ----
    /// Crash the leader at this time (0 = never), µs.
    pub crash_leader_at_us: Micros,
    /// Restart the crashed node this long after the crash (0 = never).
    pub restart_after_us: Micros,
    /// Partition the leader from its peers (but not from clients) at
    /// this time (0 = never) — the §1 deposed-leader scenario.
    pub partition_leader_at_us: Micros,
    /// Heal the partition this long after it forms (0 = never).
    pub heal_after_us: Micros,
    /// Probability a client op goes to a random node instead of the
    /// believed leader — models the paper's many concurrent clients,
    /// some of which still talk to a deposed leader.
    pub client_stray_prob: f64,

    // ---- engine / misc ----
    pub seed: u64,
    /// Use the XLA batched read-admission engine (Layer 1/2) when the
    /// leader filters queued reads against the limbo region.
    pub use_xla_admission: bool,
    /// Directory holding AOT artifacts.
    pub artifacts_dir: String,
    /// Throughput bucket width for availability timelines, µs.
    pub bucket_us: Micros,
    /// Flight recorder on/off (off ⇒ zero-capacity ring: record is a
    /// branch + return, nothing is stored). Tracing never perturbs sim
    /// determinism either way — see `determinism_guard_tracing`.
    pub flight_recorder: bool,
    /// Events retained per node's flight-recorder ring.
    pub flight_recorder_capacity: usize,
}

impl Default for Params {
    fn default() -> Self {
        Params {
            nodes: 3,
            groups: 1,
            consistency: ConsistencyMode::LeaseGuard,
            election_timeout_us: 500_000,
            election_jitter_us: 150_000,
            lease_duration_us: 1_000_000,
            heartbeat_us: 75_000,
            lease_renew_fraction: 0.5,
            max_entries_per_append: 1024,
            snapshot_threshold: 0,
            clock_error_us: 50,
            clock_drift: 1e-5,
            clock_broken: false,
            net_mean_us: 191.0,
            net_variance_us2: 391.0,
            net_min_delay_us: 20,
            net_loss: 0.0,
            interarrival_us: 300.0,
            poisson_arrivals: true,
            write_fraction: 1.0 / 3.0,
            num_keys: 1000,
            zipf_a: 0.0,
            value_bytes: 1024,
            duration_us: 3_000_000,
            op_timeout_us: 10_000_000,
            crash_leader_at_us: 0,
            restart_after_us: 0,
            partition_leader_at_us: 0,
            heal_after_us: 0,
            client_stray_prob: 0.0,
            seed: 1,
            use_xla_admission: false,
            artifacts_dir: "artifacts".to_string(),
            bucket_us: 50_000,
            flight_recorder: true,
            flight_recorder_capacity: 1024,
        }
    }
}

impl Params {
    /// Set one parameter by name. Returns an error naming valid keys on
    /// any mismatch.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        fn p<T: FromStr>(k: &str, v: &str) -> Result<T, String>
        where
            T::Err: fmt::Display,
        {
            v.parse().map_err(|e| format!("bad value for {k}: '{v}' ({e})"))
        }
        match key {
            "nodes" => self.nodes = p(key, value)?,
            "groups" => self.groups = p(key, value)?,
            "consistency" => self.consistency = p(key, value)?,
            "election_timeout_us" => self.election_timeout_us = p(key, value)?,
            "election_jitter_us" => self.election_jitter_us = p(key, value)?,
            "lease_duration_us" => self.lease_duration_us = p(key, value)?,
            "heartbeat_us" => self.heartbeat_us = p(key, value)?,
            "lease_renew_fraction" => self.lease_renew_fraction = p(key, value)?,
            "max_entries_per_append" => self.max_entries_per_append = p(key, value)?,
            "snapshot_threshold" => self.snapshot_threshold = p(key, value)?,
            "clock_error_us" => self.clock_error_us = p(key, value)?,
            "clock_drift" => self.clock_drift = p(key, value)?,
            "clock_broken" => self.clock_broken = p(key, value)?,
            "net_mean_us" => self.net_mean_us = p(key, value)?,
            "net_variance_us2" => self.net_variance_us2 = p(key, value)?,
            "net_min_delay_us" => self.net_min_delay_us = p(key, value)?,
            "net_loss" => self.net_loss = p(key, value)?,
            "interarrival_us" => self.interarrival_us = p(key, value)?,
            "poisson_arrivals" => self.poisson_arrivals = p(key, value)?,
            "write_fraction" => self.write_fraction = p(key, value)?,
            "num_keys" => self.num_keys = p(key, value)?,
            "zipf_a" => self.zipf_a = p(key, value)?,
            "value_bytes" => self.value_bytes = p(key, value)?,
            "duration_us" => self.duration_us = p(key, value)?,
            "op_timeout_us" => self.op_timeout_us = p(key, value)?,
            "crash_leader_at_us" => self.crash_leader_at_us = p(key, value)?,
            "restart_after_us" => self.restart_after_us = p(key, value)?,
            "partition_leader_at_us" => self.partition_leader_at_us = p(key, value)?,
            "heal_after_us" => self.heal_after_us = p(key, value)?,
            "client_stray_prob" => self.client_stray_prob = p(key, value)?,
            "seed" => self.seed = p(key, value)?,
            "use_xla_admission" => self.use_xla_admission = p(key, value)?,
            "artifacts_dir" => self.artifacts_dir = value.to_string(),
            "bucket_us" => self.bucket_us = p(key, value)?,
            "flight_recorder" => self.flight_recorder = p(key, value)?,
            "flight_recorder_capacity" => self.flight_recorder_capacity = p(key, value)?,
            other => return Err(format!("unknown parameter '{other}'")),
        }
        Ok(())
    }

    /// Parse a config file body (`key = value` lines, `#` comments).
    pub fn apply_file(&mut self, body: &str) -> Result<(), String> {
        for (lineno, raw) in body.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value, got '{raw}'", lineno + 1))?;
            self.set(k.trim(), v.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        }
        Ok(())
    }

    /// Sanity-check cross-parameter invariants before a run.
    pub fn validate(&self) -> Result<(), String> {
        if self.nodes < 1 || self.nodes % 2 == 0 {
            return Err(format!("nodes must be odd and >= 1, got {}", self.nodes));
        }
        if !(1..=crate::shard::MAX_GROUPS).contains(&self.groups) {
            return Err(format!(
                "groups must be in 1..={}, got {}",
                crate::shard::MAX_GROUPS,
                self.groups
            ));
        }
        if !(0.0..=1.0).contains(&self.write_fraction) {
            return Err("write_fraction must be in [0,1]".into());
        }
        if self.num_keys == 0 {
            return Err("num_keys must be > 0".into());
        }
        if self.election_timeout_us <= 0 || self.lease_duration_us <= 0 {
            return Err("timeouts must be positive".into());
        }
        if self.heartbeat_us >= self.election_timeout_us {
            return Err("heartbeat_us must be < election_timeout_us".into());
        }
        Ok(())
    }

    /// Dump as sorted key=value lines (for EXPERIMENTS.md provenance).
    pub fn dump(&self) -> String {
        let mut m = BTreeMap::new();
        m.insert("nodes", self.nodes.to_string());
        m.insert("groups", self.groups.to_string());
        m.insert("consistency", self.consistency.to_string());
        m.insert("election_timeout_us", self.election_timeout_us.to_string());
        m.insert("election_jitter_us", self.election_jitter_us.to_string());
        m.insert("lease_duration_us", self.lease_duration_us.to_string());
        m.insert("heartbeat_us", self.heartbeat_us.to_string());
        m.insert("lease_renew_fraction", self.lease_renew_fraction.to_string());
        m.insert("snapshot_threshold", self.snapshot_threshold.to_string());
        m.insert("clock_error_us", self.clock_error_us.to_string());
        m.insert("clock_drift", self.clock_drift.to_string());
        m.insert("clock_broken", self.clock_broken.to_string());
        m.insert("net_mean_us", self.net_mean_us.to_string());
        m.insert("net_variance_us2", self.net_variance_us2.to_string());
        m.insert("net_loss", self.net_loss.to_string());
        m.insert("interarrival_us", self.interarrival_us.to_string());
        m.insert("poisson_arrivals", self.poisson_arrivals.to_string());
        m.insert("write_fraction", self.write_fraction.to_string());
        m.insert("num_keys", self.num_keys.to_string());
        m.insert("zipf_a", self.zipf_a.to_string());
        m.insert("value_bytes", self.value_bytes.to_string());
        m.insert("duration_us", self.duration_us.to_string());
        m.insert("crash_leader_at_us", self.crash_leader_at_us.to_string());
        m.insert("seed", self.seed.to_string());
        m.insert("use_xla_admission", self.use_xla_admission.to_string());
        m.insert("flight_recorder", self.flight_recorder.to_string());
        m.insert("flight_recorder_capacity", self.flight_recorder_capacity.to_string());
        m.iter().map(|(k, v)| format!("{k} = {v}\n")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        Params::default().validate().unwrap();
    }

    #[test]
    fn set_and_roundtrip() {
        let mut p = Params::default();
        p.set("consistency", "quorum").unwrap();
        p.set("lease_duration_us", "2000000").unwrap();
        p.set("zipf_a", "0.5").unwrap();
        assert_eq!(p.consistency, ConsistencyMode::Quorum);
        assert_eq!(p.lease_duration_us, 2_000_000);
        assert!((p.zipf_a - 0.5).abs() < 1e-12);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut p = Params::default();
        assert!(p.set("no_such_param", "1").is_err());
    }

    #[test]
    fn bad_value_rejected_with_context() {
        let mut p = Params::default();
        let err = p.set("nodes", "three").unwrap_err();
        assert!(err.contains("nodes"), "{err}");
    }

    #[test]
    fn file_parsing_with_comments() {
        let mut p = Params::default();
        p.apply_file(
            "# availability experiment\nconsistency = leaseguard\n\nelection_timeout_us = 500000 # ET\n",
        )
        .unwrap();
        assert_eq!(p.consistency, ConsistencyMode::LeaseGuard);
        assert_eq!(p.election_timeout_us, 500_000);
    }

    #[test]
    fn file_errors_name_line() {
        let mut p = Params::default();
        let err = p.apply_file("consistency = leaseguard\ngarbage line\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn validation_rejects_even_nodes() {
        let mut p = Params::default();
        p.nodes = 4;
        assert!(p.validate().is_err());
    }

    #[test]
    fn validation_bounds_groups() {
        let mut p = Params::default();
        p.set("groups", "16").unwrap();
        p.validate().unwrap();
        p.groups = 0;
        assert!(p.validate().is_err());
        p.groups = 65;
        assert!(p.validate().is_err());
    }

    #[test]
    fn mode_flags_match_paper_matrix() {
        use ConsistencyMode::*;
        assert!(!Inconsistent.uses_log_lease() && !Quorum.uses_log_lease());
        assert!(LogLease.uses_log_lease() && !LogLease.defers_commit());
        assert!(DeferCommit.defers_commit() && !DeferCommit.inherited_reads());
        assert!(LeaseGuard.defers_commit() && LeaseGuard.inherited_reads());
    }

    #[test]
    fn mode_parse_all_names() {
        for m in ConsistencyMode::ALL {
            let s = m.to_string();
            assert_eq!(s.parse::<ConsistencyMode>().unwrap(), m);
        }
    }
}
