//! Core identifier and result types shared across the Raft stack.

/// Election term. Starts at 0 (no leader ever elected), increments on
/// each candidacy.
pub type Term = u64;

/// 1-based log position; 0 means "nothing" (before the first entry).
pub type Index = u64;

/// Client operation identifier, unique per run (assigned by the workload
/// generator / client library, echoed back in replies).
pub type OpId = u64;

/// A key's append-only value list, shared rather than copied: the store
/// keeps one `Arc` per key and reads hand out clones of the pointer, so
/// the node's read-serving hot path never copies the vector. Writers go
/// through `Arc::make_mut` (copy-on-write), which only copies while a
/// read result is still alive and referencing the same list.
pub type Values = std::sync::Arc<Vec<u64>>;

/// Raft node role.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Follower,
    Candidate,
    Leader,
}

/// Why an operation failed (client-visible; the figures bucket these).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailReason {
    /// This node is not the leader; hint may name the real one.
    NotLeader,
    /// Lease modes: no valid lease to serve a local read, and the
    /// implementation is configured fail-fast (paper Fig 7 note).
    NoLease,
    /// Inherited-lease reads: the key is affected by a limbo-region
    /// entry (paper §3.3).
    LimboConflict,
    /// LogLease mode without deferred commits: writes rejected while the
    /// commit gate is closed.
    CommitGateClosed,
    /// Leader deposed/crashed while the op was pending — the op *may*
    /// have succeeded (ambiguous; the linearizability checker branches).
    MaybeCommitted,
    /// Client-side timeout (open-loop client gave up waiting).
    Timeout,
}

/// Result of a client operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OpResult {
    WriteOk,
    /// The append-only list for the key, in commit order (§6.1).
    /// `Values` (an `Arc`) keeps the reply path allocation-free.
    ReadOk(Values),
    Failed(FailReason),
}

impl OpResult {
    pub fn is_ok(&self) -> bool {
        !matches!(self, OpResult::Failed(_))
    }
}

/// Timers a node may request from its driver (simulator or real server).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimerKind {
    /// Election timeout check (followers/candidates).
    Election,
    /// Leader heartbeat tick.
    Heartbeat,
    /// Re-evaluate the commit gate / lease renewal (§3.2, §5.1).
    LeaseCheck,
}
