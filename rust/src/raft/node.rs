//! The Raft + LeaseGuard node: one state machine, pure over timestamped
//! inputs, driven by the simulator ([`crate::cluster`]) and the real TCP
//! server ([`crate::server`]) alike.
//!
//! Every entry point takes the node's current clock reading and returns
//! [`Output`] actions. The node never reads a clock, spawns a thread, or
//! touches a socket — which is what makes runs deterministic under the
//! simulator and the protocol logic identical across both testbeds.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::collections::{HashSet, VecDeque};
use std::sync::Arc;

use crate::clock::TimeInterval;
use crate::config::{ConsistencyMode, Params};
use crate::kv::{store::ReadOutcome, Command, Store};
use crate::lease::{LeaseGuardState, OngaroState, ReadGate};
use crate::obs::{EventKind, FlightRecorder};
use crate::prob::Rng;
use crate::shard::GroupId;
use crate::snap::{self, SnapContents, SnapMeta, Snapshot, MAX_SNAPSHOT_BYTES, SNAP_CHUNK_BYTES};
use crate::{Micros, NodeId};

use super::batch::EntryBatch;
use super::log::{Entry, Log};
use super::message::Message;
use super::types::{FailReason, Index, OpId, OpResult, Role, Term, TimerKind};

/// Protocol-relevant subset of [`Params`].
#[derive(Debug, Clone)]
pub struct NodeConfig {
    pub id: NodeId,
    pub n: usize,
    pub mode: ConsistencyMode,
    pub election_timeout_us: Micros,
    pub election_jitter_us: Micros,
    pub lease_duration_us: Micros,
    pub heartbeat_us: Micros,
    /// §5.1 proactive renewal threshold as a fraction of Δ (0 = off).
    pub lease_renew_fraction: f64,
    pub max_entries_per_append: usize,
    /// Raft group this node serves — stamps flight-recorder events.
    pub group: GroupId,
    /// Flight-recorder ring capacity (0 = tracing disabled).
    pub recorder_capacity: usize,
    /// Take a state-machine snapshot and compact the log once this many
    /// applied entries have accumulated above the compaction base.
    /// 0 = compaction disabled (the log grows without bound).
    pub snapshot_threshold: u64,
}

impl NodeConfig {
    pub fn from_params(id: NodeId, p: &Params) -> Self {
        NodeConfig {
            id,
            n: p.nodes,
            mode: p.consistency,
            election_timeout_us: p.election_timeout_us,
            election_jitter_us: p.election_jitter_us,
            lease_duration_us: p.lease_duration_us,
            heartbeat_us: p.heartbeat_us,
            lease_renew_fraction: p.lease_renew_fraction,
            max_entries_per_append: p.max_entries_per_append,
            group: 0,
            recorder_capacity: if p.flight_recorder { p.flight_recorder_capacity } else { 0 },
            snapshot_threshold: p.snapshot_threshold,
        }
    }

    /// Same protocol config, stamped for Raft group `g` (multi-Raft
    /// drivers construct one node per group).
    pub fn for_group(mut self, g: GroupId) -> Self {
        self.group = g;
        self
    }

    fn majority(&self) -> usize {
        self.n / 2 + 1
    }
}

/// Actions a node asks its driver to perform.
#[derive(Debug, Clone, PartialEq)]
pub enum Output {
    /// Send `msg` to node `to`.
    Send { to: NodeId, msg: Message },
    /// Complete a client operation.
    Reply { op: OpId, result: OpResult },
    /// (Re)arm a timer `after` µs from now. Only the latest request per
    /// kind need be honored; stale firings are re-validated by the node.
    SetTimer { kind: TimerKind, after: Micros },
    /// A Put command was applied to the local state machine (true commit
    /// visibility — the omniscient linearizability checker's input).
    Applied { key: u32, value: u64 },
    /// Informational: this node just won an election for `term`.
    ElectedLeader { term: Term },
    /// Informational: this node stepped down from leading.
    SteppedDown,
}

/// A client write waiting for its entry to commit.
#[derive(Debug, Clone)]
struct PendingWrite {
    op: OpId,
    index: Index,
}

/// A quorum (ReadIndex) read waiting for a heartbeat round + apply.
#[derive(Debug, Clone)]
struct PendingQuorumRead {
    op: OpId,
    key: u32,
    read_index: Index,
    seq: u64,
}

/// The leader's materialized batch for the current replication round:
/// `arc` holds log entries `(base, base + arc.len()]`. Per-peer sends
/// take offset views into it instead of re-copying the log segment.
/// Only consulted while leader; leader logs are append-only, so a cached
/// segment can never go stale mid-leadership (it is dropped on any role
/// change or log rewrite).
#[derive(Debug)]
struct BatchCache {
    /// Exclusive lower bound (the `prev_index` of the widest view).
    base: Index,
    arc: Arc<[Entry]>,
}

/// Follower-side reassembly buffer for an in-progress snapshot
/// transfer. Volatile: a crash mid-transfer simply restarts it.
#[derive(Debug)]
struct SnapRecv {
    /// Transfer identity — the sender's snapshot boundary.
    last_index: Index,
    last_term: Term,
    buf: Vec<u8>,
}

/// Per-run protocol counters (merged into figure outputs).
#[derive(Debug, Clone, Copy, Default)]
pub struct NodeStats {
    pub elections_won: u64,
    pub noops_written: u64,
    pub reads_served_local: u64,
    /// Subset of `reads_served_local` answered under an *inherited*
    /// lease (valid prior-term lease, own-term commit still pending —
    /// §3.3, the paper's headline optimization).
    pub reads_served_inherited: u64,
    pub reads_served_quorum: u64,
    /// Quorum reads parked awaiting their ReadIndex round.
    pub reads_deferred: u64,
    pub reads_rejected_no_lease: u64,
    pub reads_rejected_limbo: u64,
    pub writes_accepted: u64,
    pub writes_rejected_gate: u64,
    pub commit_gate_blocks: u64,
    pub append_entries_sent: u64,
    /// Snapshots this node took of its own state machine (compactions).
    pub snapshots_taken: u64,
    /// Snapshots received over the wire and installed wholesale.
    pub snapshots_installed: u64,
    /// Inbound snapshots rejected (undecodable or boundary mismatch).
    pub snapshots_rejected: u64,
}

/// Raft's durable state — what a node persists and recovers after a
/// crash (Raft §5: `currentTerm`, `votedFor`, `log[]`). Everything else
/// in [`Node`] is volatile by construction: both cold boot and crash
/// recovery funnel through [`Node::boot`], which takes exactly this
/// struct, so a field can only survive a restart by being carried here.
///
/// The split encodes two safety obligations at the type level:
///
/// * `voted_for` **must** survive — forgetting it lets a restarted node
///   grant a second vote in the same term (double vote ⇒ two leaders in
///   one term ⇒ Election Safety violated).
/// * lease state (`lease`, `ongaro`, `heard_leader_at`) **must not**
///   survive — a rebooted node cannot trust pre-crash wall-clock
///   promises; in LeaseGuard the timestamped log *is* the lease, so
///   recovering the log is what re-derives lease state on the next
///   election (§3).
#[derive(Debug, Default)]
pub struct DurableState {
    pub current_term: Term,
    pub voted_for: Option<NodeId>,
    pub log: Log,
    /// Newest durable state-machine snapshot, if the log has ever been
    /// compacted. Its boundary equals the log's compaction base: boot
    /// rebuilds the store from it and resumes applying at `base + 1`.
    /// Note what is *absent*: the snapshot carries state-machine bytes
    /// only — lease and Ongaro state stay volatile even across a
    /// snapshot-assisted recovery (same §3 argument as the log).
    pub snapshot: Option<Snapshot>,
}

#[derive(Debug)]
pub struct Node {
    pub cfg: NodeConfig,
    rng: Rng,

    // ---- persistent state (mirrors [`DurableState`]; survives
    // crash/restart via [`Node::restart`]) ----
    current_term: Term,
    voted_for: Option<NodeId>,
    log: Log,
    /// Newest snapshot (boundary == `log.base()` whenever the log is
    /// compacted). Serves wire transfers and rides [`DurableState`].
    durable_snap: Option<Snapshot>,

    // ---- volatile ----
    role: Role,
    commit_index: Index,
    leader_hint: Option<NodeId>,
    store: Store,
    /// Local scalar clock (now.earliest) of the last AppendEntries from
    /// a current leader — election-timeout and Ongaro vote-withholding
    /// basis.
    heard_leader_at: Micros,
    election_deadline: Micros,

    // ---- candidate ----
    votes: HashSet<NodeId>,

    // ---- leader ----
    next_index: Vec<Index>,
    match_index: Vec<Index>,
    inflight: Vec<bool>,
    ae_seq: u64,
    last_ack_seq: Vec<u64>,
    pending_writes: VecDeque<PendingWrite>,
    pending_reads: Vec<PendingQuorumRead>,
    lease: Option<LeaseGuardState>,
    ongaro: Option<OngaroState>,
    batch_cache: Option<BatchCache>,
    /// Per-peer outbound snapshot transfer: `Some(next_offset)` while a
    /// stop-and-wait transfer is active (leader only, volatile).
    snap_offset: Vec<Option<usize>>,
    /// Inbound transfer reassembly buffer (follower only, volatile).
    snap_recv: Option<SnapRecv>,
    /// Snapshot taken/installed since the driver last drained it — the
    /// real server persists this (atomic file write + WAL segment
    /// rotation) before routing any of the same batch's outputs, the
    /// same persist-before-route discipline as the WAL itself. The
    /// simulator leaves it in place (virtual time has no disks).
    pending_snap: Option<Snapshot>,

    pub stats: NodeStats,
    /// Protocol-event flight recorder (obs). Like `stats`, this is
    /// observability, not node state: it survives [`Self::restart`] so
    /// a dump can show events from before AND after a crash.
    recorder: FlightRecorder,
}

impl Node {
    /// Create a fresh node. Returns the initial timer requests.
    pub fn new(cfg: NodeConfig, seed: u64, now: TimeInterval) -> (Self, Vec<Output>) {
        let rng = Rng::new(seed ^ (cfg.id as u64).wrapping_mul(0x9E3779B97F4A7C15));
        Self::boot(cfg, rng, DurableState::default(), now)
    }

    /// Boot from externally-recovered durable state (the real-mode
    /// [`crate::storage::Storage`] path). Identical to [`Self::new`]
    /// except the durable triple comes from disk; every volatile field —
    /// role, commit index, and above all lease state — starts at zero,
    /// exactly as [`Self::restart`] guarantees in the simulator.
    pub fn recover(
        cfg: NodeConfig,
        seed: u64,
        durable: DurableState,
        now: TimeInterval,
    ) -> (Self, Vec<Output>) {
        let rng = Rng::new(seed ^ (cfg.id as u64).wrapping_mul(0x9E3779B97F4A7C15));
        Self::boot(cfg, rng, durable, now)
    }

    /// Construct a node from its durable state, with every volatile
    /// field at its zero value. Cold boot ([`Self::new`]) and crash
    /// recovery ([`Self::restart`]) both funnel through here — the
    /// durable/volatile split is enforced by this signature, not by a
    /// field-by-field reset that can silently miss one.
    fn boot(
        cfg: NodeConfig,
        rng: Rng,
        durable: DurableState,
        now: TimeInterval,
    ) -> (Self, Vec<Output>) {
        let n = cfg.n;
        let recorder = FlightRecorder::new(cfg.recorder_capacity, cfg.group);
        // A snapshot is the one durable input to otherwise-volatile
        // state: the store and commit index restart from its boundary
        // (instead of zero) because the covered log prefix is gone and
        // can never be re-applied. Everything above the boundary is
        // re-derived exactly as before — and lease/Ongaro state is
        // re-derived from scratch, snapshot or not.
        let mut store = Store::new();
        let mut commit_index = 0;
        if let Some(s) = &durable.snapshot {
            if let Ok(c) = snap::decode(&s.data) {
                store.install(c.pairs, c.meta.applied);
                commit_index = c.meta.last_index;
            }
        }
        let mut node = Node {
            rng,
            cfg,
            current_term: durable.current_term,
            voted_for: durable.voted_for,
            log: durable.log,
            durable_snap: durable.snapshot,
            role: Role::Follower,
            commit_index,
            leader_hint: None,
            store,
            heard_leader_at: Micros::MIN,
            election_deadline: 0,
            votes: HashSet::new(),
            next_index: vec![1; n],
            match_index: vec![0; n],
            inflight: vec![false; n],
            ae_seq: 0,
            last_ack_seq: vec![0; n],
            pending_writes: VecDeque::new(),
            pending_reads: Vec::new(),
            lease: None,
            ongaro: None,
            batch_cache: None,
            snap_offset: vec![None; n],
            snap_recv: None,
            pending_snap: None,
            stats: NodeStats::default(),
            recorder,
        };
        let mut out = Vec::new();
        node.reset_election_deadline(now, &mut out);
        (node, out)
    }

    // ---------------------------------------------------------- accessors

    pub fn role(&self) -> Role {
        self.role
    }
    pub fn term(&self) -> Term {
        self.current_term
    }
    pub fn voted_for(&self) -> Option<NodeId> {
        self.voted_for
    }
    /// Drain the log's unpersisted-change watermark (see
    /// [`Log::take_dirty`]). Real-mode servers call this before routing
    /// outputs; the simulator leaves it untouched.
    pub fn take_log_dirty(&mut self) -> Option<(Index, bool)> {
        self.log.take_dirty()
    }
    /// Drain the snapshot taken/installed since the last drain. Real-mode
    /// servers persist it (atomic file + WAL segment rotation) before
    /// routing outputs, mirroring [`Self::take_log_dirty`].
    pub fn take_pending_snap(&mut self) -> Option<Snapshot> {
        self.pending_snap.take()
    }
    /// The newest snapshot this node holds (boundary == log base when
    /// the log is compacted).
    pub fn durable_snapshot(&self) -> Option<&Snapshot> {
        self.durable_snap.as_ref()
    }
    pub fn commit_index(&self) -> Index {
        self.commit_index
    }
    pub fn log(&self) -> &Log {
        &self.log
    }
    pub fn store(&self) -> &Store {
        &self.store
    }
    pub fn leader_hint(&self) -> Option<NodeId> {
        if self.role == Role::Leader {
            Some(self.cfg.id)
        } else {
            self.leader_hint
        }
    }
    pub fn is_leader(&self) -> bool {
        self.role == Role::Leader
    }
    pub fn lease_state(&self) -> Option<&LeaseGuardState> {
        self.lease.as_ref()
    }
    pub fn recorder(&self) -> &FlightRecorder {
        &self.recorder
    }
    pub fn recorder_mut(&mut self) -> &mut FlightRecorder {
        &mut self.recorder
    }

    /// Record a flight-recorder event at `now` under the current term.
    /// No clock reads, no RNG, no control-flow effects — tracing cannot
    /// perturb determinism (see `determinism_guard_tracing`).
    #[inline]
    fn trace(&mut self, now: TimeInterval, kind: EventKind, a: u64, b: u64) {
        self.recorder.record(Self::local_now(now), self.current_term, kind, a, b);
    }

    /// Conservative local scalar time used for timers and Ongaro leases.
    #[inline]
    fn local_now(now: TimeInterval) -> Micros {
        now.earliest
    }

    // ------------------------------------------------------------- timers

    fn reset_election_deadline(&mut self, now: TimeInterval, out: &mut Vec<Output>) {
        let jitter = if self.cfg.election_jitter_us > 0 {
            self.rng.range_i64(0, self.cfg.election_jitter_us)
        } else {
            0
        };
        let delay = self.cfg.election_timeout_us + jitter;
        self.election_deadline = Self::local_now(now) + delay;
        out.push(Output::SetTimer { kind: TimerKind::Election, after: delay });
    }

    pub fn on_timer(&mut self, now: TimeInterval, kind: TimerKind) -> Vec<Output> {
        let mut out = Vec::new();
        match kind {
            TimerKind::Election => self.on_election_timer(now, &mut out),
            TimerKind::Heartbeat => self.on_heartbeat_timer(now, &mut out),
            TimerKind::LeaseCheck => self.on_lease_check(now, &mut out),
        }
        out
    }

    fn on_election_timer(&mut self, now: TimeInterval, out: &mut Vec<Output>) {
        if self.role == Role::Leader {
            return;
        }
        let local = Self::local_now(now);
        if local < self.election_deadline {
            // Deadline was pushed out (heartbeats received); re-arm.
            out.push(Output::SetTimer {
                kind: TimerKind::Election,
                after: self.election_deadline - local,
            });
            return;
        }
        self.start_election(now, out);
    }

    fn on_heartbeat_timer(&mut self, now: TimeInterval, out: &mut Vec<Output>) {
        if self.role != Role::Leader {
            return;
        }
        // One round id for the whole fan-out; aligned peers then carry
        // byte-identical messages the transport can encode once.
        self.ae_seq += 1;
        let seq = self.ae_seq;
        for peer in self.peers() {
            self.inflight[peer] = false; // heartbeat overrides the window
            self.send_append_with_seq(peer, seq, now, out);
        }
        out.push(Output::SetTimer { kind: TimerKind::Heartbeat, after: self.cfg.heartbeat_us });
    }

    fn on_lease_check(&mut self, now: TimeInterval, out: &mut Vec<Output>) {
        if self.role != Role::Leader {
            return;
        }
        // The commit gate may have opened (§3.2 deferred commits drain
        // here, producing the paper's post-election write spike).
        self.try_advance_commit(now, out);
        // §5.1 proactive lease renewal: if the newest entry is aging and
        // nothing newer is in flight, write a no-op.
        if self.cfg.mode.uses_log_lease() && self.cfg.lease_renew_fraction > 0.0 {
            let threshold =
                (self.cfg.lease_duration_us as f64 * self.cfg.lease_renew_fraction) as Micros;
            let needs_renewal = match self.log.get(self.log.last_index()) {
                None => true,
                Some(e) => e.written_at.max_age(now) > threshold,
            };
            if needs_renewal {
                self.append_local(Command::Noop, now);
                self.stats.noops_written += 1;
                self.replicate_all(now, out);
            }
        }
        out.push(Output::SetTimer { kind: TimerKind::LeaseCheck, after: self.cfg.heartbeat_us });
    }

    // ---------------------------------------------------------- elections

    fn start_election(&mut self, now: TimeInterval, out: &mut Vec<Output>) {
        self.current_term += 1;
        self.trace(now, EventKind::ElectionStarted, self.current_term, 0);
        self.role = Role::Candidate;
        self.voted_for = Some(self.cfg.id);
        self.votes.clear();
        self.votes.insert(self.cfg.id);
        self.leader_hint = None;
        self.reset_election_deadline(now, out);
        if self.votes.len() >= self.cfg.majority() {
            self.become_leader(now, out); // single-node replica set
            return;
        }
        let msg = Message::RequestVote {
            term: self.current_term,
            candidate: self.cfg.id,
            last_log_index: self.log.last_index(),
            last_log_term: self.log.last_term(),
        };
        for peer in self.peers() {
            out.push(Output::Send { to: peer, msg: msg.clone() });
        }
    }

    fn become_leader(&mut self, now: TimeInterval, out: &mut Vec<Output>) {
        self.role = Role::Leader;
        self.leader_hint = Some(self.cfg.id);
        self.stats.elections_won += 1;
        // The log may have been truncated while following; any cached
        // batch from a previous leadership is untrustworthy.
        self.batch_cache = None;
        let last = self.log.last_index();
        for p in 0..self.cfg.n {
            self.next_index[p] = last + 1;
            self.match_index[p] = 0;
            self.inflight[p] = false;
            self.last_ack_seq[p] = 0;
            self.snap_offset[p] = None;
        }
        self.match_index[self.cfg.id] = last;
        // A leader is nobody's snapshot sink.
        self.snap_recv = None;
        // LeaseGuard state: prior leader's lease deadline + limbo region
        // (paper §3.1-§3.3), fixed at election.
        if self.cfg.mode.uses_log_lease() {
            let st = LeaseGuardState::at_election(
                &self.log,
                self.current_term,
                self.commit_index,
                self.cfg.lease_duration_us,
            );
            // Install limbo keys in the state machine (§7.1's
            // setLimboRegion) when inherited reads are enabled.
            if self.cfg.mode.inherited_reads() {
                if let Some((lo, hi)) = st.limbo_range() {
                    let cmds: Vec<Command> =
                        self.log.iter_range(lo, hi).map(|(_, e)| e.command).collect();
                    self.store.set_limbo_region(cmds.iter());
                } else {
                    self.store.set_limbo_region([].iter());
                }
            }
            let limbo_hi = st.limbo_range().map(|(_, hi)| hi).unwrap_or(0);
            self.trace(now, EventKind::LeaseInherited, st.limbo_len(), limbo_hi);
            self.lease = Some(st);
            out.push(Output::SetTimer { kind: TimerKind::LeaseCheck, after: self.cfg.heartbeat_us });
        }
        if self.cfg.mode == ConsistencyMode::OngaroLease {
            self.ongaro = Some(OngaroState::new(self.cfg.n, self.cfg.id));
        }
        // Term-start no-op (standard Raft; in LeaseGuard it will become
        // this leader's lease once the commit gate opens and it commits).
        self.append_local(Command::Noop, now);
        self.stats.noops_written += 1;
        self.replicate_all(now, out);
        out.push(Output::SetTimer { kind: TimerKind::Heartbeat, after: self.cfg.heartbeat_us });
        let limbo = self.lease.as_ref().map(|l| l.limbo_len()).unwrap_or(0);
        self.trace(now, EventKind::ElectionWon, limbo, 0);
        out.push(Output::ElectedLeader { term: self.current_term });
    }

    fn step_down(&mut self, now: TimeInterval, new_term: Term, out: &mut Vec<Output>) {
        let was_leader = self.role == Role::Leader;
        if new_term > self.current_term {
            self.current_term = new_term;
            self.voted_for = None;
        }
        self.role = Role::Follower;
        self.votes.clear();
        self.lease = None;
        self.ongaro = None;
        self.batch_cache = None;
        // Outbound transfer windows are leader state; drop them.
        for o in self.snap_offset.iter_mut() {
            *o = None;
        }
        self.store.set_limbo_region([].iter());
        // Pending writes may have replicated and may yet commit: the
        // client must treat them as ambiguous (§6.2; checker branches).
        for w in self.pending_writes.drain(..) {
            out.push(Output::Reply { op: w.op, result: OpResult::Failed(FailReason::MaybeCommitted) });
        }
        for r in self.pending_reads.drain(..) {
            out.push(Output::Reply { op: r.op, result: OpResult::Failed(FailReason::NotLeader) });
        }
        if was_leader {
            self.trace(now, EventKind::SteppedDown, self.current_term, 0);
            out.push(Output::SteppedDown);
        }
    }

    // ----------------------------------------------------------- messages

    pub fn on_message(&mut self, now: TimeInterval, msg: Message) -> Vec<Output> {
        let mut out = Vec::new();
        // Term gossip (§2.1): any higher term converts us to follower.
        if msg.term() > self.current_term {
            self.step_down(now, msg.term(), &mut out);
            self.reset_election_deadline(now, &mut out);
        }
        match msg {
            Message::RequestVote { term, candidate, last_log_index, last_log_term } => {
                self.on_request_vote(now, term, candidate, last_log_index, last_log_term, &mut out)
            }
            Message::VoteReply { term, voter, granted } => {
                self.on_vote_reply(now, term, voter, granted, &mut out)
            }
            Message::AppendEntries { term, leader, prev_index, prev_term, entries, leader_commit, seq } => {
                self.on_append_entries(
                    now, term, leader, prev_index, prev_term, entries, leader_commit, seq, &mut out,
                )
            }
            Message::AppendReply { term, from, success, match_index, seq } => {
                self.on_append_reply(now, term, from, success, match_index, seq, &mut out)
            }
            Message::SnapInstall { term, leader, last_index, last_term, offset, data, done, seq } => {
                self.on_snap_install(
                    now, term, leader, last_index, last_term, offset, data, done, seq, &mut out,
                )
            }
            Message::SnapAck { term, from, last_index, offset, installed, seq } => {
                self.on_snap_ack(now, term, from, last_index, offset, installed, seq, &mut out)
            }
        }
        out
    }

    fn on_request_vote(
        &mut self,
        now: TimeInterval,
        term: Term,
        candidate: NodeId,
        last_log_index: Index,
        last_log_term: Term,
        out: &mut Vec<Output>,
    ) {
        let mut granted = false;
        if term == self.current_term && self.role == Role::Follower {
            // Ongaro-mode vote withholding (§7.1): a follower that heard
            // from a leader less than Δ ago refuses to vote. LeaseGuard
            // deliberately does NOT do this (§3 "Elections").
            let withheld = self.cfg.mode == ConsistencyMode::OngaroLease
                && self.heard_leader_at != Micros::MIN
                && Self::local_now(now) - self.heard_leader_at < self.cfg.lease_duration_us;
            let can_vote = self.voted_for.is_none() || self.voted_for == Some(candidate);
            if !withheld
                && can_vote
                && self.log.candidate_up_to_date(last_log_term, last_log_index)
            {
                granted = true;
                self.voted_for = Some(candidate);
                self.reset_election_deadline(now, out);
            }
        }
        out.push(Output::Send {
            to: candidate,
            msg: Message::VoteReply { term: self.current_term, voter: self.cfg.id, granted },
        });
    }

    fn on_vote_reply(
        &mut self,
        now: TimeInterval,
        term: Term,
        voter: NodeId,
        granted: bool,
        out: &mut Vec<Output>,
    ) {
        if self.role != Role::Candidate || term != self.current_term || !granted {
            return;
        }
        self.votes.insert(voter);
        if self.votes.len() >= self.cfg.majority() {
            self.become_leader(now, out);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn on_append_entries(
        &mut self,
        now: TimeInterval,
        term: Term,
        leader: NodeId,
        prev_index: Index,
        prev_term: Term,
        entries: EntryBatch,
        leader_commit: Index,
        seq: u64,
        out: &mut Vec<Output>,
    ) {
        if term < self.current_term {
            out.push(Output::Send {
                to: leader,
                msg: Message::AppendReply {
                    term: self.current_term,
                    from: self.cfg.id,
                    success: false,
                    match_index: 0,
                    seq,
                },
            });
            return;
        }
        // Equal term: a candidate yields to the elected leader.
        if self.role != Role::Follower {
            self.step_down(now, term, out);
        }
        self.leader_hint = Some(leader);
        self.heard_leader_at = Self::local_now(now);
        // Randomized per-receipt jitter (Raft §5.2): follower deadlines
        // must diverge or simultaneous timeouts split the vote and delay
        // failover past the paper's ~ET recovery point.
        let jitter = if self.cfg.election_jitter_us > 0 {
            self.rng.range_i64(0, self.cfg.election_jitter_us)
        } else {
            0
        };
        self.election_deadline = Self::local_now(now) + self.cfg.election_timeout_us + jitter;

        // Log consistency check.
        let success = match self.log.term_at(prev_index) {
            Some(t) if t == prev_term => true,
            _ => false,
        };
        let mut match_index = 0;
        if success {
            // Append, truncating on conflict (Raft §5.3). Entries are
            // `Copy` scalars read straight out of the shared batch — no
            // per-entry heap work on the follower ingest path.
            let mut idx = prev_index;
            for &e in entries.iter() {
                idx += 1;
                // Entries at or below the compaction base are already
                // covered by our snapshot (a delayed append can overlap
                // a prefix we compacted): committed state, skip.
                if idx <= self.log.base() {
                    continue;
                }
                match self.log.term_at(idx) {
                    Some(t) if t == e.term => { /* duplicate, skip */ }
                    Some(_) => {
                        self.log.truncate_after(idx - 1);
                        self.log.append(e);
                    }
                    None => {
                        self.log.append(e);
                    }
                }
            }
            match_index = idx;
            // Advance follower commitIndex and apply (followers keep
            // their state machine warm so a newly elected leader can
            // serve inherited-lease reads immediately, §3.3).
            let new_commit = leader_commit.min(self.log.last_index());
            if new_commit > self.commit_index {
                self.apply_range(self.commit_index + 1, new_commit, out);
                self.commit_index = new_commit;
                self.maybe_take_snapshot(now);
            }
        }
        out.push(Output::Send {
            to: leader,
            msg: Message::AppendReply {
                term: self.current_term,
                from: self.cfg.id,
                success,
                match_index,
                seq,
            },
        });
    }

    fn on_append_reply(
        &mut self,
        now: TimeInterval,
        term: Term,
        from: NodeId,
        success: bool,
        match_index: Index,
        seq: u64,
        out: &mut Vec<Output>,
    ) {
        if self.role != Role::Leader || term != self.current_term {
            return;
        }
        self.inflight[from] = false;
        self.last_ack_seq[from] = self.last_ack_seq[from].max(seq);
        if let Some(o) = self.ongaro.as_mut() {
            o.record_ack(from, seq);
        }
        if success {
            if match_index > self.match_index[from] {
                self.match_index[from] = match_index;
            }
            self.next_index[from] = self.next_index[from].max(match_index + 1);
            self.try_advance_commit(now, out);
            self.serve_ready_quorum_reads(now, out);
            // Continue catch-up if the follower is still behind.
            if self.next_index[from] <= self.log.last_index() {
                self.send_append(from, now, out);
            }
        } else {
            // Back up and retry (coarse: halve toward 1). If this walks
            // next_index below the compaction base, the resend routes to
            // a snapshot transfer (see `send_append_with_seq`).
            let ni = &mut self.next_index[from];
            *ni = (*ni / 2).max(1);
            self.send_append(from, now, out);
        }
    }

    /// Follower side of InstallSnapshot: buffer chunks, and on the final
    /// one decode + install the state machine wholesale. Lease and
    /// Ongaro state are deliberately untouched — a snapshot carries
    /// committed state-machine contents only, and volatile lease state
    /// must stay volatile (same argument as [`Self::restart`]).
    #[allow(clippy::too_many_arguments)]
    fn on_snap_install(
        &mut self,
        now: TimeInterval,
        term: Term,
        leader: NodeId,
        last_index: Index,
        last_term: Term,
        offset: u64,
        data: Vec<u8>,
        done: bool,
        seq: u64,
        out: &mut Vec<Output>,
    ) {
        let nack = |me: &Self, offset: u64, installed: bool| Output::Send {
            to: leader,
            msg: Message::SnapAck {
                term: me.current_term,
                from: me.cfg.id,
                last_index,
                offset,
                installed,
                seq,
            },
        };
        if term < self.current_term {
            out.push(nack(self, 0, false));
            return;
        }
        // Equal term: a candidate yields to the elected leader, and this
        // counts as leader contact exactly like AppendEntries does.
        if self.role != Role::Follower {
            self.step_down(now, term, out);
        }
        self.leader_hint = Some(leader);
        self.heard_leader_at = Self::local_now(now);
        let jitter = if self.cfg.election_jitter_us > 0 {
            self.rng.range_i64(0, self.cfg.election_jitter_us)
        } else {
            0
        };
        self.election_deadline = Self::local_now(now) + self.cfg.election_timeout_us + jitter;

        // Everything through the boundary is already committed here (a
        // stale leader view, e.g. after its restart): report success so
        // the leader resumes AppendEntries at last_index + 1.
        if last_index <= self.commit_index {
            self.snap_recv = None;
            out.push(nack(self, offset.saturating_add(data.len() as u64), true));
            return;
        }
        let mut buf = match self.snap_recv.take() {
            // In-order continuation of the transfer we were buffering.
            Some(r)
                if r.last_index == last_index
                    && r.last_term == last_term
                    && r.buf.len() == offset as usize =>
            {
                r.buf
            }
            // Duplicate or reordered old chunk (chaos nets): keep the
            // buffer and report actual progress so the leader realigns.
            Some(r)
                if r.last_index == last_index
                    && r.last_term == last_term
                    && (offset as usize) < r.buf.len() =>
            {
                let got = r.buf.len() as u64;
                self.snap_recv = Some(r);
                out.push(nack(self, got, false));
                return;
            }
            // A fresh transfer may start any time the chunk is at 0.
            _ if offset == 0 => Vec::new(),
            // Gap (or a different snapshot mid-buffer): restart.
            _ => {
                out.push(nack(self, 0, false));
                return;
            }
        };
        if buf.len().saturating_add(data.len()) > MAX_SNAPSHOT_BYTES {
            self.stats.snapshots_rejected += 1;
            self.trace(now, EventKind::SnapshotRejected, last_index, buf.len() as u64);
            out.push(nack(self, 0, false));
            return;
        }
        buf.extend_from_slice(&data);
        if !done {
            let got = buf.len() as u64;
            self.snap_recv = Some(SnapRecv { last_index, last_term, buf });
            out.push(nack(self, got, false));
            return;
        }
        let size = buf.len() as u64;
        match snap::decode(&buf) {
            Ok(c)
                if c.meta.last_index == last_index
                    && c.meta.last_term == last_term
                    && c.meta.group == self.cfg.group =>
            {
                self.install_snapshot(now, c, buf);
                out.push(nack(self, size, true));
            }
            _ => {
                // Undecodable or boundary/group mismatch: refuse and ask
                // for a restart rather than install corrupt state.
                self.stats.snapshots_rejected += 1;
                self.trace(now, EventKind::SnapshotRejected, last_index, size);
                out.push(nack(self, 0, false));
            }
        }
    }

    /// Install decoded snapshot contents wholesale: replace the store,
    /// move the log's compaction base (keeping any matching suffix),
    /// and advance the commit index to the boundary. The covered
    /// entries were committed by definition, so this never un-commits
    /// anything. Volatile lease state is NOT resurrected — the limbo
    /// region is cleared by the store install and `lease`/`ongaro`
    /// stay `None` (a follower holds none to begin with).
    fn install_snapshot(&mut self, now: TimeInterval, c: SnapContents, payload: Vec<u8>) {
        let meta = c.meta;
        let size = payload.len() as u64;
        self.store.install(c.pairs, meta.applied);
        self.log.install_snapshot_meta(meta.last_index, meta.last_term, meta.last_written_at);
        self.commit_index = self.commit_index.max(meta.last_index);
        self.batch_cache = None;
        let s = Snapshot { meta, data: Arc::new(payload) };
        self.durable_snap = Some(s.clone());
        self.pending_snap = Some(s);
        self.stats.snapshots_installed += 1;
        self.trace(now, EventKind::SnapshotInstalled, meta.last_index, size);
    }

    /// Leader side of the transfer: advance the stop-and-wait window on
    /// progress acks, and on `installed` resume ordinary AppendEntries
    /// from the boundary.
    #[allow(clippy::too_many_arguments)]
    fn on_snap_ack(
        &mut self,
        now: TimeInterval,
        term: Term,
        from: NodeId,
        last_index: Index,
        offset: u64,
        installed: bool,
        seq: u64,
        out: &mut Vec<Output>,
    ) {
        if self.role != Role::Leader || term != self.current_term {
            return;
        }
        self.inflight[from] = false;
        self.last_ack_seq[from] = self.last_ack_seq[from].max(seq);
        if let Some(o) = self.ongaro.as_mut() {
            o.record_ack(from, seq);
        }
        if installed {
            self.snap_offset[from] = None;
            if last_index > self.match_index[from] {
                self.match_index[from] = last_index;
            }
            self.next_index[from] = self.next_index[from].max(last_index + 1);
            self.try_advance_commit(now, out);
            self.serve_ready_quorum_reads(now, out);
            if self.next_index[from] <= self.log.last_index() {
                self.send_append(from, now, out);
            }
            return;
        }
        // Progress ack: trust the follower's buffered length as the next
        // offset — unless it is for a snapshot we since replaced, in
        // which case the transfer restarts from zero.
        let current = self.durable_snap.as_ref().map(|s| s.meta.last_index);
        self.snap_offset[from] =
            if current == Some(last_index) { Some(offset as usize) } else { Some(0) };
        self.send_append(from, now, out);
    }

    /// Compact once the applied prefix outgrows the configured
    /// threshold: move the log base to the commit index, serialize the
    /// store at that boundary, and queue the snapshot for the driver to
    /// persist. With `snapshot_threshold == 0` (the default) this is a
    /// no-op and a fixed-seed run is byte-identical to pre-compaction
    /// builds.
    fn maybe_take_snapshot(&mut self, now: TimeInterval) {
        let threshold = self.cfg.snapshot_threshold;
        if threshold == 0 {
            return;
        }
        let boundary = self.commit_index;
        if boundary <= self.log.base() || boundary - self.log.base() < threshold {
            return;
        }
        self.log.compact_to(boundary);
        self.batch_cache = None;
        let meta = SnapMeta {
            group: self.cfg.group,
            last_index: self.log.base(),
            last_term: self.log.base_term(),
            last_written_at: self.log.base_written_at(),
            applied: self.store.applied(),
        };
        let s = snap::encode(&self.store, meta);
        self.stats.snapshots_taken += 1;
        self.trace(now, EventKind::SnapshotTaken, meta.last_index, s.size() as u64);
        self.durable_snap = Some(s.clone());
        self.pending_snap = Some(s);
        // In-flight transfers of the replaced snapshot self-correct: the
        // next ack names the old boundary and restarts from offset 0.
    }

    // -------------------------------------------------------- replication

    fn peers(&self) -> impl Iterator<Item = NodeId> {
        let me = self.cfg.id;
        (0..self.cfg.n).filter(move |&p| p != me)
    }

    /// Send one AppendEntries to `peer` carrying entries from its
    /// next_index (bounded batch), or an empty heartbeat. Solo sends
    /// (catch-up, nack backoff) open their own one-message round.
    fn send_append(&mut self, peer: NodeId, now: TimeInterval, out: &mut Vec<Output>) {
        if self.inflight[peer] {
            return;
        }
        self.ae_seq += 1;
        let seq = self.ae_seq;
        self.send_append_with_seq(peer, seq, now, out);
    }

    /// The per-peer half of a replication round. Callers have already
    /// allocated the round id and cleared `inflight` where appropriate.
    /// Zero per-peer deep copies: the entry payload is a view into the
    /// round's shared batch (see [`Self::shared_entries`]).
    fn send_append_with_seq(
        &mut self,
        peer: NodeId,
        seq: u64,
        now: TimeInterval,
        out: &mut Vec<Output>,
    ) {
        if self.inflight[peer] {
            return;
        }
        let prev_index = self.next_index[peer] - 1;
        if prev_index < self.log.base() {
            // The entries this peer needs were compacted away: ship the
            // snapshot instead (Raft §7 InstallSnapshot). Same round id,
            // same inflight window — a snapshot chunk *is* this round's
            // message to the peer.
            self.send_snapshot_chunk(peer, seq, now, out);
            return;
        }
        let prev_term = self.log.term_at(prev_index).unwrap_or(0);
        let hi = self
            .log
            .last_index()
            .min(prev_index + self.cfg.max_entries_per_append as Index);
        let entries = self.shared_entries(prev_index, hi);
        if let Some(o) = self.ongaro.as_mut() {
            o.record_send(peer, seq, Self::local_now(now));
        }
        self.inflight[peer] = true;
        self.stats.append_entries_sent += 1;
        out.push(Output::Send {
            to: peer,
            msg: Message::AppendEntries {
                term: self.current_term,
                leader: self.cfg.id,
                prev_index,
                prev_term,
                entries,
                leader_commit: self.commit_index,
                seq,
            },
        });
    }

    /// Send the next chunk of the current snapshot to `peer`
    /// (stop-and-wait: one chunk per inflight window; the follower's
    /// ack opens the next). Offsets live in `snap_offset[peer]` and are
    /// only advanced by acks, so a lost chunk is retransmitted at the
    /// same offset on the next heartbeat round.
    fn send_snapshot_chunk(
        &mut self,
        peer: NodeId,
        seq: u64,
        now: TimeInterval,
        out: &mut Vec<Output>,
    ) {
        let Some(snap) = self.durable_snap.clone() else {
            // Unreachable in practice: base > 0 implies a snapshot was
            // taken or installed. Degrade by resyncing from the suffix
            // we still have rather than wedging replication.
            self.next_index[peer] = self.log.base() + 1;
            return;
        };
        let offset = self.snap_offset[peer].unwrap_or(0);
        let Some((chunk, done)) = snap.chunk(offset, SNAP_CHUNK_BYTES) else {
            // Offset out of range: the snapshot was replaced mid-transfer.
            // Restart from zero on the next round.
            self.snap_offset[peer] = Some(0);
            return;
        };
        self.snap_offset[peer] = Some(offset);
        if let Some(o) = self.ongaro.as_mut() {
            o.record_send(peer, seq, Self::local_now(now));
        }
        self.inflight[peer] = true;
        out.push(Output::Send {
            to: peer,
            msg: Message::SnapInstall {
                term: self.current_term,
                leader: self.cfg.id,
                last_index: snap.meta.last_index,
                last_term: snap.meta.last_term,
                offset: offset as u64,
                data: chunk.to_vec(),
                done,
                seq,
            },
        });
    }

    /// Entries `(lo, hi]` as a shared view: materialized into an `Arc`
    /// at most once per round, then handed to every peer by refcount.
    /// A peer at a different `next_index` (catch-up) re-centers the
    /// cache; aligned peers — the steady-state fan-out — always hit.
    fn shared_entries(&mut self, lo: Index, hi: Index) -> EntryBatch {
        if hi <= lo {
            return EntryBatch::empty();
        }
        if let Some(c) = &self.batch_cache {
            if c.base <= lo && hi <= c.base + c.arc.len() as Index {
                return EntryBatch::view(
                    c.arc.clone(),
                    (lo - c.base) as usize,
                    (hi - lo) as usize,
                );
            }
        }
        let arc: Arc<[Entry]> = Arc::from(self.log.slice(lo, hi));
        self.batch_cache = Some(BatchCache { base: lo, arc: arc.clone() });
        EntryBatch::view(arc, 0, (hi - lo) as usize)
    }

    fn replicate_all(&mut self, now: TimeInterval, out: &mut Vec<Output>) {
        // One round id + one materialized batch for the whole fan-out.
        self.ae_seq += 1;
        let seq = self.ae_seq;
        self.trace(now, EventKind::AppendFanout, self.log.last_index(), seq);
        for peer in self.peers() {
            self.send_append_with_seq(peer, seq, now, out);
        }
    }

    /// Force a fresh heartbeat round to every peer (quorum reads need a
    /// round that *starts* after the read arrives — ReadIndex). Returns
    /// the round's seq: every peer's send has seq >= it.
    fn force_round(&mut self, now: TimeInterval, out: &mut Vec<Output>) -> u64 {
        self.ae_seq += 1;
        let start_seq = self.ae_seq;
        for peer in self.peers() {
            self.inflight[peer] = false;
            self.send_append_with_seq(peer, start_seq, now, out);
        }
        start_seq
    }

    /// Append a command to the local log stamped with `intervalNow()`
    /// (Fig 2 lines 5-6).
    fn append_local(&mut self, command: Command, now: TimeInterval) -> Index {
        let e = Entry { term: self.current_term, command, written_at: now };
        let idx = self.log.append(e);
        self.match_index[self.cfg.id] = idx;
        idx
    }

    /// CommitEntry (Fig 2 lines 28-42): advance commitIndex to the
    /// highest majority-replicated own-term index, subject to the
    /// LeaseGuard commit gate.
    fn try_advance_commit(&mut self, now: TimeInterval, out: &mut Vec<Output>) {
        if self.role != Role::Leader {
            return;
        }
        // Highest index replicated on a majority.
        let mut m = self.match_index.clone();
        m.sort_unstable_by(|a, b| b.cmp(a));
        let candidate = m[self.cfg.majority() - 1];
        if candidate <= self.commit_index {
            return;
        }
        // Raft §5.4.2: only own-term entries commit by counting.
        if self.log.term_at(candidate) != Some(self.current_term) {
            return;
        }
        // LeaseGuard commit gate (Fig 2 lines 34-38): wait until the
        // deposed leader's lease provably expired.
        if let Some(lease) = &self.lease {
            if !lease.commit_gate_open(now) {
                self.stats.commit_gate_blocks += 1;
                let after = lease.gate_retry_after(now).max(100);
                self.trace(now, EventKind::CommitGateBlocked, candidate, 0);
                out.push(Output::SetTimer { kind: TimerKind::LeaseCheck, after });
                return;
            }
        }
        // §5.1 planned handover: committing our own end-lease entry is
        // the final act of this leadership — step down afterwards.
        let relinquishing = self
            .log
            .iter_range(self.commit_index, candidate)
            .any(|(_, e)| e.term == self.current_term && e.command == Command::EndLease);
        self.apply_range(self.commit_index + 1, candidate, out);
        self.commit_index = candidate;
        self.trace(now, EventKind::CommitAdvance, candidate, 0);
        self.maybe_take_snapshot(now);
        if relinquishing {
            // Ack everything committed, then relinquish leadership.
            while let Some(w) = self.pending_writes.front() {
                if w.index <= self.commit_index {
                    let w = self.pending_writes.pop_front().unwrap();
                    out.push(Output::Reply { op: w.op, result: OpResult::WriteOk });
                } else {
                    break;
                }
            }
            self.step_down(now, self.current_term, out);
            return;
        }
        let mut lease_acquired = false;
        if let Some(lease) = self.lease.as_mut() {
            if !lease.own_term_committed() {
                lease.on_own_term_commit();
                // Limbo region disappears (§3.3): clear the read gate.
                self.store.set_limbo_region([].iter());
                lease_acquired = true;
            }
        }
        if lease_acquired {
            self.trace(now, EventKind::LeaseAcquired, self.commit_index, 0);
        }
        // Acknowledge all writes whose entries just committed — under
        // deferred commits this is the paper's post-election ack burst.
        while let Some(w) = self.pending_writes.front() {
            if w.index <= self.commit_index {
                let w = self.pending_writes.pop_front().unwrap();
                out.push(Output::Reply { op: w.op, result: OpResult::WriteOk });
            } else {
                break;
            }
        }
    }

    fn apply_range(&mut self, from: Index, to: Index, out: &mut Vec<Output>) {
        for i in from..=to {
            let e = self.log.get(i).expect("applying missing entry");
            if let Command::Put { key, value, .. } = e.command {
                out.push(Output::Applied { key, value });
            }
            let cmd = e.command;
            self.store.apply(&cmd);
        }
    }

    // ------------------------------------------------------ client ops

    /// Handle a client write (Fig 2 ClientWrite).
    pub fn client_write(
        &mut self,
        now: TimeInterval,
        op: OpId,
        key: u32,
        value: u64,
        payload_bytes: u32,
    ) -> Vec<Output> {
        let mut out = Vec::new();
        if self.role != Role::Leader {
            out.push(Output::Reply { op, result: OpResult::Failed(FailReason::NotLeader) });
            return out;
        }
        // LogLease (unoptimized): fail-fast while the commit gate is
        // closed — the paper's Fig 7 "log-based lease" blackout. With
        // deferred commits (§3.2) the write is accepted and replicated,
        // and its ack waits for the gate.
        if self.cfg.mode == ConsistencyMode::LogLease {
            if let Some(lease) = &self.lease {
                if !lease.commit_gate_open(now) {
                    self.stats.writes_rejected_gate += 1;
                    self.trace(now, EventKind::WriteRejectedGate, key as u64, 0);
                    out.push(Output::Reply {
                        op,
                        result: OpResult::Failed(FailReason::CommitGateClosed),
                    });
                    return out;
                }
            }
        }
        let index = self.append_local(Command::Put { key, value, payload_bytes }, now);
        self.pending_writes.push_back(PendingWrite { op, index });
        self.stats.writes_accepted += 1;
        self.trace(now, EventKind::WriteAccepted, key as u64, index);
        self.replicate_all(now, &mut out);
        // Single-node replica set commits immediately.
        self.try_advance_commit(now, &mut out);
        out
    }

    /// Handle a client read (Fig 2 ClientRead).
    pub fn client_read(&mut self, now: TimeInterval, op: OpId, key: u32) -> Vec<Output> {
        let mut out = Vec::new();
        if self.role != Role::Leader {
            out.push(Output::Reply { op, result: OpResult::Failed(FailReason::NotLeader) });
            return out;
        }
        match self.cfg.mode {
            ConsistencyMode::Inconsistent => {
                self.stats.reads_served_local += 1;
                self.trace(now, EventKind::ReadServedLocal, key as u64, 0);
                out.push(Output::Reply { op, result: OpResult::ReadOk(self.store.read(key)) });
            }
            ConsistencyMode::Quorum => {
                // ReadIndex: snapshot commitIndex, require a heartbeat
                // round started after arrival to be majority-acked.
                self.stats.reads_deferred += 1;
                self.trace(now, EventKind::ReadDeferred, key as u64, 0);
                let seq = self.force_round(now, &mut out);
                self.pending_reads.push(PendingQuorumRead {
                    op,
                    key,
                    read_index: self.commit_index,
                    seq,
                });
                if self.cfg.n == 1 {
                    self.serve_ready_quorum_reads(now, &mut out);
                }
            }
            ConsistencyMode::OngaroLease => {
                let has = self
                    .ongaro
                    .as_ref()
                    .map(|o| o.has_lease(Self::local_now(now), self.cfg.lease_duration_us))
                    .unwrap_or(false)
                    || self.cfg.n == 1;
                if has {
                    self.stats.reads_served_local += 1;
                    self.trace(now, EventKind::ReadServedLocal, key as u64, 0);
                    out.push(Output::Reply { op, result: OpResult::ReadOk(self.store.read(key)) });
                } else {
                    self.stats.reads_rejected_no_lease += 1;
                    self.trace(now, EventKind::ReadRejectedNoLease, key as u64, 0);
                    out.push(Output::Reply { op, result: OpResult::Failed(FailReason::NoLease) });
                }
            }
            ConsistencyMode::LogLease | ConsistencyMode::DeferCommit | ConsistencyMode::LeaseGuard => {
                self.lease_read(now, op, key, &mut out);
            }
        }
        out
    }

    fn lease_read(&mut self, now: TimeInterval, op: OpId, key: u32, out: &mut Vec<Output>) {
        let inherited = self.cfg.mode.inherited_reads();
        let gate = self
            .lease
            .as_ref()
            .map(|l| l.read_gate(&self.log, self.current_term, self.commit_index, now, inherited))
            .unwrap_or(ReadGate::NoLease);
        match gate {
            ReadGate::Serve => {
                self.stats.reads_served_local += 1;
                self.trace(now, EventKind::ReadServedLocal, key as u64, 0);
                out.push(Output::Reply { op, result: OpResult::ReadOk(self.store.read(key)) });
            }
            ReadGate::ServeUnlessLimbo => match self.store.read_gated(key) {
                ReadOutcome::Values(v) => {
                    // Valid lease, own-term commit still pending: this
                    // read was served under the *inherited* lease.
                    self.stats.reads_served_local += 1;
                    self.stats.reads_served_inherited += 1;
                    self.trace(now, EventKind::ReadServedInherited, key as u64, 0);
                    out.push(Output::Reply { op, result: OpResult::ReadOk(v) });
                }
                ReadOutcome::LimboConflict => {
                    self.stats.reads_rejected_limbo += 1;
                    self.trace(now, EventKind::ReadRejectedLimbo, key as u64, 0);
                    out.push(Output::Reply {
                        op,
                        result: OpResult::Failed(FailReason::LimboConflict),
                    });
                }
            },
            ReadGate::NoLease => {
                self.stats.reads_rejected_no_lease += 1;
                self.trace(now, EventKind::ReadRejectedNoLease, key as u64, 0);
                // §5.1: when writes are rare, reestablish the lease with
                // a no-op so subsequent reads can be served.
                if self.cfg.lease_renew_fraction > 0.0
                    && self.log.last_index() == self.commit_index
                {
                    self.append_local(Command::Noop, now);
                    self.stats.noops_written += 1;
                    self.replicate_all(now, out);
                }
                out.push(Output::Reply { op, result: OpResult::Failed(FailReason::NoLease) });
            }
        }
    }

    /// Batched read admission — the Layer-1/2 integration point.
    ///
    /// In full-LeaseGuard mode on a leader, the whole batch is judged by
    /// one admission decision (lease age + limbo conflicts), computed by
    /// `admit` — either [`crate::runtime::scalar_admission`] or the XLA
    /// engine ([`crate::runtime::AdmissionEngine::admit`]); the protocol
    /// outcome is identical by construction (both implement the Fig 2
    /// ClientRead gate; tests pin them to each other). Other modes and
    /// non-leaders route through the per-op path.
    pub fn client_read_batch<F>(
        &mut self,
        now: TimeInterval,
        ops: &[(OpId, u32)],
        admit: F,
    ) -> Vec<Output>
    where
        F: FnOnce(&crate::runtime::AdmissionInputs) -> Vec<bool>,
    {
        use crate::runtime::{hash_key, AdmissionInputs};
        if self.role != Role::Leader || self.cfg.mode != ConsistencyMode::LeaseGuard {
            let mut out = Vec::new();
            for &(op, key) in ops {
                out.extend(self.client_read(now, op, key));
            }
            return out;
        }
        let Some(lease) = self.lease.as_ref() else {
            let mut out = Vec::new();
            for &(op, key) in ops {
                out.extend(self.client_read(now, op, key));
            }
            return out;
        };
        let status = lease.status(&self.log, self.current_term, self.commit_index, now);
        let limbo_hashes: Vec<i32> = if status.own_term_commit {
            Vec::new()
        } else {
            self.store.limbo_keys().map(hash_key).collect()
        };
        let inputs = AdmissionInputs {
            query_hashes: ops.iter().map(|&(_, k)| hash_key(k)).collect(),
            limbo_hashes,
            commit_age_us: status.commit_age_us.min(i32::MAX as Micros),
            delta_us: self.cfg.lease_duration_us.min(i32::MAX as Micros),
            own_term_commit: status.own_term_commit,
        };
        let mask = admit(&inputs);
        debug_assert_eq!(mask.len(), ops.len());
        let mut out = Vec::new();
        let mut renewed = false;
        for (&(op, key), &admitted) in ops.iter().zip(mask.iter()) {
            if admitted {
                self.stats.reads_served_local += 1;
                if status.own_term_commit {
                    self.trace(now, EventKind::ReadServedLocal, key as u64, 0);
                } else {
                    // Admitted while our own-term commit is pending: the
                    // inherited lease served this read (§3.3).
                    self.stats.reads_served_inherited += 1;
                    self.trace(now, EventKind::ReadServedInherited, key as u64, 0);
                }
                out.push(Output::Reply { op, result: OpResult::ReadOk(self.store.read(key)) });
            } else if !status.valid {
                self.stats.reads_rejected_no_lease += 1;
                self.trace(now, EventKind::ReadRejectedNoLease, key as u64, 0);
                if !renewed
                    && self.cfg.lease_renew_fraction > 0.0
                    && self.log.last_index() == self.commit_index
                {
                    renewed = true;
                    self.append_local(Command::Noop, now);
                    self.stats.noops_written += 1;
                    self.replicate_all(now, &mut out);
                }
                out.push(Output::Reply { op, result: OpResult::Failed(FailReason::NoLease) });
            } else {
                self.stats.reads_rejected_limbo += 1;
                self.trace(now, EventKind::ReadRejectedLimbo, key as u64, 0);
                out.push(Output::Reply { op, result: OpResult::Failed(FailReason::LimboConflict) });
            }
        }
        out
    }

    /// Serve quorum reads whose round is majority-acked (ReadIndex).
    fn serve_ready_quorum_reads(&mut self, now: TimeInterval, out: &mut Vec<Output>) {
        if self.pending_reads.is_empty() {
            return;
        }
        let majority = self.cfg.majority();
        let mut remaining = Vec::with_capacity(self.pending_reads.len());
        let reads = std::mem::take(&mut self.pending_reads);
        for r in reads {
            // Count self plus peers whose last ack round >= r.seq.
            let acks = 1 + self
                .peers_ack_count(r.seq);
            let applied_enough = self.commit_index >= r.read_index;
            if acks >= majority && applied_enough {
                self.stats.reads_served_quorum += 1;
                self.trace(now, EventKind::ReadServedQuorum, r.key as u64, 0);
                out.push(Output::Reply {
                    op: r.op,
                    result: OpResult::ReadOk(self.store.read(r.key)),
                });
            } else {
                remaining.push(r);
            }
        }
        self.pending_reads = remaining;
    }

    fn peers_ack_count(&self, seq: u64) -> usize {
        self.peers().filter(|&p| self.last_ack_seq[p] >= seq).count()
    }

    // -------------------------------------------------- crash / restart

    /// Crash recovery: rebuild the node from its [`DurableState`] alone
    /// (term, vote, log survive the reboot; everything volatile —
    /// commit index, applied store, lease/Ongaro state, replication
    /// windows, pending client ops — is reconstructed from zero by
    /// [`Self::boot`]). The driver isolates a crashed node via the
    /// network; this models the reboot itself.
    pub fn restart(&mut self, now: TimeInterval) -> Vec<Output> {
        let durable = DurableState {
            current_term: self.current_term,
            voted_for: self.voted_for,
            log: std::mem::take(&mut self.log),
            // The snapshot is durable by the time it exists (the real
            // driver persists it before routing; the sim treats the
            // in-memory copy as its disk) — it survives the reboot and
            // boot() reseeds the store from it.
            snapshot: self.durable_snap.take(),
        };
        // The RNG stream continues across the reboot (a fresh seed would
        // replay the pre-crash jitter sequence); stats and the flight
        // recorder are per-run observability, not node state, and keep
        // accumulating — a post-crash dump shows both incarnations.
        let rng = self.rng.clone();
        let stats = self.stats;
        let recorder = std::mem::replace(&mut self.recorder, FlightRecorder::disabled());
        let (node, out) = Self::boot(self.cfg.clone(), rng, durable, now);
        *self = node;
        self.stats = stats;
        self.recorder = recorder;
        out
    }

    /// Planned handover (§5.1): relinquish the lease by committing an
    /// end-lease entry, then step down once it commits. (Exposed for the
    /// maintenance-drain example; not used by the availability figures.)
    pub fn begin_stepdown(&mut self, now: TimeInterval) -> Vec<Output> {
        let mut out = Vec::new();
        if self.role != Role::Leader {
            return out;
        }
        self.append_local(Command::EndLease, now);
        self.replicate_all(now, &mut out);
        out
    }

    /// Test/driver hook: inject log entries (e.g. to set up limbo-region
    /// scenarios deterministically).
    #[doc(hidden)]
    pub fn debug_force_log(&mut self, entries: Vec<Entry>, commit: Index) {
        let mut out = Vec::new();
        self.batch_cache = None;
        for e in entries {
            self.log.append(e);
        }
        let commit = commit.min(self.log.last_index());
        if commit > self.commit_index {
            self.apply_range(self.commit_index + 1, commit, &mut out);
            self.commit_index = commit;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ET: Micros = 500_000;
    const DELTA: Micros = 1_000_000;

    fn cfg(id: NodeId, mode: ConsistencyMode) -> NodeConfig {
        NodeConfig {
            id,
            n: 3,
            mode,
            election_timeout_us: ET,
            election_jitter_us: 0,
            lease_duration_us: DELTA,
            heartbeat_us: 75_000,
            lease_renew_fraction: 0.5,
            max_entries_per_append: 1024,
            group: 0,
            recorder_capacity: 64,
            snapshot_threshold: 0,
        }
    }

    fn t(us: Micros) -> TimeInterval {
        TimeInterval::exact(us)
    }

    /// Drive node 0 to leadership at time `now` by faking votes.
    fn make_leader(mode: ConsistencyMode, now: TimeInterval) -> Node {
        let (mut n, _) = Node::new(cfg(0, mode), 1, t(0));
        let out = n.on_timer(now, TimerKind::Election);
        assert!(out.iter().any(|o| matches!(o, Output::Send { msg: Message::RequestVote { .. }, .. })));
        let term = n.term();
        let out = n.on_message(now, Message::VoteReply { term, voter: 1, granted: true });
        assert!(out.iter().any(|o| matches!(o, Output::ElectedLeader { .. })));
        assert!(n.is_leader());
        n
    }

    fn ack_all(n: &mut Node, now: TimeInterval, from: NodeId) -> Vec<Output> {
        // Ack everything the leader has. Each peer may have been sent a
        // different round seq (a real follower echoes the seq it got);
        // echo the last two rounds to cover this helper's callers.
        let seq = n.ae_seq;
        let mut out = n.on_message(
            now,
            Message::AppendReply {
                term: n.term(),
                from,
                success: true,
                match_index: n.log.last_index(),
                seq: seq.saturating_sub(1),
            },
        );
        out.extend(n.on_message(
            now,
            Message::AppendReply {
                term: n.term(),
                from,
                success: true,
                match_index: n.log.last_index(),
                seq,
            },
        ));
        out
    }

    #[test]
    fn election_happy_path() {
        let now = t(ET);
        let n = make_leader(ConsistencyMode::Inconsistent, now);
        assert_eq!(n.term(), 1);
        assert_eq!(n.log.last_index(), 1); // term-start noop
    }

    #[test]
    fn follower_grants_one_vote_per_term() {
        let (mut f, _) = Node::new(cfg(1, ConsistencyMode::Inconsistent), 2, t(0));
        let out = f.on_message(
            t(10),
            Message::RequestVote { term: 1, candidate: 0, last_log_index: 0, last_log_term: 0 },
        );
        assert!(matches!(
            out.last(),
            Some(Output::Send { msg: Message::VoteReply { granted: true, .. }, .. })
        ));
        // Second candidate, same term: denied.
        let out = f.on_message(
            t(20),
            Message::RequestVote { term: 1, candidate: 2, last_log_index: 5, last_log_term: 1 },
        );
        assert!(matches!(
            out.last(),
            Some(Output::Send { msg: Message::VoteReply { granted: false, .. }, .. })
        ));
    }

    #[test]
    fn vote_denied_to_stale_log() {
        let (mut f, _) = Node::new(cfg(1, ConsistencyMode::Inconsistent), 2, t(0));
        f.debug_force_log(
            vec![Entry { term: 1, command: Command::Noop, written_at: t(5) }],
            0,
        );
        f.current_term = 1;
        let out = f.on_message(
            t(10),
            Message::RequestVote { term: 2, candidate: 0, last_log_index: 0, last_log_term: 0 },
        );
        assert!(matches!(
            out.last(),
            Some(Output::Send { msg: Message::VoteReply { granted: false, .. }, .. })
        ));
    }

    #[test]
    fn write_commits_after_majority_ack() {
        let now = t(ET);
        let mut n = make_leader(ConsistencyMode::Inconsistent, now);
        ack_all(&mut n, now, 1); // commit the noop
        let out = n.client_write(t(ET + 1000), 42, 7, 700, 0);
        assert!(out.iter().any(|o| matches!(o, Output::Send { msg: Message::AppendEntries { .. }, .. })));
        assert!(!out.iter().any(|o| matches!(o, Output::Reply { .. })));
        let out = ack_all(&mut n, t(ET + 2000), 1);
        assert!(
            out.iter()
                .any(|o| matches!(o, Output::Reply { op: 42, result: OpResult::WriteOk })),
            "{out:?}"
        );
        assert_eq!(*n.store().read(7), vec![700]);
    }

    #[test]
    fn inconsistent_read_served_immediately() {
        let now = t(ET);
        let mut n = make_leader(ConsistencyMode::Inconsistent, now);
        let out = n.client_read(now, 1, 5);
        assert!(matches!(
            out.last(),
            Some(Output::Reply { result: OpResult::ReadOk(v), .. }) if v.is_empty()
        ));
    }

    #[test]
    fn quorum_read_waits_for_round() {
        let now = t(ET);
        let mut n = make_leader(ConsistencyMode::Quorum, now);
        ack_all(&mut n, now, 1);
        let out = n.client_read(t(ET + 100), 9, 3);
        // Not served yet: needs a majority-acked round.
        assert!(!out.iter().any(|o| matches!(o, Output::Reply { .. })));
        let out = ack_all(&mut n, t(ET + 300), 1);
        assert!(out
            .iter()
            .any(|o| matches!(o, Output::Reply { op: 9, result: OpResult::ReadOk(_) })));
    }

    #[test]
    fn leaseguard_read_denied_without_commit() {
        let now = t(ET);
        let mut n = make_leader(ConsistencyMode::LeaseGuard, now);
        // Nothing committed yet → no lease.
        let out = n.client_read(now, 5, 1);
        assert!(out
            .iter()
            .any(|o| matches!(o, Output::Reply { result: OpResult::Failed(FailReason::NoLease), .. })));
    }

    #[test]
    fn leaseguard_read_served_after_own_commit_then_expires() {
        let now = t(ET);
        let mut n = make_leader(ConsistencyMode::LeaseGuard, now);
        ack_all(&mut n, now, 1); // noop commits: fresh cluster, gate open
        let out = n.client_read(t(ET + 1000), 5, 1);
        assert!(out
            .iter()
            .any(|o| matches!(o, Output::Reply { result: OpResult::ReadOk(_), .. })));
        // After Δ with no writes, the lease expires → NoLease (+ renewal noop).
        let late = t(ET + DELTA + 10_000);
        let out = n.client_read(late, 6, 1);
        assert!(out
            .iter()
            .any(|o| matches!(o, Output::Reply { result: OpResult::Failed(FailReason::NoLease), .. })));
        // Renewal noop was appended and replicated.
        assert!(out.iter().any(|o| matches!(o, Output::Send { msg: Message::AppendEntries { .. }, .. })));
    }

    /// Build the paper's failover scene: node 1 replicated entries from a
    /// term-1 leader (some committed), then wins term 2.
    fn failover_leader(mode: ConsistencyMode) -> Node {
        let (mut n, _) = Node::new(cfg(1, mode), 3, t(0));
        // Replicate 3 entries from leader 0, the last written at 500ms;
        // leader_commit covers only the first.
        let entries = vec![
            Entry { term: 1, command: Command::Put { key: 1, value: 11, payload_bytes: 0 }, written_at: t(300_000) },
            Entry { term: 1, command: Command::Put { key: 2, value: 22, payload_bytes: 0 }, written_at: t(400_000) },
            Entry { term: 1, command: Command::Put { key: 3, value: 33, payload_bytes: 0 }, written_at: t(500_000) },
        ];
        n.on_message(
            t(500_100),
            Message::AppendEntries {
                term: 1,
                leader: 0,
                prev_index: 0,
                prev_term: 0,
                entries: entries.into(),
                leader_commit: 1,
                seq: 1,
            },
        );
        assert_eq!(n.commit_index(), 1);
        // Old leader crashes; node 1 times out and wins term 2.
        let now = t(1_100_000);
        n.on_timer(now, TimerKind::Election);
        assert_eq!(n.role(), Role::Candidate);
        n.on_message(now, Message::VoteReply { term: 2, voter: 2, granted: true });
        assert!(n.is_leader());
        n
    }

    #[test]
    fn commit_gate_blocks_until_prior_lease_expires() {
        let mut n = failover_leader(ConsistencyMode::LeaseGuard);
        // Majority-ack everything (noop at index 4).
        let now = t(1_000_200);
        ack_all(&mut n, now, 2);
        // Gate: last prior-term entry written at 500ms + Δ → 1.5s.
        assert_eq!(n.commit_index(), 1, "commit must be gated");
        assert!(n.stats.commit_gate_blocks > 0);
        // After the lease expires the LeaseCheck timer opens the gate.
        let late = t(1_500_200);
        n.on_timer(late, TimerKind::LeaseCheck);
        assert_eq!(n.commit_index(), 4);
    }

    #[test]
    fn defer_commit_accepts_writes_while_gated() {
        let mut n = failover_leader(ConsistencyMode::DeferCommit);
        // Clear the initial replication window so the write below sends
        // immediately (otherwise it rides the next heartbeat).
        ack_all(&mut n, t(1_000_250), 2);
        let now = t(1_000_300);
        let out = n.client_write(now, 77, 9, 900, 0);
        // Accepted (replication sent), not yet acked.
        assert!(out.iter().any(|o| matches!(o, Output::Send { .. })));
        assert!(!out.iter().any(|o| matches!(o, Output::Reply { .. })));
        // Ack from majority; still gated.
        ack_all(&mut n, t(1_000_400), 2);
        assert_eq!(n.commit_index(), 1);
        // Gate opens → deferred ack burst.
        let out = n.on_timer(t(1_500_300), TimerKind::LeaseCheck);
        assert!(out
            .iter()
            .any(|o| matches!(o, Output::Reply { op: 77, result: OpResult::WriteOk })));
        assert_eq!(n.commit_index(), 5);
    }

    #[test]
    fn logllease_rejects_writes_while_gated() {
        let mut n = failover_leader(ConsistencyMode::LogLease);
        let out = n.client_write(t(1_000_300), 77, 9, 900, 0);
        assert!(out.iter().any(|o| matches!(
            o,
            Output::Reply { result: OpResult::Failed(FailReason::CommitGateClosed), .. }
        )));
    }

    #[test]
    fn inherited_lease_reads_respect_limbo() {
        let mut n = failover_leader(ConsistencyMode::LeaseGuard);
        let now = t(1_100_000); // prior lease (from 500ms entry) valid till 1.5s
        // Limbo region = entries (1, 3]: keys 2 and 3.
        assert_eq!(n.lease_state().unwrap().limbo_len(), 2);
        // Key 1 (committed, not in limbo): served from inherited lease.
        let out = n.client_read(now, 1, 1);
        assert!(
            out.iter().any(
                |o| matches!(o, Output::Reply { result: OpResult::ReadOk(v), .. } if **v == vec![11])
            ),
            "{out:?}"
        );
        // Key 2 (in limbo): rejected.
        let out = n.client_read(now, 2, 2);
        assert!(out.iter().any(|o| matches!(
            o,
            Output::Reply { result: OpResult::Failed(FailReason::LimboConflict), .. }
        )));
    }

    #[test]
    fn loglease_has_no_inherited_reads() {
        let mut n = failover_leader(ConsistencyMode::LogLease);
        let out = n.client_read(t(1_100_000), 1, 1);
        assert!(out.iter().any(|o| matches!(
            o,
            Output::Reply { result: OpResult::Failed(FailReason::NoLease), .. }
        )));
    }

    #[test]
    fn limbo_clears_after_own_term_commit() {
        let mut n = failover_leader(ConsistencyMode::LeaseGuard);
        ack_all(&mut n, t(1_000_200), 2);
        n.on_timer(t(1_500_200), TimerKind::LeaseCheck); // gate opens, commits
        assert!(n.lease_state().unwrap().own_term_committed());
        // Key 2 now readable.
        let out = n.client_read(t(1_500_300), 3, 2);
        assert!(out.iter().any(
            |o| matches!(o, Output::Reply { result: OpResult::ReadOk(v), .. } if **v == vec![22])
        ));
    }

    #[test]
    fn ongaro_lease_from_heartbeat_acks() {
        let now = t(ET);
        let mut n = make_leader(ConsistencyMode::OngaroLease, now);
        // Before any ack: no lease.
        let out = n.client_read(now, 1, 1);
        assert!(out.iter().any(|o| matches!(
            o,
            Output::Reply { result: OpResult::Failed(FailReason::NoLease), .. }
        )));
        ack_all(&mut n, t(ET + 200), 1);
        let out = n.client_read(t(ET + 300), 2, 1);
        assert!(out.iter().any(|o| matches!(o, Output::Reply { result: OpResult::ReadOk(_), .. })));
        // Lease lapses without further acks after Δ from the SEND time.
        let out = n.client_read(t(ET + DELTA + 1000), 3, 1);
        assert!(out.iter().any(|o| matches!(
            o,
            Output::Reply { result: OpResult::Failed(FailReason::NoLease), .. }
        )));
    }

    #[test]
    fn ongaro_follower_withholds_vote() {
        let (mut f, _) = Node::new(cfg(1, ConsistencyMode::OngaroLease), 5, t(0));
        // Hears from leader 0 at t=100ms.
        f.on_message(
            t(100_000),
            Message::AppendEntries {
                term: 1, leader: 0, prev_index: 0, prev_term: 0,
                entries: EntryBatch::empty(), leader_commit: 0, seq: 1,
            },
        );
        // Candidate asks at t=600ms: within Δ=1s of last AE → withheld.
        let out = f.on_message(
            t(600_000),
            Message::RequestVote { term: 2, candidate: 2, last_log_index: 0, last_log_term: 0 },
        );
        assert!(matches!(
            out.last(),
            Some(Output::Send { msg: Message::VoteReply { granted: false, .. }, .. })
        ));
        // At t=1.2s (past Δ since last AE): grants.
        let out = f.on_message(
            t(1_200_000),
            Message::RequestVote { term: 3, candidate: 2, last_log_index: 0, last_log_term: 0 },
        );
        assert!(matches!(
            out.last(),
            Some(Output::Send { msg: Message::VoteReply { granted: true, .. }, .. })
        ));
    }

    #[test]
    fn leaseguard_does_not_withhold_votes() {
        let (mut f, _) = Node::new(cfg(1, ConsistencyMode::LeaseGuard), 5, t(0));
        f.on_message(
            t(100_000),
            Message::AppendEntries {
                term: 1, leader: 0, prev_index: 0, prev_term: 0,
                entries: EntryBatch::empty(), leader_commit: 0, seq: 1,
            },
        );
        // §3: even a node that knows of a valid lease may vote.
        let out = f.on_message(
            t(150_000),
            Message::RequestVote { term: 2, candidate: 2, last_log_index: 0, last_log_term: 0 },
        );
        assert!(matches!(
            out.last(),
            Some(Output::Send { msg: Message::VoteReply { granted: true, .. }, .. })
        ));
    }

    #[test]
    fn step_down_fails_pending_ambiguously() {
        let now = t(ET);
        let mut n = make_leader(ConsistencyMode::Inconsistent, now);
        ack_all(&mut n, now, 1);
        n.client_write(t(ET + 100), 50, 1, 100, 0);
        // Higher-term message deposes.
        let out = n.on_message(
            t(ET + 200),
            Message::AppendEntries {
                term: 9, leader: 2, prev_index: 0, prev_term: 0,
                entries: EntryBatch::empty(), leader_commit: 0, seq: 1,
            },
        );
        assert!(out.iter().any(|o| matches!(
            o,
            Output::Reply { op: 50, result: OpResult::Failed(FailReason::MaybeCommitted) }
        )));
        assert!(out.iter().any(|o| matches!(o, Output::SteppedDown)));
        assert_eq!(n.role(), Role::Follower);
    }

    #[test]
    fn follower_truncates_conflicts() {
        let (mut f, _) = Node::new(cfg(1, ConsistencyMode::Inconsistent), 8, t(0));
        f.on_message(
            t(100),
            Message::AppendEntries {
                term: 1, leader: 0, prev_index: 0, prev_term: 0,
                entries: vec![
                    Entry { term: 1, command: Command::Put { key: 1, value: 1, payload_bytes: 0 }, written_at: t(50) },
                    Entry { term: 1, command: Command::Put { key: 2, value: 2, payload_bytes: 0 }, written_at: t(60) },
                ]
                .into(),
                leader_commit: 0,
                seq: 1,
            },
        );
        assert_eq!(f.log().last_index(), 2);
        // New leader (term 3) overwrites index 2.
        f.on_message(
            t(200),
            Message::AppendEntries {
                term: 3, leader: 2, prev_index: 1, prev_term: 1,
                entries: vec![Entry { term: 3, command: Command::Noop, written_at: t(150) }].into(),
                leader_commit: 2,
                seq: 1,
            },
        );
        assert_eq!(f.log().last_index(), 2);
        assert_eq!(f.log().last_term(), 3);
        assert_eq!(f.commit_index(), 2);
    }

    #[test]
    fn follower_rejects_gapped_append() {
        let (mut f, _) = Node::new(cfg(1, ConsistencyMode::Inconsistent), 8, t(0));
        let out = f.on_message(
            t(100),
            Message::AppendEntries {
                term: 1, leader: 0, prev_index: 5, prev_term: 1,
                entries: EntryBatch::empty(), leader_commit: 0, seq: 3,
            },
        );
        assert!(matches!(
            out.last(),
            Some(Output::Send { msg: Message::AppendReply { success: false, .. }, .. })
        ));
    }

    #[test]
    fn restart_preserves_log_loses_volatile() {
        let now = t(ET);
        let mut n = make_leader(ConsistencyMode::LeaseGuard, now);
        ack_all(&mut n, now, 1);
        n.client_write(t(ET + 100), 1, 1, 10, 0);
        ack_all(&mut n, t(ET + 200), 1);
        assert!(n.commit_index() >= 2);
        let log_len = n.log().last_index();
        n.restart(t(ET + 300));
        assert_eq!(n.role(), Role::Follower);
        assert_eq!(n.commit_index(), 0);
        assert_eq!(n.log().last_index(), log_len);
        assert_eq!(n.term(), 1);
        assert_eq!(n.store().applied(), 0);
    }

    #[test]
    fn restart_cannot_double_vote_in_same_term() {
        // Election Safety regression: votedFor is durable. A node that
        // granted its term-3 vote, crashed, and restarted must refuse a
        // rival candidate in the same term.
        let (mut f, _) = Node::new(cfg(1, ConsistencyMode::LeaseGuard), 2, t(0));
        let out = f.on_message(
            t(10),
            Message::RequestVote { term: 3, candidate: 0, last_log_index: 0, last_log_term: 0 },
        );
        assert!(matches!(
            out.last(),
            Some(Output::Send { msg: Message::VoteReply { granted: true, .. }, .. })
        ));
        f.restart(t(20));
        assert_eq!(f.term(), 3, "currentTerm is durable");
        let out = f.on_message(
            t(30),
            Message::RequestVote { term: 3, candidate: 2, last_log_index: 9, last_log_term: 3 },
        );
        assert!(
            matches!(
                out.last(),
                Some(Output::Send { msg: Message::VoteReply { granted: false, .. }, .. })
            ),
            "restarted node granted a second vote in term 3: {out:?}"
        );
        // Re-granting the original candidate stays legal (idempotent).
        let out = f.on_message(
            t(40),
            Message::RequestVote { term: 3, candidate: 0, last_log_index: 0, last_log_term: 0 },
        );
        assert!(matches!(
            out.last(),
            Some(Output::Send { msg: Message::VoteReply { granted: true, .. }, .. })
        ));
    }

    #[test]
    fn restart_never_preserves_lease_or_ongaro_state() {
        // A rebooted node cannot trust pre-crash wall-clock promises:
        // lease state must be re-derived from the (durable) log at the
        // next election, never carried across the crash.
        let mut n = failover_leader(ConsistencyMode::LeaseGuard);
        assert!(n.lease_state().is_some());
        n.restart(t(1_200_000));
        assert!(n.lease_state().is_none(), "lease survived a reboot");
        assert_eq!(n.role(), Role::Follower);
        assert_eq!(n.commit_index(), 0, "commitIndex is volatile");
        assert_eq!(n.store().applied(), 0, "state machine is volatile");

        // Ongaro mode: vote-withholding memory (heard_leader_at) must
        // also die with the process — a restarted follower may vote
        // immediately, exactly as a freshly booted one.
        let (mut f, _) = Node::new(cfg(1, ConsistencyMode::OngaroLease), 5, t(0));
        f.on_message(
            t(100_000),
            Message::AppendEntries {
                term: 1, leader: 0, prev_index: 0, prev_term: 0,
                entries: EntryBatch::empty(), leader_commit: 0, seq: 1,
            },
        );
        f.restart(t(150_000));
        let out = f.on_message(
            t(200_000),
            Message::RequestVote { term: 2, candidate: 2, last_log_index: 0, last_log_term: 0 },
        );
        assert!(
            matches!(
                out.last(),
                Some(Output::Send { msg: Message::VoteReply { granted: true, .. }, .. })
            ),
            "withholding state must not survive restart: {out:?}"
        );
    }

    #[test]
    fn planned_handover_relinquishes_lease() {
        // §5.1: the outgoing leader commits an end-lease entry as its
        // final act; the next leader needs no gate wait.
        let now = t(ET);
        let mut old = make_leader(ConsistencyMode::LeaseGuard, now);
        ack_all(&mut old, now, 1);
        old.client_write(t(ET + 1000), 1, 5, 50, 0);
        ack_all(&mut old, t(ET + 2000), 1);
        // Begin the drain: append EndLease, replicate, commit.
        let outs = old.begin_stepdown(t(ET + 3000));
        assert!(outs.iter().any(|o| matches!(o, Output::Send { .. })));
        let outs = ack_all(&mut old, t(ET + 4000), 1);
        assert!(outs.iter().any(|o| matches!(o, Output::SteppedDown)), "{outs:?}");
        assert_eq!(old.role(), Role::Follower);

        // A new leader whose log ends with the EndLease entry starts
        // with an open commit gate (no Δ wait), despite fresh entries.
        let (mut new, _) = Node::new(cfg(1, ConsistencyMode::LeaseGuard), 9, t(0));
        let entries: EntryBatch = old.log().slice(0, old.log().last_index()).into();
        new.on_message(
            t(ET + 5000),
            Message::AppendEntries {
                term: old.term(),
                leader: 0,
                prev_index: 0,
                prev_term: 0,
                entries,
                leader_commit: 1,
                seq: 1,
            },
        );
        new.on_timer(t(2 * ET + 5100), TimerKind::Election);
        new.on_message(t(2 * ET + 5100), Message::VoteReply {
            term: new.term(),
            voter: 2,
            granted: true,
        });
        assert!(new.is_leader());
        // Gate open immediately: majority ack commits everything now,
        // long before the EndLease entry is Δ old.
        ack_all(&mut new, t(2 * ET + 6000), 2);
        assert_eq!(new.commit_index(), new.log().last_index());
        let out = new.client_read(t(2 * ET + 7000), 99, 5);
        assert!(out.iter().any(|o| matches!(o, Output::Reply { result: OpResult::ReadOk(_), .. })));
    }

    #[test]
    fn fanout_shares_one_batch_across_peers() {
        // A replication round must materialize the batch once and hand
        // every peer a view of the same allocation, under one round id.
        let now = t(ET);
        let mut n = make_leader(ConsistencyMode::Inconsistent, now);
        n.client_write(t(ET + 100), 1, 1, 10, 0);
        let outs = n.on_timer(t(ET + 200), TimerKind::Heartbeat);
        let appends: Vec<(&EntryBatch, u64)> = outs
            .iter()
            .filter_map(|o| match o {
                Output::Send { msg: Message::AppendEntries { entries, seq, .. }, .. } => {
                    Some((entries, *seq))
                }
                _ => None,
            })
            .collect();
        assert_eq!(appends.len(), 2, "{outs:?}");
        assert_eq!(appends[0].0.len(), 2); // noop + the write
        assert!(
            appends[0].0.shares_buffer(appends[1].0),
            "fan-out must share one materialized batch"
        );
        assert_eq!(appends[0].1, appends[1].1, "one round id per fan-out");
        // The shared round is protocol-equivalent: a majority ack of
        // that seq still commits.
        let out = ack_all(&mut n, t(ET + 300), 1);
        assert!(out
            .iter()
            .any(|o| matches!(o, Output::Reply { op: 1, result: OpResult::WriteOk })));
    }

    #[test]
    fn applied_outputs_emitted_once_per_put() {
        let now = t(ET);
        let mut n = make_leader(ConsistencyMode::Inconsistent, now);
        ack_all(&mut n, now, 1);
        n.client_write(t(ET + 100), 1, 3, 30, 0);
        let out = ack_all(&mut n, t(ET + 200), 1);
        let applied: Vec<_> = out
            .iter()
            .filter(|o| matches!(o, Output::Applied { key: 3, value: 30 }))
            .collect();
        assert_eq!(applied.len(), 1);
    }

    // ------------------------------------------------ snapshots/compaction

    fn make_leader_with(c: NodeConfig, now: TimeInterval) -> Node {
        let (mut n, _) = Node::new(c, 1, t(0));
        n.on_timer(now, TimerKind::Election);
        let term = n.term();
        n.on_message(now, Message::VoteReply { term, voter: 1, granted: true });
        assert!(n.is_leader());
        n
    }

    /// Leader with `writes` committed puts of `key` (values 0..writes),
    /// peer 1 acking everything and peer 2 silent (lagging).
    fn compacted_leader(threshold: u64, writes: u64, key: u32) -> Node {
        let mut c = cfg(0, ConsistencyMode::LeaseGuard);
        c.snapshot_threshold = threshold;
        let now = t(ET);
        let mut n = make_leader_with(c, now);
        ack_all(&mut n, now, 1);
        for i in 0..writes {
            let at = t(ET + 1000 * (i as Micros + 1));
            n.client_write(at, i, key, i, 0);
            ack_all(&mut n, at, 1);
        }
        n
    }

    #[test]
    fn threshold_compaction_moves_base_and_restart_resumes_from_snapshot() {
        let mut n = compacted_leader(3, 6, 2);
        // Commits ran 1..=7 (noop + 6 writes); threshold 3 compacts at
        // commit 3 and again at commit 6.
        assert_eq!(n.commit_index(), 7);
        assert_eq!(n.log().base(), 6, "two compactions: 3 then 6");
        assert_eq!(n.log().last_index(), 7, "uncompacted suffix survives");
        assert_eq!(n.stats.snapshots_taken, 2);
        let snap = n.take_pending_snap().expect("driver drains the snapshot");
        assert_eq!(snap.meta.last_index, 6);
        assert_eq!(snap.meta.group, 0);
        assert!(n.take_pending_snap().is_none(), "drain is one-shot");
        // Reads still see the full (partly compacted-away) history.
        let out = n.client_read(t(ET + 50_000), 99, 2);
        assert!(
            out.iter().any(|o| matches!(
                o,
                Output::Reply { result: OpResult::ReadOk(v), .. } if **v == vec![0, 1, 2, 3, 4, 5]
            )),
            "{out:?}"
        );
        // Restart: term/vote durable, store + commit resume from the
        // snapshot boundary instead of zero, lease stays dead.
        let term = n.term();
        n.restart(t(ET + 60_000));
        assert_eq!(n.term(), term);
        assert_eq!(n.commit_index(), 6, "boot resumes from the snapshot");
        assert_eq!(n.store().applied(), 6);
        assert_eq!(*n.store().read(2), vec![0, 1, 2, 3, 4], "suffix entry 7 is uncommitted again");
        assert!(n.lease_state().is_none(), "lease never rides a snapshot");
        assert_eq!(n.role(), Role::Follower);
        assert_eq!(n.log().base(), 6);
        assert_eq!(n.log().last_index(), 7);
    }

    #[test]
    fn zero_threshold_never_compacts() {
        let mut n = compacted_leader(0, 8, 1);
        assert_eq!(n.log().base(), 0);
        assert_eq!(n.stats.snapshots_taken, 0);
        assert!(n.take_pending_snap().is_none());
    }

    #[test]
    fn lagging_follower_catches_up_via_snapshot_and_resumes_appends() {
        let mut leader = compacted_leader(2, 5, 7);
        assert_eq!(leader.log().base(), 6, "fully compacted");
        let (mut f, _) = Node::new(cfg(2, ConsistencyMode::LeaseGuard), 3, t(0));
        // Pump heartbeats + node-2 traffic both ways until installed.
        let mut installed_round = None;
        for round in 0..20 {
            let at = t(ET + 100_000 + (round as Micros) * 1000);
            let outs = leader.on_timer(at, TimerKind::Heartbeat);
            let to_f: Vec<Message> = outs
                .into_iter()
                .filter_map(|o| match o {
                    Output::Send { to: 2, msg } => Some(msg),
                    _ => None,
                })
                .collect();
            let mut back = Vec::new();
            for m in to_f {
                assert!(
                    matches!(m, Message::SnapInstall { .. }) || installed_round.is_some(),
                    "pre-install traffic to a lagging peer must be snapshot chunks"
                );
                for o in f.on_message(at, m) {
                    if let Output::Send { to: 0, msg } = o {
                        back.push(msg);
                    }
                }
            }
            for m in back {
                leader.on_message(at, m);
            }
            if f.stats.snapshots_installed > 0 && installed_round.is_none() {
                installed_round = Some(round);
            }
            if installed_round.is_some() {
                break;
            }
        }
        assert!(installed_round.is_some(), "transfer never completed");
        assert_eq!(f.commit_index(), 6);
        assert_eq!(f.store().applied(), 6);
        assert_eq!(*f.store().read(7), vec![0, 1, 2, 3, 4]);
        assert_eq!(f.log().base(), 6, "follower log base moved to the boundary");
        assert!(f.lease_state().is_none(), "install must not resurrect lease state");
        assert_eq!(f.role(), Role::Follower);
        assert!(f.take_pending_snap().is_some(), "driver persists the installed snapshot");
        // Ordinary replication resumes: next round is AppendEntries and
        // the follower accepts it.
        let at = t(ET + 300_000);
        let outs = leader.on_timer(at, TimerKind::Heartbeat);
        let m = outs
            .into_iter()
            .find_map(|o| match o {
                Output::Send { to: 2, msg: m @ Message::AppendEntries { .. } } => Some(m),
                _ => None,
            })
            .expect("post-install traffic reverts to AppendEntries");
        let out = f.on_message(at, m);
        assert!(
            out.iter().any(|o| matches!(
                o,
                Output::Send { msg: Message::AppendReply { success: true, .. }, .. }
            )),
            "{out:?}"
        );
        // And the new state is itself crash-durable on the follower.
        f.restart(t(ET + 400_000));
        assert_eq!(f.commit_index(), 6);
        assert_eq!(*f.store().read(7), vec![0, 1, 2, 3, 4]);
        assert!(f.lease_state().is_none());
    }

    #[test]
    fn snapshot_chunks_buffer_dedupe_and_reject_gaps() {
        let mut leader = compacted_leader(1, 3, 5);
        let snap = leader.durable_snapshot().expect("compacted").clone();
        let data = &snap.data;
        assert!(data.len() >= 4);
        let half = data.len() / 2;
        let term = leader.term() + 1;
        let si = |offset: usize, chunk: &[u8], done: bool, seq: u64| Message::SnapInstall {
            term,
            leader: 0,
            last_index: snap.meta.last_index,
            last_term: snap.meta.last_term,
            offset: offset as u64,
            data: chunk.to_vec(),
            done,
            seq,
        };
        let ack = |out: &[Output]| -> (u64, bool) {
            out.iter()
                .find_map(|o| match o {
                    Output::Send {
                        msg: Message::SnapAck { offset, installed, .. }, ..
                    } => Some((*offset, *installed)),
                    _ => None,
                })
                .expect("every chunk is acked")
        };
        let (mut f, _) = Node::new(cfg(1, ConsistencyMode::LeaseGuard), 4, t(0));
        // First half buffers.
        let out = f.on_message(t(10), si(0, &data[..half], false, 1));
        assert_eq!(ack(&out), (half as u64, false));
        // Duplicate delivery of the same chunk: progress reported, no
        // double-buffering.
        let out = f.on_message(t(20), si(0, &data[..half], false, 2));
        assert_eq!(ack(&out), (half as u64, false));
        // Gap: an offset beyond the buffer restarts the transfer.
        let out = f.on_message(t(30), si(half + 3, &data[half + 3..], true, 3));
        assert_eq!(ack(&out), (0, false));
        // Retransmit from scratch completes the install.
        let out = f.on_message(t(40), si(0, &data[..half], false, 4));
        assert_eq!(ack(&out), (half as u64, false));
        let out = f.on_message(t(50), si(half, &data[half..], true, 5));
        assert_eq!(ack(&out), (data.len() as u64, true));
        assert_eq!(f.stats.snapshots_installed, 1);
        assert_eq!(f.commit_index(), snap.meta.last_index);
    }

    #[test]
    fn corrupt_or_mismatched_snapshot_is_rejected_not_installed() {
        let mut leader = compacted_leader(1, 2, 9);
        let snap = leader.durable_snapshot().expect("compacted").clone();
        let mut bad = (*snap.data).clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0xFF;
        let (mut f, _) = Node::new(cfg(1, ConsistencyMode::LeaseGuard), 6, t(0));
        let out = f.on_message(
            t(10),
            Message::SnapInstall {
                term: leader.term(),
                leader: 0,
                last_index: snap.meta.last_index,
                last_term: snap.meta.last_term,
                offset: 0,
                data: bad,
                done: true,
                seq: 1,
            },
        );
        assert!(
            out.iter().any(|o| matches!(
                o,
                Output::Send { msg: Message::SnapAck { installed: false, offset: 0, .. }, .. }
            )),
            "{out:?}"
        );
        assert_eq!(f.stats.snapshots_rejected, 1);
        assert_eq!(f.stats.snapshots_installed, 0);
        assert_eq!(f.commit_index(), 0, "nothing installed");
        assert_eq!(f.store().applied(), 0);
        // A stale-term chunk is ignored outright (no step down, no buffer).
        let (mut g, _) = Node::new(cfg(1, ConsistencyMode::LeaseGuard), 7, t(0));
        g.on_message(
            t(10),
            Message::RequestVote { term: 5, candidate: 0, last_log_index: 9, last_log_term: 5 },
        );
        let out = g.on_message(
            t(20),
            Message::SnapInstall {
                term: 4,
                leader: 2,
                last_index: snap.meta.last_index,
                last_term: snap.meta.last_term,
                offset: 0,
                data: (*snap.data).clone(),
                done: true,
                seq: 1,
            },
        );
        assert!(
            out.iter().any(|o| matches!(
                o,
                Output::Send { msg: Message::SnapAck { installed: false, .. }, .. }
            )),
            "{out:?}"
        );
        assert_eq!(g.stats.snapshots_installed, 0);
    }
}
