//! The replicated log. In LeaseGuard **the log is the lease**: every
//! entry carries the leader's `intervalNow()` at creation (paper §3,
//! Fig 2 line 5), and lease validity is derived purely from entry
//! timestamps — no extra messages or data structures.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use crate::clock::TimeInterval;
use crate::kv::Command;

use super::types::{Index, Term};

/// One log entry: `(term, command, intervalNow())` (Fig 2 line 5).
/// `Copy`: three scalar fields — follower ingest copies entries out of a
/// shared [`super::batch::EntryBatch`] without touching the heap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Entry {
    pub term: Term,
    pub command: Command,
    /// Leader-local bounded-uncertainty timestamp at creation.
    pub written_at: TimeInterval,
}

/// 1-based append-only log with the usual Raft truncation-on-conflict.
///
/// The log additionally tracks which suffix has changed since the last
/// [`Log::take_dirty`] — the real-mode server drains this watermark into
/// the WAL before externalizing any message that depends on the entries
/// (Raft's persist-before-send rule). The simulator never drains it;
/// virtual time has no disks, and an unread watermark costs nothing.
#[derive(Debug, Clone, Default)]
pub struct Log {
    entries: Vec<Entry>,
    /// Lowest index appended since the last `take_dirty` (1-based).
    dirty_from: Option<Index>,
    /// Whether a truncation happened since the last `take_dirty`.
    truncated: bool,
}

impl Log {
    pub fn new() -> Self {
        Log::default()
    }

    /// Index of the last entry (0 if empty).
    #[inline]
    pub fn last_index(&self) -> Index {
        self.entries.len() as Index
    }

    /// Term of the last entry (0 if empty).
    #[inline]
    pub fn last_term(&self) -> Term {
        self.entries.last().map(|e| e.term).unwrap_or(0)
    }

    /// Entry at 1-based `index`.
    #[inline]
    pub fn get(&self, index: Index) -> Option<&Entry> {
        if index == 0 {
            return None;
        }
        self.entries.get(index as usize - 1)
    }

    /// Term at `index`; 0 for index 0 (the empty prefix matches anything).
    #[inline]
    pub fn term_at(&self, index: Index) -> Option<Term> {
        if index == 0 {
            return Some(0);
        }
        self.get(index).map(|e| e.term)
    }

    /// Append one entry, returning its index (Fig 2 line 6).
    pub fn append(&mut self, entry: Entry) -> Index {
        self.entries.push(entry);
        let idx = self.last_index();
        self.dirty_from = Some(self.dirty_from.map_or(idx, |d| d.min(idx)));
        idx
    }

    /// Truncate the log so `last_index() == index` (drop entries after
    /// `index`). Used when a follower detects a conflict.
    pub fn truncate_after(&mut self, index: Index) {
        if (index as usize) < self.entries.len() {
            self.truncated = true;
            self.dirty_from = Some(self.dirty_from.map_or(index + 1, |d| d.min(index + 1)));
        }
        self.entries.truncate(index as usize);
    }

    /// Drain the unpersisted-change watermark: `(first dirty index,
    /// truncation happened)`, or `None` when nothing changed since the
    /// last call. After a truncation the dirty range `watermark..` may be
    /// partly or wholly gone from the log — persisting "truncate to
    /// watermark-1, then re-append `watermark..=last_index`" is always
    /// correct.
    pub fn take_dirty(&mut self) -> Option<(Index, bool)> {
        let truncated = std::mem::take(&mut self.truncated);
        match self.dirty_from.take() {
            Some(from) => Some((from, truncated)),
            None if truncated => Some((self.last_index() + 1, true)),
            None => None,
        }
    }

    /// Entries in `(from, to]`, for AppendEntries construction.
    pub fn slice(&self, from_exclusive: Index, to_inclusive: Index) -> &[Entry] {
        let lo = from_exclusive as usize;
        let hi = (to_inclusive as usize).min(self.entries.len());
        if lo >= hi {
            return &[];
        }
        &self.entries[lo..hi]
    }

    /// Iterate entries in `(from, to]` with their 1-based indexes.
    pub fn iter_range(
        &self,
        from_exclusive: Index,
        to_inclusive: Index,
    ) -> impl Iterator<Item = (Index, &Entry)> {
        self.slice(from_exclusive, to_inclusive)
            .iter()
            .enumerate()
            .map(move |(i, e)| (from_exclusive + 1 + i as Index, e))
    }

    /// Raft §5.4.1 up-to-date check: is a candidate with (last_term,
    /// last_index) at least as up to date as this log?
    pub fn candidate_up_to_date(&self, cand_last_term: Term, cand_last_index: Index) -> bool {
        (cand_last_term, cand_last_index) >= (self.last_term(), self.last_index())
    }

    /// Latest `written_at.latest` over entries with term < `t` — the
    /// deposed leader's lease deadline basis. The paper caches
    /// `lastEntryInPreviousTermIndex` (§7.1); we additionally take the
    /// max timestamp to stay correct even if clocks skew across terms.
    /// O(suffix): scans back only past entries with term >= t.
    pub fn max_prior_term_latest(&self, t: Term) -> Option<crate::Micros> {
        // Find the newest entry with term < t...
        let idx = self.entries.iter().rposition(|e| e.term < t)?;
        let mut best = self.entries[idx].written_at.latest;
        // ...then widen over a bounded lookback window: timestamps are
        // near-monotone within a log, so the newest prior-term entry
        // dominates in practice; the 64-entry window keeps us correct
        // under any realistic cross-term clock skew while staying O(1).
        let lo = idx.saturating_sub(64);
        for p in &self.entries[lo..idx] {
            if p.term < t {
                best = best.max(p.written_at.latest);
            }
        }
        Some(best)
    }

    /// The newest entry with term < `t` (the deposed leader's final
    /// act — used to detect a §5.1 end-lease relinquishment).
    pub fn last_prior_term_entry(&self, t: Term) -> Option<&Entry> {
        let idx = self.entries.iter().rposition(|e| e.term < t)?;
        Some(&self.entries[idx])
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::Command;

    fn e(term: Term, t: crate::Micros) -> Entry {
        Entry { term, command: Command::Noop, written_at: TimeInterval::exact(t) }
    }

    #[test]
    fn append_and_index() {
        let mut l = Log::new();
        assert_eq!(l.last_index(), 0);
        assert_eq!(l.term_at(0), Some(0));
        assert_eq!(l.append(e(1, 10)), 1);
        assert_eq!(l.append(e(1, 20)), 2);
        assert_eq!(l.last_index(), 2);
        assert_eq!(l.last_term(), 1);
        assert_eq!(l.get(1).unwrap().written_at.latest, 10);
        assert_eq!(l.get(3), None);
    }

    #[test]
    fn truncate_on_conflict() {
        let mut l = Log::new();
        l.append(e(1, 1));
        l.append(e(1, 2));
        l.append(e(2, 3));
        l.truncate_after(1);
        assert_eq!(l.last_index(), 1);
        assert_eq!(l.last_term(), 1);
    }

    #[test]
    fn slice_ranges() {
        let mut l = Log::new();
        for i in 0..5 {
            l.append(e(1, i));
        }
        assert_eq!(l.slice(0, 5).len(), 5);
        assert_eq!(l.slice(2, 4).len(), 2);
        assert_eq!(l.slice(4, 4).len(), 0);
        assert_eq!(l.slice(0, 99).len(), 5);
        let idx: Vec<Index> = l.iter_range(2, 4).map(|(i, _)| i).collect();
        assert_eq!(idx, vec![3, 4]);
    }

    #[test]
    fn up_to_date_rule() {
        let mut l = Log::new();
        l.append(e(1, 1));
        l.append(e(2, 2));
        // Higher last term wins regardless of length.
        assert!(l.candidate_up_to_date(3, 1));
        // Same term: longer or equal log wins.
        assert!(l.candidate_up_to_date(2, 2));
        assert!(!l.candidate_up_to_date(2, 1));
        assert!(!l.candidate_up_to_date(1, 99));
    }

    #[test]
    fn dirty_watermark_tracks_appends_and_truncations() {
        let mut l = Log::new();
        assert_eq!(l.take_dirty(), None);
        l.append(e(1, 1));
        l.append(e(1, 2));
        assert_eq!(l.take_dirty(), Some((1, false)));
        assert_eq!(l.take_dirty(), None, "drained");
        l.append(e(1, 3));
        assert_eq!(l.take_dirty(), Some((3, false)));
        // Conflict: truncate below the clean range, then refill.
        l.truncate_after(1);
        l.append(e(2, 9));
        assert_eq!(l.take_dirty(), Some((2, true)));
        // Pure truncation with no refill still reports.
        l.truncate_after(1);
        let (from, trunc) = l.take_dirty().unwrap();
        assert!(trunc);
        assert!(from >= 2, "persist replays truncate-to-{} then nothing", from - 1);
        // No-op truncation at or above the tip is not dirty.
        l.truncate_after(5);
        assert_eq!(l.take_dirty(), None);
    }

    #[test]
    fn max_prior_term_latest_finds_newest() {
        let mut l = Log::new();
        l.append(e(1, 100));
        l.append(e(1, 200));
        l.append(e(2, 300));
        assert_eq!(l.max_prior_term_latest(2), Some(200));
        assert_eq!(l.max_prior_term_latest(3), Some(300));
        assert_eq!(l.max_prior_term_latest(1), None);
        assert_eq!(Log::new().max_prior_term_latest(5), None);
    }

    #[test]
    fn max_prior_term_latest_handles_skew() {
        // An earlier entry with a *later* timestamp (clock skew across a
        // term boundary) within the lookback window is still found.
        let mut l = Log::new();
        l.append(e(1, 500)); // skewed-late timestamp
        l.append(e(1, 200));
        l.append(e(2, 600));
        assert_eq!(l.max_prior_term_latest(2), Some(500));
    }
}
