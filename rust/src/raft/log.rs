//! The replicated log. In LeaseGuard **the log is the lease**: every
//! entry carries the leader's `intervalNow()` at creation (paper §3,
//! Fig 2 line 5), and lease validity is derived purely from entry
//! timestamps — no extra messages or data structures.
//!
//! Snapshots & compaction: the log may be *compacted* up to a snapshot
//! point. Everything at or below `base` lives only in the snapshot; the
//! in-memory (and on-disk WAL-segment) suffix starts at `base + 1`. The
//! boundary keeps `(base, base_term, base_written_at)` so consistency
//! checks and the LeaseGuard commit-gate arithmetic still work at the
//! seam: `base_written_at.latest` is folded over the *entire* compacted
//! prefix (not just the boundary entry), so a deposed leader's lease
//! deadline derived from it is never early even under cross-term clock
//! skew among compacted entries.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use crate::clock::TimeInterval;
use crate::kv::Command;

use super::types::{Index, Term};

/// One log entry: `(term, command, intervalNow())` (Fig 2 line 5).
/// `Copy`: three scalar fields — follower ingest copies entries out of a
/// shared [`super::batch::EntryBatch`] without touching the heap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Entry {
    pub term: Term,
    pub command: Command,
    /// Leader-local bounded-uncertainty timestamp at creation.
    pub written_at: TimeInterval,
}

/// 1-based append-only log with the usual Raft truncation-on-conflict,
/// plus a compaction watermark (`base`).
///
/// The log additionally tracks which suffix has changed since the last
/// [`Log::take_dirty`] — the real-mode server drains this watermark into
/// the WAL before externalizing any message that depends on the entries
/// (Raft's persist-before-send rule). The simulator never drains it;
/// virtual time has no disks, and an unread watermark costs nothing.
#[derive(Debug, Clone)]
pub struct Log {
    /// `entries[i]` holds index `base + 1 + i`.
    entries: Vec<Entry>,
    /// Compaction watermark: highest index covered by the snapshot
    /// (0 = never compacted; the log starts at index 1 as always).
    base: Index,
    /// Term of the entry at `base` (0 when `base == 0` — the empty
    /// prefix matches anything, as in vanilla Raft).
    base_term: Term,
    /// `written_at` folded over the whole compacted prefix: `latest` is
    /// the max over every compacted entry, so lease-deadline math that
    /// can no longer scan those entries stays conservative-safe.
    base_written_at: TimeInterval,
    /// Lowest index appended since the last `take_dirty` (1-based).
    dirty_from: Option<Index>,
    /// Whether a truncation happened since the last `take_dirty`.
    truncated: bool,
}

impl Default for Log {
    fn default() -> Self {
        Log {
            entries: Vec::new(),
            base: 0,
            base_term: 0,
            base_written_at: TimeInterval::exact(0),
            dirty_from: None,
            truncated: false,
        }
    }
}

impl Log {
    pub fn new() -> Self {
        Log::default()
    }

    /// A log whose prefix up to `(base, base_term)` lives in a snapshot.
    pub fn with_base(base: Index, base_term: Term, base_written_at: TimeInterval) -> Self {
        Log { base, base_term, base_written_at, ..Log::default() }
    }

    /// Compaction watermark: highest index covered by the snapshot.
    #[inline]
    pub fn base(&self) -> Index {
        self.base
    }

    /// Term at the compaction watermark.
    #[inline]
    pub fn base_term(&self) -> Term {
        self.base_term
    }

    /// Folded `written_at` of the compacted prefix (see type docs).
    #[inline]
    pub fn base_written_at(&self) -> TimeInterval {
        self.base_written_at
    }

    /// Index of the first entry still held in memory (`base + 1`).
    #[inline]
    pub fn first_index(&self) -> Index {
        self.base + 1
    }

    /// Index of the last entry (`base` if the suffix is empty; 0 for a
    /// fresh, never-compacted log).
    #[inline]
    pub fn last_index(&self) -> Index {
        self.base + self.entries.len() as Index
    }

    /// Term of the last entry (`base_term` if the suffix is empty).
    #[inline]
    pub fn last_term(&self) -> Term {
        self.entries.last().map(|e| e.term).unwrap_or(self.base_term)
    }

    /// Entry at 1-based `index`; `None` at or below the compaction
    /// watermark (those entries live only in the snapshot).
    #[inline]
    pub fn get(&self, index: Index) -> Option<&Entry> {
        if index <= self.base {
            return None;
        }
        self.entries.get((index - self.base - 1) as usize)
    }

    /// Term at `index`: `base_term` at the watermark itself (0 for a
    /// fresh log's empty prefix), `None` below it (compacted away).
    #[inline]
    pub fn term_at(&self, index: Index) -> Option<Term> {
        if index == self.base {
            return Some(self.base_term);
        }
        self.get(index).map(|e| e.term)
    }

    /// Append one entry, returning its index (Fig 2 line 6).
    pub fn append(&mut self, entry: Entry) -> Index {
        self.entries.push(entry);
        let idx = self.last_index();
        self.dirty_from = Some(self.dirty_from.map_or(idx, |d| d.min(idx)));
        idx
    }

    /// Truncate the log so `last_index() == index` (drop entries after
    /// `index`). Used when a follower detects a conflict. Clamped to the
    /// compaction watermark: the snapshot prefix is committed state and
    /// can never conflict.
    pub fn truncate_after(&mut self, index: Index) {
        let index = index.max(self.base);
        if index < self.last_index() {
            self.truncated = true;
            self.dirty_from = Some(self.dirty_from.map_or(index + 1, |d| d.min(index + 1)));
        }
        self.entries.truncate((index - self.base) as usize);
    }

    /// Compact the in-memory prefix up to `index` (inclusive): the
    /// caller has captured everything `..= index` in a snapshot. Folds
    /// the dropped entries' `written_at.latest` into the boundary
    /// interval and discards the dirty watermark below the new base
    /// (the storage layer rewrites the surviving suffix wholesale when
    /// it rotates to a fresh WAL segment).
    pub fn compact_to(&mut self, index: Index) {
        if index <= self.base || index > self.last_index() {
            return;
        }
        let drop = (index - self.base) as usize;
        let boundary = self.entries[drop - 1];
        let mut latest = self.base_written_at.latest;
        for e in &self.entries[..drop] {
            latest = latest.max(e.written_at.latest);
        }
        self.entries.drain(..drop);
        self.base = index;
        self.base_term = boundary.term;
        self.base_written_at =
            TimeInterval { earliest: boundary.written_at.earliest, latest };
        self.dirty_from = None;
        self.truncated = false;
    }

    /// Install a snapshot boundary received over the wire. If the local
    /// log already holds the boundary entry with a matching term, the
    /// suffix past it is retained (vanilla Raft's InstallSnapshot rule);
    /// otherwise the whole log is replaced by the snapshot point.
    pub fn install_snapshot_meta(
        &mut self,
        index: Index,
        term: Term,
        written_at: TimeInterval,
    ) {
        if self.term_at(index) == Some(term) {
            self.compact_to(index);
            // Fold the sender's boundary interval too: it covers the
            // whole snapshotted prefix from the leader's perspective.
            self.base_written_at.latest = self.base_written_at.latest.max(written_at.latest);
            return;
        }
        self.entries.clear();
        self.base = index;
        self.base_term = term;
        self.base_written_at = written_at;
        self.dirty_from = None;
        self.truncated = false;
    }

    /// Drain the unpersisted-change watermark: `(first dirty index,
    /// truncation happened)`, or `None` when nothing changed since the
    /// last call. After a truncation the dirty range `watermark..` may be
    /// partly or wholly gone from the log — persisting "truncate to
    /// watermark-1, then re-append `watermark..=last_index`" is always
    /// correct.
    pub fn take_dirty(&mut self) -> Option<(Index, bool)> {
        let truncated = std::mem::take(&mut self.truncated);
        match self.dirty_from.take() {
            Some(from) => Some((from, truncated)),
            None if truncated => Some((self.last_index() + 1, true)),
            None => None,
        }
    }

    /// Entries in `(from, to]`, for AppendEntries construction. The
    /// range is clamped to what is still in memory: entries at or below
    /// the compaction watermark are never returned (callers that need
    /// them send a snapshot instead).
    pub fn slice(&self, from_exclusive: Index, to_inclusive: Index) -> &[Entry] {
        let lo = (from_exclusive.max(self.base) - self.base) as usize;
        let hi = ((to_inclusive.max(self.base) - self.base) as usize).min(self.entries.len());
        if lo >= hi {
            return &[];
        }
        &self.entries[lo..hi]
    }

    /// Iterate entries in `(from, to]` with their 1-based indexes
    /// (clamped to the in-memory suffix, like [`Log::slice`]).
    pub fn iter_range(
        &self,
        from_exclusive: Index,
        to_inclusive: Index,
    ) -> impl Iterator<Item = (Index, &Entry)> {
        let from = from_exclusive.max(self.base);
        self.slice(from, to_inclusive)
            .iter()
            .enumerate()
            .map(move |(i, e)| (from + 1 + i as Index, e))
    }

    /// Raft §5.4.1 up-to-date check: is a candidate with (last_term,
    /// last_index) at least as up to date as this log?
    pub fn candidate_up_to_date(&self, cand_last_term: Term, cand_last_index: Index) -> bool {
        (cand_last_term, cand_last_index) >= (self.last_term(), self.last_index())
    }

    /// Latest `written_at.latest` over entries with term < `t` — the
    /// deposed leader's lease deadline basis. The paper caches
    /// `lastEntryInPreviousTermIndex` (§7.1); we additionally take the
    /// max timestamp to stay correct even if clocks skew across terms.
    /// O(suffix): scans back only past entries with term >= t. When the
    /// newest prior-term entry has been compacted away, the folded
    /// boundary interval answers for the whole snapshot prefix
    /// (conservative: never earlier than the true deadline).
    pub fn max_prior_term_latest(&self, t: Term) -> Option<crate::Micros> {
        let from_base = (self.base > 0 && self.base_term < t)
            .then_some(self.base_written_at.latest);
        let idx = match self.entries.iter().rposition(|e| e.term < t) {
            Some(i) => i,
            None => return from_base,
        };
        let mut best = self.entries[idx].written_at.latest;
        // ...then widen over a bounded lookback window: timestamps are
        // near-monotone within a log, so the newest prior-term entry
        // dominates in practice; the 64-entry window keeps us correct
        // under any realistic cross-term clock skew while staying O(1).
        let lo = idx.saturating_sub(64);
        for p in &self.entries[lo..idx] {
            if p.term < t {
                best = best.max(p.written_at.latest);
            }
        }
        if lo == 0 {
            if let Some(b) = from_base {
                best = best.max(b);
            }
        }
        Some(best)
    }

    /// The newest *in-memory* entry with term < `t` (the deposed
    /// leader's final act — used to detect a §5.1 end-lease
    /// relinquishment). A compacted relinquishment entry returns `None`:
    /// the gate then falls back to the timed wait, which is safe (a
    /// snapshot point is committed state, so the wait is bounded).
    pub fn last_prior_term_entry(&self, t: Term) -> Option<&Entry> {
        let idx = self.entries.iter().rposition(|e| e.term < t)?;
        Some(&self.entries[idx])
    }

    /// In-memory suffix length (entries past the compaction watermark).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::Command;

    fn e(term: Term, t: crate::Micros) -> Entry {
        Entry { term, command: Command::Noop, written_at: TimeInterval::exact(t) }
    }

    #[test]
    fn append_and_index() {
        let mut l = Log::new();
        assert_eq!(l.last_index(), 0);
        assert_eq!(l.term_at(0), Some(0));
        assert_eq!(l.append(e(1, 10)), 1);
        assert_eq!(l.append(e(1, 20)), 2);
        assert_eq!(l.last_index(), 2);
        assert_eq!(l.last_term(), 1);
        assert_eq!(l.get(1).unwrap().written_at.latest, 10);
        assert_eq!(l.get(3), None);
    }

    #[test]
    fn truncate_on_conflict() {
        let mut l = Log::new();
        l.append(e(1, 1));
        l.append(e(1, 2));
        l.append(e(2, 3));
        l.truncate_after(1);
        assert_eq!(l.last_index(), 1);
        assert_eq!(l.last_term(), 1);
    }

    #[test]
    fn slice_ranges() {
        let mut l = Log::new();
        for i in 0..5 {
            l.append(e(1, i));
        }
        assert_eq!(l.slice(0, 5).len(), 5);
        assert_eq!(l.slice(2, 4).len(), 2);
        assert_eq!(l.slice(4, 4).len(), 0);
        assert_eq!(l.slice(0, 99).len(), 5);
        let idx: Vec<Index> = l.iter_range(2, 4).map(|(i, _)| i).collect();
        assert_eq!(idx, vec![3, 4]);
    }

    #[test]
    fn up_to_date_rule() {
        let mut l = Log::new();
        l.append(e(1, 1));
        l.append(e(2, 2));
        // Higher last term wins regardless of length.
        assert!(l.candidate_up_to_date(3, 1));
        // Same term: longer or equal log wins.
        assert!(l.candidate_up_to_date(2, 2));
        assert!(!l.candidate_up_to_date(2, 1));
        assert!(!l.candidate_up_to_date(1, 99));
    }

    #[test]
    fn dirty_watermark_tracks_appends_and_truncations() {
        let mut l = Log::new();
        assert_eq!(l.take_dirty(), None);
        l.append(e(1, 1));
        l.append(e(1, 2));
        assert_eq!(l.take_dirty(), Some((1, false)));
        assert_eq!(l.take_dirty(), None, "drained");
        l.append(e(1, 3));
        assert_eq!(l.take_dirty(), Some((3, false)));
        // Conflict: truncate below the clean range, then refill.
        l.truncate_after(1);
        l.append(e(2, 9));
        assert_eq!(l.take_dirty(), Some((2, true)));
        // Pure truncation with no refill still reports.
        l.truncate_after(1);
        let (from, trunc) = l.take_dirty().unwrap();
        assert!(trunc);
        assert!(from >= 2, "persist replays truncate-to-{} then nothing", from - 1);
        // No-op truncation at or above the tip is not dirty.
        l.truncate_after(5);
        assert_eq!(l.take_dirty(), None);
    }

    #[test]
    fn max_prior_term_latest_finds_newest() {
        let mut l = Log::new();
        l.append(e(1, 100));
        l.append(e(1, 200));
        l.append(e(2, 300));
        assert_eq!(l.max_prior_term_latest(2), Some(200));
        assert_eq!(l.max_prior_term_latest(3), Some(300));
        assert_eq!(l.max_prior_term_latest(1), None);
        assert_eq!(Log::new().max_prior_term_latest(5), None);
    }

    #[test]
    fn max_prior_term_latest_handles_skew() {
        // An earlier entry with a *later* timestamp (clock skew across a
        // term boundary) within the lookback window is still found.
        let mut l = Log::new();
        l.append(e(1, 500)); // skewed-late timestamp
        l.append(e(1, 200));
        l.append(e(2, 600));
        assert_eq!(l.max_prior_term_latest(2), Some(500));
    }

    #[test]
    fn compaction_moves_the_base_and_keeps_the_suffix() {
        let mut l = Log::new();
        for i in 1..=5 {
            l.append(e(1, i * 10));
        }
        l.compact_to(3);
        assert_eq!(l.base(), 3);
        assert_eq!(l.base_term(), 1);
        assert_eq!(l.first_index(), 4);
        assert_eq!(l.last_index(), 5);
        assert_eq!(l.len(), 2);
        // Compacted entries are gone; the watermark itself answers term
        // queries; below it is unknown.
        assert_eq!(l.get(3), None);
        assert_eq!(l.term_at(3), Some(1));
        assert_eq!(l.term_at(2), None);
        assert_eq!(l.get(4).unwrap().written_at.latest, 40);
        // Appends continue from the tip.
        assert_eq!(l.append(e(2, 60)), 6);
        assert_eq!(l.last_term(), 2);
        // Out-of-range compactions are no-ops.
        l.compact_to(2);
        assert_eq!(l.base(), 3);
        l.compact_to(99);
        assert_eq!(l.base(), 3);
    }

    #[test]
    fn compaction_folds_written_at_over_the_prefix() {
        // A compacted entry with a later timestamp than the boundary
        // entry (cross-term skew) must still dominate the folded bound.
        let mut l = Log::new();
        l.append(e(1, 900)); // skewed late
        l.append(e(1, 200));
        l.compact_to(2);
        assert_eq!(l.base_written_at().latest, 900);
        // Gate arithmetic sees the folded bound for prior-term queries.
        assert_eq!(l.max_prior_term_latest(2), Some(900));
        // An in-memory prior-term entry still folds the base in.
        l.append(e(2, 300));
        assert_eq!(l.max_prior_term_latest(3), Some(900));
    }

    #[test]
    fn truncate_never_crosses_the_base() {
        let mut l = Log::new();
        for i in 1..=4 {
            l.append(e(1, i));
        }
        l.compact_to(3);
        l.truncate_after(1); // clamped to base = 3
        assert_eq!(l.last_index(), 3);
        assert_eq!(l.base(), 3);
        assert_eq!(l.last_term(), 1);
    }

    #[test]
    fn install_meta_keeps_matching_suffix_or_resets() {
        // Matching boundary: suffix retained.
        let mut l = Log::new();
        for i in 1..=4 {
            l.append(e(1, i * 10));
        }
        l.install_snapshot_meta(2, 1, TimeInterval::exact(25));
        assert_eq!(l.base(), 2);
        assert_eq!(l.last_index(), 4);
        assert!(l.base_written_at().latest >= 25);
        // Conflicting boundary term: log replaced wholesale.
        let mut l = Log::new();
        for i in 1..=4 {
            l.append(e(1, i * 10));
        }
        l.install_snapshot_meta(3, 2, TimeInterval::exact(99));
        assert_eq!(l.base(), 3);
        assert_eq!(l.base_term(), 2);
        assert_eq!(l.last_index(), 3);
        assert!(l.is_empty());
        assert_eq!(l.base_written_at().latest, 99);
        // Boundary beyond the log: replace too.
        let mut l = Log::new();
        l.append(e(1, 10));
        l.install_snapshot_meta(7, 3, TimeInterval::exact(70));
        assert_eq!(l.base(), 7);
        assert_eq!(l.last_index(), 7);
        assert_eq!(l.last_term(), 3);
    }

    #[test]
    fn slices_clamp_to_the_compacted_suffix() {
        let mut l = Log::new();
        for i in 1..=6 {
            l.append(e(1, i * 10));
        }
        l.compact_to(3);
        // (0, 6] clamps to (3, 6].
        assert_eq!(l.slice(0, 6).len(), 3);
        let idx: Vec<Index> = l.iter_range(0, 6).map(|(i, _)| i).collect();
        assert_eq!(idx, vec![4, 5, 6]);
        assert_eq!(l.slice(4, 6).len(), 2);
    }

    #[test]
    fn compaction_discards_the_dirty_watermark_below_base() {
        // Storage rotates to a fresh segment at compaction time and
        // rewrites the suffix wholesale, so the pre-compaction dirty
        // range must not leak through take_dirty.
        let mut l = Log::new();
        for i in 1..=5 {
            l.append(e(1, i));
        }
        l.compact_to(4);
        assert_eq!(l.take_dirty(), None);
        l.append(e(2, 9));
        assert_eq!(l.take_dirty(), Some((6, false)));
    }
}
