//! Shared, cheaply-clonable entry batches for replication fan-out.
//!
//! Before this type, `Message::AppendEntries` carried a `Vec<Entry>`, so
//! a leader of an *n*-node replica set deep-copied every batch *n−1*
//! times per replication round — exactly the per-message overhead the
//! paper's throughput story (LogCabin ~1k → ~10k writes/s) cannot afford
//! in a reproduction. An [`EntryBatch`] is an offset/length view into an
//! `Arc<[Entry]>`: the leader materializes the log segment **once** per
//! round and every per-peer message, every in-flight simulator delivery,
//! and every queued real-mode frame shares that one allocation. Cloning
//! is a reference-count bump.

use std::fmt;
use std::ops::Deref;
use std::sync::{Arc, OnceLock};

use super::log::Entry;

/// An immutable slice view into a shared entry buffer.
///
/// Invariant: `off + len <= arc.len()`. Entries are immutable once
/// appended to a leader's log, so views stay valid for the lifetime of
/// the round that created them.
#[derive(Clone)]
pub struct EntryBatch {
    arc: Arc<[Entry]>,
    off: u32,
    len: u32,
}

/// One process-wide empty batch so heartbeats allocate nothing.
fn empty_arc() -> Arc<[Entry]> {
    static EMPTY: OnceLock<Arc<[Entry]>> = OnceLock::new();
    EMPTY.get_or_init(|| Arc::from(Vec::new())).clone()
}

impl EntryBatch {
    /// The shared empty batch (heartbeats).
    pub fn empty() -> EntryBatch {
        EntryBatch { arc: empty_arc(), off: 0, len: 0 }
    }

    /// Take ownership of a materialized batch.
    pub fn from_vec(v: Vec<Entry>) -> EntryBatch {
        let len = v.len();
        assert!(len <= u32::MAX as usize, "batch too large");
        EntryBatch { arc: Arc::from(v), off: 0, len: len as u32 }
    }

    /// A sub-view into an already-shared buffer (the per-peer fan-out
    /// path: one `Arc` materialization per round, N−1 of these).
    pub fn view(arc: Arc<[Entry]>, off: usize, len: usize) -> EntryBatch {
        assert!(off + len <= arc.len(), "view out of bounds");
        EntryBatch { arc, off: off as u32, len: len as u32 }
    }

    #[inline]
    pub fn as_slice(&self) -> &[Entry] {
        &self.arc[self.off as usize..(self.off + self.len) as usize]
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn iter(&self) -> std::slice::Iter<'_, Entry> {
        self.as_slice().iter()
    }

    /// Do two batches view the same underlying allocation? Diagnostic
    /// for tests/benches proving the fan-out really shares one buffer.
    pub fn shares_buffer(&self, other: &EntryBatch) -> bool {
        Arc::ptr_eq(&self.arc, &other.arc)
    }
}

impl Deref for EntryBatch {
    type Target = [Entry];

    #[inline]
    fn deref(&self) -> &[Entry] {
        self.as_slice()
    }
}

impl From<Vec<Entry>> for EntryBatch {
    fn from(v: Vec<Entry>) -> EntryBatch {
        EntryBatch::from_vec(v)
    }
}

impl From<&[Entry]> for EntryBatch {
    fn from(s: &[Entry]) -> EntryBatch {
        EntryBatch { arc: Arc::from(s), off: 0, len: s.len() as u32 }
    }
}

impl PartialEq for EntryBatch {
    fn eq(&self, other: &EntryBatch) -> bool {
        // Fast path: two views of the same buffer (the broadcast case —
        // this is what lets the real-mode router detect that consecutive
        // fan-out sends can share one encoded frame).
        if Arc::ptr_eq(&self.arc, &other.arc) && self.off == other.off && self.len == other.len {
            return true;
        }
        self.as_slice() == other.as_slice()
    }
}

impl Eq for EntryBatch {}

impl fmt::Debug for EntryBatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_list().entries(self.as_slice()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::TimeInterval;
    use crate::kv::Command;

    fn e(term: u64, t: i64) -> Entry {
        Entry { term, command: Command::Noop, written_at: TimeInterval::exact(t) }
    }

    #[test]
    fn views_share_one_allocation() {
        let arc: Arc<[Entry]> = Arc::from(vec![e(1, 1), e(1, 2), e(1, 3), e(1, 4)]);
        let a = EntryBatch::view(arc.clone(), 0, 4);
        let b = EntryBatch::view(arc.clone(), 1, 3);
        assert_eq!(a.len(), 4);
        assert_eq!(b.as_slice(), &a.as_slice()[1..]);
        // arc + a + b = 3 strong refs; no entry was copied.
        assert_eq!(Arc::strong_count(&arc), 3);
        let c = b.clone();
        assert_eq!(Arc::strong_count(&arc), 4);
        drop((a, b, c));
        assert_eq!(Arc::strong_count(&arc), 1);
    }

    #[test]
    fn empty_is_shared_and_allocation_free() {
        let a = EntryBatch::empty();
        let b = EntryBatch::empty();
        assert!(a.is_empty() && b.is_empty());
        assert_eq!(a, b);
    }

    #[test]
    fn equality_by_content_and_by_view() {
        let v = vec![e(2, 10), e(2, 20)];
        let a = EntryBatch::from_vec(v.clone());
        let b = EntryBatch::from_vec(v);
        assert_eq!(a, b); // different buffers, same content
        let sub = EntryBatch::view(Arc::from(vec![e(9, 9), e(2, 10), e(2, 20)]), 1, 2);
        assert_eq!(a, sub);
        assert_ne!(a, EntryBatch::empty());
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_view_rejected() {
        let arc: Arc<[Entry]> = Arc::from(vec![e(1, 1)]);
        let _ = EntryBatch::view(arc, 1, 1);
    }
}
