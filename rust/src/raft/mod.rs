//! The Raft substrate (Ongaro & Ousterhout [42]) that LeaseGuard builds
//! on: log, messages, elections, replication, commitment.
//!
//! The design keeps the node a *pure state machine over time-stamped
//! inputs*: every entry point takes the node's current clock reading
//! (a [`crate::clock::TimeInterval`]) and returns a list of [`Output`]
//! actions (messages to send, timers to set, client replies). The same
//! node core is therefore driven by both the deterministic simulator
//! ([`crate::cluster`], paper §6) and the real threaded TCP server
//! ([`crate::server`], paper §7) — one protocol implementation, two
//! testbeds, mirroring the paper's simulation + LogCabin methodology.
//!
//! LeaseGuard-specific logic (lease validity, commit gating, limbo
//! regions, Ongaro comparison leases) lives in [`crate::lease`] and is
//! invoked from the node at the three points the paper modifies:
//! `ClientRead`, `ClientWrite` acknowledgment, and `CommitEntry`
//! (paper Fig 2).

pub mod batch;
pub mod log;
pub mod message;
pub mod node;
pub mod types;

pub use batch::EntryBatch;
pub use log::{Entry, Log};
pub use message::Message;
pub use node::{DurableState, Node, NodeConfig, Output};
pub use types::{FailReason, Index, OpId, OpResult, Role, Term, TimerKind, Values};
