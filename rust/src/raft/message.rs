//! Raft RPCs. LeaseGuard adds **no messages and no fields** beyond
//! vanilla Raft (paper §3: "no changes to Raft messages, no additional
//! messages") — the only addition anywhere is the timestamp inside each
//! log entry. `seq` on AppendEntries is a round identifier LogCabin-style
//! implementations already need for quorum reads (ReadIndex) and that the
//! Ongaro-lease comparator uses to match acks to send times; it does not
//! carry lease information.

use super::batch::EntryBatch;
use super::types::{Index, Term};
use crate::NodeId;

#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    RequestVote {
        term: Term,
        candidate: NodeId,
        last_log_index: Index,
        last_log_term: Term,
    },
    VoteReply {
        term: Term,
        voter: NodeId,
        granted: bool,
    },
    AppendEntries {
        term: Term,
        leader: NodeId,
        prev_index: Index,
        prev_term: Term,
        /// Shared view into the leader's materialized batch: cloning a
        /// `Message` (per-peer fan-out, sim deliveries, wire queues)
        /// bumps a refcount instead of deep-copying entries.
        entries: EntryBatch,
        leader_commit: Index,
        /// Replication-round id (ReadIndex / Ongaro-lease bookkeeping);
        /// one id per fan-out round, shared by every peer's message.
        seq: u64,
    },
    AppendReply {
        term: Term,
        from: NodeId,
        success: bool,
        /// Highest log index known replicated on `from` when success.
        match_index: Index,
        /// Echo of the AppendEntries round id.
        seq: u64,
    },
}

impl Message {
    /// The sender's term, gossiped on every message (§2.1).
    pub fn term(&self) -> Term {
        match self {
            Message::RequestVote { term, .. }
            | Message::VoteReply { term, .. }
            | Message::AppendEntries { term, .. }
            | Message::AppendReply { term, .. } => *term,
        }
    }

    /// Short tag for logs/traces.
    pub fn kind(&self) -> &'static str {
        match self {
            Message::RequestVote { .. } => "RequestVote",
            Message::VoteReply { .. } => "VoteReply",
            Message::AppendEntries { .. } => "AppendEntries",
            Message::AppendReply { .. } => "AppendReply",
        }
    }
}
