//! Raft RPCs. Every message here is vanilla Raft: LeaseGuard adds **no
//! messages and no fields** (paper §3: "no changes to Raft messages, no
//! additional messages") — the only lease-related addition anywhere is
//! the timestamp inside each log entry. `seq` on AppendEntries is a
//! round identifier LogCabin-style
//! implementations already need for quorum reads (ReadIndex) and that the
//! Ongaro-lease comparator uses to match acks to send times; it does not
//! carry lease information.
//!
//! `SnapInstall`/`SnapAck` are vanilla Raft too: the InstallSnapshot RPC
//! from the Raft paper (§7), chunked for flow control. They carry
//! state-machine bytes and the compaction boundary — never lease state,
//! which is volatile by construction (see [`crate::snap`]).

use super::batch::EntryBatch;
use super::types::{Index, Term};
use crate::NodeId;

#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    RequestVote {
        term: Term,
        candidate: NodeId,
        last_log_index: Index,
        last_log_term: Term,
    },
    VoteReply {
        term: Term,
        voter: NodeId,
        granted: bool,
    },
    AppendEntries {
        term: Term,
        leader: NodeId,
        prev_index: Index,
        prev_term: Term,
        /// Shared view into the leader's materialized batch: cloning a
        /// `Message` (per-peer fan-out, sim deliveries, wire queues)
        /// bumps a refcount instead of deep-copying entries.
        entries: EntryBatch,
        leader_commit: Index,
        /// Replication-round id (ReadIndex / Ongaro-lease bookkeeping);
        /// one id per fan-out round, shared by every peer's message.
        seq: u64,
    },
    AppendReply {
        term: Term,
        from: NodeId,
        success: bool,
        /// Highest log index known replicated on `from` when success.
        match_index: Index,
        /// Echo of the AppendEntries round id.
        seq: u64,
    },
    /// One chunk of a snapshot transfer (Raft §7 InstallSnapshot),
    /// sent when a follower's `next_index` has fallen below the
    /// leader's compaction point. Stop-and-wait: one chunk in flight
    /// per peer, the next sent on the matching [`Message::SnapAck`].
    SnapInstall {
        term: Term,
        leader: NodeId,
        /// Snapshot boundary: the transfer's identity. A follower
        /// buffering chunks for one boundary discards them if the next
        /// chunk names a different one (the leader re-compacted).
        last_index: Index,
        last_term: Term,
        /// Byte offset of `data` within the snapshot payload.
        offset: u64,
        /// At most [`crate::snap::SNAP_CHUNK_BYTES`] payload bytes.
        data: Vec<u8>,
        /// True on the final chunk: the follower decodes and installs.
        done: bool,
        /// Round id, same numbering as AppendEntries `seq`.
        seq: u64,
    },
    /// Follower progress/result report for a snapshot transfer.
    SnapAck {
        term: Term,
        from: NodeId,
        /// Echo of the transfer's snapshot boundary.
        last_index: Index,
        /// Bytes buffered so far (the next offset the follower wants);
        /// 0 asks the leader to restart the transfer.
        offset: u64,
        /// True once the snapshot is decoded and installed (or the
        /// follower already has everything through `last_index`).
        installed: bool,
        /// Echo of the SnapInstall round id.
        seq: u64,
    },
}

impl Message {
    /// The sender's term, gossiped on every message (§2.1).
    pub fn term(&self) -> Term {
        match self {
            Message::RequestVote { term, .. }
            | Message::VoteReply { term, .. }
            | Message::AppendEntries { term, .. }
            | Message::AppendReply { term, .. }
            | Message::SnapInstall { term, .. }
            | Message::SnapAck { term, .. } => *term,
        }
    }

    /// Short tag for logs/traces.
    pub fn kind(&self) -> &'static str {
        match self {
            Message::RequestVote { .. } => "RequestVote",
            Message::VoteReply { .. } => "VoteReply",
            Message::AppendEntries { .. } => "AppendEntries",
            Message::AppendReply { .. } => "AppendReply",
            Message::SnapInstall { .. } => "SnapInstall",
            Message::SnapAck { .. } => "SnapAck",
        }
    }
}
