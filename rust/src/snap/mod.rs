//! State-machine snapshots: the serialization half of log compaction.
//!
//! A snapshot is a versioned, self-describing byte payload capturing
//! the whole [`crate::kv::Store`] plus the compaction boundary
//! `(last_included_index, last_included_term, folded written_at,
//! group)`. One canonical payload serves every consumer:
//!
//! * **disk** — [`crate::snap::file`] wraps it in a CRC frame and
//!   writes it atomically next to the WAL segments;
//! * **wire** — `Message::SnapInstall` streams it to a lagging peer in
//!   bounded chunks (see [`SNAP_CHUNK_BYTES`]);
//! * **recovery** — [`decode`] rebuilds the store wholesale.
//!
//! Lease discipline (paper §3): a snapshot carries **only** durable
//! state-machine contents. Lease state, Ongaro vote-withholding memory,
//! and the limbo region are volatile by construction and must never
//! ride a snapshot — they are re-derived from the live, timestamped log
//! at the next election. The folded `last_written_at` interval exists
//! solely so the commit-gate arithmetic stays conservative when the
//! entries it would have scanned are gone (see
//! [`crate::raft::log::Log::max_prior_term_latest`]).
//!
//! Decode here is a peer-facing parser of untrusted bytes: it is held
//! to the same panic-free standard as `server/wire.rs` (lint rule R4 —
//! no unwrap/expect/panic/slice-indexing on this path) and every length
//! is validated against the bytes actually present before allocation.

use std::sync::Arc;

use crate::clock::TimeInterval;
use crate::kv::Store;
use crate::raft::types::{Index, Term, Values};
use crate::server::wire::{Dec, DecodeError, Enc};
use crate::shard::GroupId;

/// Payload magic: `"LGSN"` (LeaseGuard SNapshot), compared as one LE u32.
pub const SNAP_MAGIC: [u8; 4] = *b"LGSN";
/// Payload format version.
pub const SNAP_VERSION: u8 = 1;
/// Wire-transfer chunk size: small enough that a chunk is one ordinary
/// network event (the sim models each chunk as its own sized delivery,
/// so nemesis scenarios can crash a node mid-transfer), large enough to
/// move a snapshot in few round trips.
pub const SNAP_CHUNK_BYTES: usize = 16 << 10;
/// Refuse to buffer or decode absurd snapshots (anti-DoS bound shared
/// by the wire receive path and the file reader).
pub const MAX_SNAPSHOT_BYTES: usize = 1 << 26;

/// The compaction boundary a snapshot captures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SnapMeta {
    /// Raft group this snapshot belongs to (multi-Raft: snapshots are
    /// namespaced per group and never cross groups).
    pub group: GroupId,
    /// Highest log index whose effects the snapshot contains.
    pub last_index: Index,
    /// Term of the entry at `last_index`.
    pub last_term: Term,
    /// `written_at` folded (max `latest`) over the entire compacted
    /// prefix — the lease-deadline bound for entries no longer present.
    pub last_written_at: TimeInterval,
    /// Store `applied` counter at the snapshot point (equals
    /// `last_index`: every committed command bumps it exactly once).
    pub applied: u64,
}

/// An encoded snapshot: meta plus the canonical payload bytes. The
/// payload is `Arc`-shared so fan-out (disk write, per-peer wire
/// chunks, sim deliveries) clones a pointer, not the buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub meta: SnapMeta,
    pub data: Arc<Vec<u8>>,
}

impl Snapshot {
    /// Total payload size in bytes.
    pub fn size(&self) -> usize {
        self.data.len()
    }

    /// The chunk starting at `offset` (at most `max` bytes) and whether
    /// it is the final one. `None` when `offset` is out of range.
    pub fn chunk(&self, offset: usize, max: usize) -> Option<(&[u8], bool)> {
        let len = self.data.len();
        if offset >= len {
            return None;
        }
        let end = offset.saturating_add(max).min(len);
        let c = self.data.get(offset..end)?;
        Some((c, end == len))
    }
}

/// Decoded snapshot contents, ready for [`Store::install`].
#[derive(Debug, Clone, PartialEq)]
pub struct SnapContents {
    pub meta: SnapMeta,
    pub pairs: Vec<(u32, Values)>,
}

/// Serialize `store` at boundary `meta` into the canonical payload.
/// Deterministic: keys are walked in ascending order (the store is a
/// BTreeMap precisely so this walk is stable across nodes and replays).
pub fn encode(store: &Store, meta: SnapMeta) -> Snapshot {
    let mut e = Enc::new();
    e.u32(u32::from_le_bytes(SNAP_MAGIC));
    e.u8(SNAP_VERSION);
    e.u32(meta.group);
    e.u64(meta.last_index);
    e.u64(meta.last_term);
    e.i64(meta.last_written_at.earliest);
    e.i64(meta.last_written_at.latest);
    e.u64(meta.applied);
    e.u32(store.key_count() as u32);
    for (k, vals) in store.entries_sorted() {
        e.u32(k);
        e.u32(vals.len() as u32);
        for &v in vals.iter() {
            e.u64(v);
        }
    }
    Snapshot { meta, data: Arc::new(e.buf) }
}

/// Parse an untrusted snapshot payload. Every failure is an error
/// return, never a panic; counts are validated against remaining bytes
/// before any allocation; keys must be strictly ascending (the
/// canonical encoding), so a bit-flipped payload that survives the
/// outer CRC still cannot smuggle in a malformed store.
pub fn decode(bytes: &[u8]) -> Result<SnapContents, DecodeError> {
    if bytes.len() > MAX_SNAPSHOT_BYTES {
        return Err(DecodeError(format!("snapshot of {} bytes exceeds cap", bytes.len())));
    }
    let mut d = Dec::new(bytes);
    let magic = d.u32()?;
    if magic != u32::from_le_bytes(SNAP_MAGIC) {
        return Err(DecodeError(format!("bad snapshot magic {magic:#010x}")));
    }
    let version = d.u8()?;
    if version != SNAP_VERSION {
        return Err(DecodeError(format!(
            "unsupported snapshot version {version} (this build speaks {SNAP_VERSION})"
        )));
    }
    let group = d.u32()?;
    let last_index = d.u64()?;
    let last_term = d.u64()?;
    let earliest = d.i64()?;
    let latest = d.i64()?;
    let applied = d.u64()?;
    // 8 = u32 key + u32 value-count: the smallest per-key footprint.
    let nkeys = d.count(8)?;
    let mut pairs: Vec<(u32, Values)> = Vec::with_capacity(nkeys);
    let mut prev: Option<u32> = None;
    for _ in 0..nkeys {
        let key = d.u32()?;
        if let Some(p) = prev {
            if key <= p {
                return Err(DecodeError(format!("keys not strictly ascending at {key}")));
            }
        }
        prev = Some(key);
        let nvals = d.count(8)?; // 8 bytes per u64 value
        let mut vals = Vec::with_capacity(nvals);
        for _ in 0..nvals {
            vals.push(d.u64()?);
        }
        pairs.push((key, vals.into()));
    }
    if !d.done() {
        return Err(DecodeError("trailing bytes in snapshot payload".to_string()));
    }
    Ok(SnapContents {
        meta: SnapMeta {
            group,
            last_index,
            last_term,
            last_written_at: TimeInterval::new(earliest, latest),
            applied,
        },
        pairs,
    })
}

pub mod file;

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests only — production decode above stays panic-free (lint R4)
mod tests {
    use super::*;
    use crate::kv::Command;

    fn store_with(puts: &[(u32, u64)]) -> Store {
        let mut s = Store::new();
        for &(k, v) in puts {
            s.apply(&Command::Put { key: k, value: v, payload_bytes: 0 });
        }
        s
    }

    fn meta(index: Index, term: Term, applied: u64) -> SnapMeta {
        SnapMeta {
            group: 3,
            last_index: index,
            last_term: term,
            last_written_at: TimeInterval::new(90, 110),
            applied,
        }
    }

    #[test]
    fn roundtrip_restores_store_and_meta() {
        let s = store_with(&[(7, 70), (1, 10), (1, 11), (900, 9)]);
        let snap = encode(&s, meta(4, 2, s.applied()));
        let c = decode(&snap.data).expect("decode");
        assert_eq!(c.meta, snap.meta);
        let mut t = Store::new();
        t.install(c.pairs, c.meta.applied);
        assert_eq!(*t.read(1), vec![10, 11]);
        assert_eq!(*t.read(7), vec![70]);
        assert_eq!(*t.read(900), vec![9]);
        assert_eq!(t.applied(), 4);
        assert_eq!(t.key_count(), 3);
    }

    #[test]
    fn encoding_is_deterministic() {
        let s = store_with(&[(5, 1), (2, 2), (9, 3)]);
        let a = encode(&s, meta(3, 1, 3));
        let b = encode(&s, meta(3, 1, 3));
        assert_eq!(a.data, b.data, "same state must encode byte-identically");
    }

    #[test]
    fn empty_store_roundtrips() {
        let s = Store::new();
        let snap = encode(&s, meta(0, 0, 0));
        let c = decode(&snap.data).expect("decode");
        assert!(c.pairs.is_empty());
        assert_eq!(c.meta.last_index, 0);
    }

    #[test]
    fn every_truncated_prefix_is_an_error_never_a_panic() {
        let s = store_with(&[(1, 10), (2, 20)]);
        let snap = encode(&s, meta(2, 1, 2));
        for cut in 0..snap.data.len() {
            assert!(
                decode(&snap.data[..cut]).is_err(),
                "prefix of len {cut}/{} decoded cleanly",
                snap.data.len()
            );
        }
        // Seeded single-byte corruption sweep: decode must RETURN on
        // every input (error or changed values), never panic.
        let mut rng = crate::prob::Rng::new(0x5AFE);
        for _ in 0..300 {
            let mut b = (*snap.data).clone();
            let i = rng.below(b.len() as u64) as usize;
            b[i] ^= 1 << rng.below(8);
            let _ = decode(&b);
        }
    }

    #[test]
    fn poison_counts_rejected_without_allocating() {
        let s = store_with(&[(1, 10)]);
        let snap = encode(&s, meta(1, 1, 1));
        // Key count sits right after the 41-byte fixed header.
        let mut b = (*snap.data).clone();
        b[41..45].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode(&b).unwrap_err();
        assert!(err.0.contains("exceeds remaining"), "{err:?}");
        // Oversized payload cap.
        let huge = vec![0u8; MAX_SNAPSHOT_BYTES + 1];
        assert!(decode(&huge).is_err());
    }

    #[test]
    fn unsorted_keys_rejected() {
        // Hand-build a payload with descending keys: structurally valid,
        // canonically invalid.
        let s = store_with(&[(1, 10), (2, 20)]);
        let snap = encode(&s, meta(2, 1, 2));
        let mut b = (*snap.data).clone();
        // Swap the two key ids (key 1 at offset 45, key 2 at 45+4+4+8).
        b[45..49].copy_from_slice(&2u32.to_le_bytes());
        b[61..65].copy_from_slice(&1u32.to_le_bytes());
        let err = decode(&b).unwrap_err();
        assert!(err.0.contains("ascending"), "{err:?}");
    }

    #[test]
    fn chunks_reassemble_exactly() {
        let s = store_with(&(0..2000u32).map(|k| (k, k as u64)).collect::<Vec<_>>());
        let snap = encode(&s, meta(2000, 1, 2000));
        assert!(snap.size() > SNAP_CHUNK_BYTES, "need a multi-chunk snapshot");
        let mut buf = Vec::new();
        loop {
            let (chunk, done) = snap.chunk(buf.len(), SNAP_CHUNK_BYTES).expect("chunk");
            assert!(chunk.len() <= SNAP_CHUNK_BYTES);
            buf.extend_from_slice(chunk);
            if done {
                break;
            }
        }
        assert_eq!(&buf, &*snap.data);
        assert!(snap.chunk(snap.size(), SNAP_CHUNK_BYTES).is_none(), "past-end chunk");
        let c = decode(&buf).expect("reassembled decode");
        assert_eq!(c.pairs.len(), 2000);
    }
}
