//! Snapshot files on disk: CRC framing, atomic writes, torn-file
//! fallback, and retention pruning.
//!
//! Layout inside a node's (per-group) data directory:
//!
//! ```text
//! snap-00000000000000000042        snapshot through log index 42
//! snap-00000000000000000117        snapshot through log index 117
//! wal                              base-0 segment (legacy name)
//! wal-00000000000000000042         segment whose records start above 42
//! wal-00000000000000000117         live segment
//! ```
//!
//! Indices are zero-padded so lexical order is numeric order. A
//! snapshot file is `u32 crc32(payload) | u32 payload_len | payload`
//! where the payload is the canonical [`crate::snap`] encoding; it is
//! written with the same atomicity discipline as `hardstate.rs`
//! (tmp + fsync + rename + directory fsync), so a crash leaves either
//! the old file set or the new one — never a half-written visible file.
//!
//! Reads are deliberately forgiving: any framing or payload defect —
//! short file, bad CRC, bad decode, trailing bytes — makes the file
//! invisible ([`read`] returns `None`) and recovery falls back to the
//! next-newest snapshot plus a longer WAL replay. That fallback is why
//! retention always keeps the previous snapshot alongside the newest.

use std::fs::{self, File};
use std::io::{self, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::raft::types::Index;
use crate::storage::wal::crc32;
use crate::storage::FsyncPolicy;

use super::{decode, Snapshot, MAX_SNAPSHOT_BYTES};

/// Canonical file name for a snapshot through `index`.
pub fn snap_name(index: Index) -> String {
    format!("snap-{index:020}")
}

/// Canonical file name for the WAL segment that starts above `base`.
/// Base 0 keeps the legacy bare name so pre-compaction directories
/// recover unchanged.
pub fn segment_name(base: Index) -> String {
    if base == 0 {
        "wal".to_string()
    } else {
        format!("wal-{base:020}")
    }
}

fn parse_index(name: &str, prefix: &str) -> Option<Index> {
    let digits = name.strip_prefix(prefix)?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Write `snap` atomically into `dir`, returning its final path.
pub fn write(dir: &Path, snap: &Snapshot, policy: FsyncPolicy) -> io::Result<PathBuf> {
    let name = snap_name(snap.meta.last_index);
    let tmp = dir.join(format!("{name}.tmp"));
    let dst = dir.join(&name);
    {
        let mut f = File::create(&tmp)?;
        f.write_all(&crc32(&snap.data).to_le_bytes())?;
        f.write_all(&(snap.data.len() as u32).to_le_bytes())?;
        f.write_all(&snap.data)?;
        if policy.fsyncs() {
            f.sync_data()?;
        }
    }
    fs::rename(&tmp, &dst)?;
    if policy.fsyncs() {
        File::open(dir)?.sync_all()?;
    }
    Ok(dst)
}

/// Read and fully validate one snapshot file. `None` on any defect —
/// torn write, bit rot, oversize, trailing garbage, undecodable
/// payload — so callers treat the file as absent and fall back.
pub fn read(path: &Path) -> Option<Snapshot> {
    let bytes = fs::read(path).ok()?;
    let crc = u32::from_le_bytes(bytes.get(0..4)?.try_into().ok()?);
    let len = u32::from_le_bytes(bytes.get(4..8)?.try_into().ok()?) as usize;
    if len > MAX_SNAPSHOT_BYTES || bytes.len() != 8 + len {
        return None;
    }
    let payload = bytes.get(8..)?;
    if crc32(payload) != crc {
        return None;
    }
    let contents = decode(payload).ok()?;
    Some(Snapshot { meta: contents.meta, data: Arc::new(payload.to_vec()) })
}

/// All snapshot indices present in `dir`, ascending. Stray `.tmp`
/// files (crash before rename) are ignored — and cleaned up lazily by
/// the next [`write`]'s rename.
pub fn list(dir: &Path) -> io::Result<Vec<Index>> {
    let mut out = Vec::new();
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        if let Some(idx) = entry.file_name().to_str().and_then(|n| parse_index(n, "snap-")) {
            out.push(idx);
        }
    }
    out.sort_unstable();
    Ok(out)
}

/// All WAL segment bases present in `dir`, ascending (the legacy bare
/// `wal` file is base 0).
pub fn list_segments(dir: &Path) -> io::Result<Vec<Index>> {
    let mut out = Vec::new();
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        match entry.file_name().to_str() {
            Some("wal") => out.push(0),
            Some(n) => {
                if let Some(base) = parse_index(n, "wal-") {
                    out.push(base);
                }
            }
            None => {}
        }
    }
    out.sort_unstable();
    Ok(out)
}

/// Newest fully-valid snapshot in `dir`, skipping torn/corrupt files.
/// This *is* the recovery fallback: the newest file is tried first and
/// each defective candidate silently yields to the one before it.
pub fn load_newest(dir: &Path) -> io::Result<Option<Snapshot>> {
    let mut indices = list(dir)?;
    while let Some(idx) = indices.pop() {
        if let Some(s) = read(&dir.join(snap_name(idx))) {
            return Ok(Some(s));
        }
    }
    Ok(None)
}

/// Retention: keep the newest and previous snapshots (the previous one
/// is the torn-newest fallback) plus every WAL segment that a recovery
/// starting from the *previous* snapshot could still need. Everything
/// older is deleted. `live_segment` is never deleted regardless.
pub fn prune(dir: &Path, live_segment: Index, policy: FsyncPolicy) -> io::Result<()> {
    let snaps = list(dir)?;
    // Keep the last two snapshots; floor is the oldest one retained.
    let keep_from = match snaps.len().checked_sub(2) {
        Some(i) => snaps.get(i).copied().unwrap_or(0),
        None => 0,
    };
    let mut removed = false;
    for idx in &snaps {
        if *idx < keep_from {
            fs::remove_file(dir.join(snap_name(*idx)))?;
            removed = true;
        }
    }
    // A segment with base B holds records for indices > B. Recovery
    // from snapshot `keep_from` replays every segment whose *successor*
    // covers indices above keep_from — i.e. drop segment B only if some
    // retained segment with base B' (B < B' <= keep_from) supersedes it.
    let segs = list_segments(dir)?;
    let seg_floor =
        segs.iter().copied().filter(|b| *b <= keep_from).max().unwrap_or(0);
    for base in &segs {
        if *base < seg_floor && *base != live_segment {
            fs::remove_file(dir.join(segment_name(*base)))?;
            removed = true;
        }
    }
    if removed && policy.fsyncs() {
        File::open(dir)?.sync_all()?;
    }
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::clock::TimeInterval;
    use crate::kv::{Command, Store};
    use crate::snap::{encode, SnapMeta};
    use crate::testkit::TempDir;

    fn snap_at(index: Index, keys: u32) -> Snapshot {
        let mut s = Store::new();
        for k in 0..keys {
            s.apply(&Command::Put { key: k, value: k as u64, payload_bytes: 0 });
        }
        encode(
            &s,
            SnapMeta {
                group: 0,
                last_index: index,
                last_term: 1,
                last_written_at: TimeInterval::exact(index as i64),
                applied: keys as u64,
            },
        )
    }

    #[test]
    fn write_read_roundtrip() {
        let d = TempDir::new("snapfile-rt");
        let s = snap_at(9, 5);
        let p = write(d.path(), &s, FsyncPolicy::Never).unwrap();
        assert_eq!(p.file_name().unwrap().to_str().unwrap(), snap_name(9));
        assert_eq!(read(&p).unwrap(), s);
    }

    #[test]
    fn newest_valid_wins_and_torn_falls_back() {
        let d = TempDir::new("snapfile-newest");
        let old = snap_at(5, 3);
        let new = snap_at(12, 7);
        write(d.path(), &old, FsyncPolicy::Never).unwrap();
        let newest_path = write(d.path(), &new, FsyncPolicy::Never).unwrap();
        assert_eq!(load_newest(d.path()).unwrap().unwrap().meta.last_index, 12);
        // Tear the newest file: recovery silently falls back to index 5.
        let full = fs::read(&newest_path).unwrap();
        fs::write(&newest_path, &full[..full.len() / 2]).unwrap();
        assert_eq!(load_newest(d.path()).unwrap().unwrap().meta.last_index, 5);
    }

    #[test]
    fn corrupt_crc_is_invisible() {
        let d = TempDir::new("snapfile-crc");
        let p = write(d.path(), &snap_at(3, 2), FsyncPolicy::Never).unwrap();
        let mut b = fs::read(&p).unwrap();
        let last = b.len() - 1;
        b[last] ^= 0x40;
        fs::write(&p, &b).unwrap();
        assert!(read(&p).is_none());
        assert!(load_newest(d.path()).unwrap().is_none());
    }

    #[test]
    fn listing_ignores_tmp_and_foreign_files() {
        let d = TempDir::new("snapfile-list");
        write(d.path(), &snap_at(7, 1), FsyncPolicy::Never).unwrap();
        write(d.path(), &snap_at(2, 1), FsyncPolicy::Never).unwrap();
        fs::write(d.path().join("snap-00000000000000000099.tmp"), b"half").unwrap();
        fs::write(d.path().join("hard_state"), b"x").unwrap();
        fs::write(d.path().join("snap-abc"), b"x").unwrap();
        assert_eq!(list(d.path()).unwrap(), vec![2, 7]);
    }

    #[test]
    fn segment_names_roundtrip_through_listing() {
        let d = TempDir::new("snapfile-segs");
        fs::write(d.path().join(segment_name(0)), b"").unwrap();
        fs::write(d.path().join(segment_name(42)), b"").unwrap();
        fs::write(d.path().join(segment_name(7)), b"").unwrap();
        assert_eq!(list_segments(d.path()).unwrap(), vec![0, 7, 42]);
    }

    #[test]
    fn prune_keeps_two_snapshots_and_needed_segments() {
        let d = TempDir::new("snapfile-prune");
        for idx in [5u64, 12, 20] {
            write(d.path(), &snap_at(idx, 1), FsyncPolicy::Never).unwrap();
        }
        for base in [0u64, 5, 12, 20] {
            fs::write(d.path().join(segment_name(base)), b"").unwrap();
        }
        prune(d.path(), 20, FsyncPolicy::Never).unwrap();
        assert_eq!(list(d.path()).unwrap(), vec![12, 20], "keep newest + previous");
        // Recovery floor is snapshot 12; segment 12 supersedes 0 and 5.
        assert_eq!(list_segments(d.path()).unwrap(), vec![12, 20]);
        // Pruning again is a no-op.
        prune(d.path(), 20, FsyncPolicy::Never).unwrap();
        assert_eq!(list_segments(d.path()).unwrap(), vec![12, 20]);
    }

    #[test]
    fn prune_with_one_snapshot_keeps_everything_needed() {
        let d = TempDir::new("snapfile-prune1");
        write(d.path(), &snap_at(5, 1), FsyncPolicy::Never).unwrap();
        for base in [0u64, 5] {
            fs::write(d.path().join(segment_name(base)), b"").unwrap();
        }
        prune(d.path(), 5, FsyncPolicy::Never).unwrap();
        // keep_from = 0 with a single snapshot: nothing is deleted.
        assert_eq!(list(d.path()).unwrap(), vec![5]);
        assert_eq!(list_segments(d.path()).unwrap(), vec![0, 5]);
    }
}
