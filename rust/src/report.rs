//! Rendering experiment results: ASCII tables, sparkline-style timeline
//! charts for the availability figures, and CSV emission so results can
//! be re-plotted elsewhere. Everything the figure drivers print flows
//! through here.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A simple aligned table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let r: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(r.len(), self.header.len(), "row arity mismatch");
        self.rows.push(r);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:>width$}  ", c, width = widths[i]);
            }
            out.push('\n');
        };
        fmt_row(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            fmt_row(r, &widths, &mut out);
        }
        out
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.header.join(","))?;
        for r in &self.rows {
            writeln!(f, "{}", r.join(","))?;
        }
        Ok(())
    }
}

/// ASCII timeline chart: one row per series, one character per bucket,
/// height-coded by value (the availability figures at terminal
/// resolution).
pub fn timeline_chart(labels: &[&str], series: &[Vec<f64>], bucket_ms: f64) -> String {
    const GLYPHS: &[char] = &[' ', '\u{2581}', '\u{2582}', '\u{2583}', '\u{2584}', '\u{2585}', '\u{2586}', '\u{2587}', '\u{2588}'];
    let maxv = series
        .iter()
        .flat_map(|s| s.iter().copied())
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let mut out = String::new();
    let label_w = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    for (label, s) in labels.iter().zip(series.iter()) {
        let _ = write!(out, "{label:>label_w$} |");
        for &v in s {
            let idx = ((v / maxv) * (GLYPHS.len() - 1) as f64).round() as usize;
            out.push(GLYPHS[idx.min(GLYPHS.len() - 1)]);
        }
        let _ = writeln!(out, "| max={maxv:.0}/s");
    }
    let n = series.first().map(|s| s.len()).unwrap_or(0);
    let _ = writeln!(
        out,
        "{:>label_w$} |{}| ({} buckets x {:.0} ms)",
        "t",
        (0..n).map(|i| if i % 10 == 0 { '+' } else { '-' }).collect::<String>(),
        n,
        bucket_ms
    );
    out
}

/// Minimal JSON string escaping (hand-rolled JSON; no serde offline).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Write a machine-readable microbench trajectory (`BENCH_micro.json`):
/// one `(name, ops_per_sec, ops_per_rep)` row per bench.
pub fn write_bench_json(path: &Path, rows: &[(String, f64, u64)]) -> std::io::Result<()> {
    let mut body = String::from("{\n  \"suite\": \"micro\",\n  \"results\": [\n");
    for (i, (name, ops_per_sec, ops_per_rep)) in rows.iter().enumerate() {
        let _ = write!(
            body,
            "    {{\"name\": \"{}\", \"ops_per_sec\": {:.1}, \"ops_per_rep\": {}}}",
            esc(name),
            ops_per_sec,
            ops_per_rep
        );
        body.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    body.push_str("  ]\n}\n");
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, body)
}

/// Write the Nemesis scenario matrix (`SCENARIOS.json`): one row per
/// (scenario, consistency mode) with the linearizability verdict and
/// availability/latency stats. Deterministic per seed — commit the file
/// to track the matrix across PRs (like `BENCH_micro.json`).
pub fn write_scenarios_json(
    path: &Path,
    seed: u64,
    rows: &[crate::sim::scenario::ScenarioOutcome],
) -> std::io::Result<()> {
    let mut body = String::new();
    let _ = write!(body, "{{\n  \"suite\": \"scenarios\",\n  \"seed\": {seed},\n  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            body,
            "    {{\"scenario\": \"{}\", \"mode\": \"{}\", \
             \"expect_linearizable\": {}, \"violations\": {}, \
             \"reads_ok\": {}, \"reads_failed\": {}, \
             \"writes_ok\": {}, \"writes_failed\": {}, \
             \"read_p50_us\": {}, \"read_p99_us\": {}, \
             \"write_p50_us\": {}, \"write_p99_us\": {}, \
             \"elections\": {}, \"faults_injected\": {}, \"events\": {}}}",
            esc(&r.scenario),
            r.mode,
            r.expect_linearizable,
            r.violations,
            r.reads_ok,
            r.reads_failed,
            r.writes_ok,
            r.writes_failed,
            r.read_p50_us,
            r.read_p99_us,
            r.write_p50_us,
            r.write_p99_us,
            r.elections,
            r.faults_injected,
            r.events_processed,
        );
        body.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    body.push_str("  ]\n}\n");
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, body)
}

/// Format µs as a human latency string.
pub fn fmt_us(us: i64) -> String {
    if us >= 1_000_000 {
        format!("{:.2}s", us as f64 / 1e6)
    } else if us >= 1000 {
        format!("{:.2}ms", us as f64 / 1e3)
    } else {
        format!("{us}µs")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(["mode", "p90(read)", "p90(write)"]);
        t.row(["quorum", "5.1ms", "5.3ms"]);
        t.row(["leaseguard", "120µs", "5.2ms"]);
        let s = t.render();
        assert!(s.contains("quorum"));
        assert!(s.lines().count() == 4);
        let widths: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert_eq!(widths[0], widths[2], "aligned rows");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new(["x", "y"]);
        t.row(["1", "2"]);
        let p = std::env::temp_dir().join("leaseguard_test_table.csv");
        t.write_csv(&p).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert_eq!(body, "x,y\n1,2\n");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn chart_has_one_row_per_series() {
        let s = timeline_chart(&["reads", "writes"], &[vec![0.0, 5.0, 10.0], vec![1.0, 1.0, 1.0]], 50.0);
        assert_eq!(s.lines().count(), 3);
    }

    #[test]
    fn bench_json_shape() {
        let p = std::env::temp_dir().join("leaseguard_test_bench.json");
        write_bench_json(
            &p,
            &[
                ("a \"quoted\" bench".to_string(), 1234.56, 99),
                ("plain".to_string(), 7.0, 1),
            ],
        )
        .unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert!(body.contains("\"suite\": \"micro\""));
        assert!(body.contains("\\\"quoted\\\""));
        assert!(body.contains("\"ops_per_sec\": 1234.6"));
        assert!(body.contains("\"ops_per_rep\": 99"));
        assert!(body.trim_end().ends_with('}'));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn scenarios_json_shape() {
        use crate::config::ConsistencyMode;
        use crate::sim::scenario::ScenarioOutcome;
        let row = ScenarioOutcome {
            scenario: "leader-crash-restart".to_string(),
            mode: ConsistencyMode::LeaseGuard,
            expect_linearizable: true,
            violations: 0,
            reads_ok: 100,
            reads_failed: 5,
            writes_ok: 50,
            writes_failed: 2,
            read_p50_us: 120,
            read_p99_us: 900,
            write_p50_us: 500,
            write_p99_us: 1500,
            elections: 2,
            faults_injected: 1,
            events_processed: 12345,
        };
        let p = std::env::temp_dir().join("leaseguard_test_scenarios.json");
        write_scenarios_json(&p, 7, &[row]).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert!(body.contains("\"suite\": \"scenarios\""));
        assert!(body.contains("\"seed\": 7"));
        assert!(body.contains("\"scenario\": \"leader-crash-restart\""));
        assert!(body.contains("\"mode\": \"leaseguard\""));
        assert!(body.contains("\"violations\": 0"));
        assert!(body.trim_end().ends_with('}'));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn fmt_us_scales() {
        assert_eq!(fmt_us(900), "900µs");
        assert_eq!(fmt_us(1500), "1.50ms");
        assert_eq!(fmt_us(2_000_000), "2.00s");
    }
}
