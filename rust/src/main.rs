//! `leaseguard` — the launcher (Layer-3 entry point).
//!
//! ```text
//! leaseguard sim      [--param k=v ...]          one simulated run + report
//! leaseguard scenarios [--json [PATH]]           Nemesis fault matrix × consistency modes
//! leaseguard figure N [--scale 0.5] [--out DIR]  regenerate paper figure N (5-11)
//! leaseguard serve    --node I --listen ADDR --peers A,B,C [--param k=v ...]
//! leaseguard stat     --addr HOST:PORT [--json] [--tail N] live server introspection
//! leaseguard bench-cluster [--param k=v ...]     in-process real cluster + open-loop client
//! leaseguard check    [--artifacts DIR]          verify AOT artifacts load & agree with scalar
//! leaseguard lint     [--root DIR] [--json]      determinism/protocol linter over the source tree
//! leaseguard params                              dump default parameters
//! ```

use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use leaseguard::cli::Args;
use leaseguard::cluster::Cluster;
use leaseguard::config::Params;
use leaseguard::figures::{run_figure, Scale};
use leaseguard::linearizability;
use leaseguard::report::{fmt_us, timeline_chart, write_scenarios_json, Table};
use leaseguard::sim::scenario;
use leaseguard::runtime::{hash_key, scalar_admission, AdmissionEngine, AdmissionInputs, EngineHandle};
use leaseguard::server::server::{Server, ServerConfig};

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    let mut params = Params::default();
    args.apply_params(&mut params).map_err(|e| anyhow!(e))?;
    match args.subcommand.as_deref() {
        Some("sim") => cmd_sim(params),
        Some("scenarios") => cmd_scenarios(args, params),
        Some("figure") => {
            let n: u32 = args
                .positionals
                .first()
                .ok_or_else(|| anyhow!("usage: leaseguard figure <5..11>"))?
                .parse()?;
            let scale = Scale(args.get_parse::<f64>("scale").map_err(|e| anyhow!(e))?.unwrap_or(1.0));
            // `--groups G` (sugar for `--param groups=G`): multi-Raft
            // axis — figure 11 sweeps group counts 1,2,…,G.
            if let Some(g) = args.get_parse::<usize>("groups").map_err(|e| anyhow!(e))? {
                params.groups = g;
                params.validate().map_err(|e| anyhow!(e))?;
            }
            let out = args.get("out").unwrap_or("results").to_string();
            std::fs::create_dir_all(&out).ok();
            let report = run_figure(n, &params, scale, &out)?;
            println!("{report}");
            Ok(())
        }
        Some("serve") => cmd_serve(args, params),
        Some("stat") => cmd_stat(args),
        Some("client") => cmd_client(args, params),
        Some("bench-cluster") => cmd_bench_cluster(args, params),
        Some("bench") => cmd_bench(args),
        Some("check") => cmd_check(&params),
        Some("lint") => cmd_lint(args),
        Some("params") => {
            print!("{}", params.dump());
            Ok(())
        }
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand '{o}'");
            }
            eprintln!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "usage: leaseguard <sim|scenarios|figure|serve|stat|bench|bench-cluster|check|lint|params> [--param k=v ...]
  sim                     one simulated run (availability timeline + latency + linearizability)
  scenarios               Nemesis fault matrix: every scenario x {leaseguard,quorum,inconsistent},
                          linearizability-checked (--json [PATH] writes SCENARIOS.json).
                          --param overrides apply to every run; a knob left at (or explicitly
                          set to) its global default gets the matrix's workload shape instead,
                          and per-scenario tunes always win
  figure <5..11>          regenerate a paper figure (--scale F, --out DIR;
                          figure 11 also takes --groups G for the multi-Raft axis)
  serve                   one real server (--node I --listen ADDR --peers A,B,C
                          --data-dir PATH for crash durability, --fsync always|group|never,
                          --snapshot-threshold N to compact the log every N committed entries)
  stat                    live introspection of a running server (--addr HOST:PORT;
                          --json for machine-readable output, --tail N flight-recorder
                          events per group, default 32)
  client                  open-loop workload against an already-running external cluster
                          (--peers A,B,C; shape it with --param duration_us/interarrival_us)
  bench                   hot-path microbenches (--json [PATH] writes BENCH_micro.json)
  bench-cluster           in-process 3-node TCP cluster + open-loop client
  check                   load AOT artifacts, cross-check engine vs scalar oracle
  lint                    self-hosted determinism/protocol linter (--root DIR, default rust/src;
                          --json for machine-readable output; exits nonzero on unwaived findings)
  params                  print all parameters and defaults";

fn cmd_sim(params: Params) -> Result<()> {
    println!("# simulated run\n{}", params.dump());
    let rep = Cluster::new(params.clone()).run();
    let reads = rep.series.ok_rate_per_sec(true);
    let writes = rep.series.ok_rate_per_sec(false);
    println!(
        "{}",
        timeline_chart(&["reads/s", "writes/s"], &[reads, writes], params.bucket_us as f64 / 1000.0)
    );
    println!(
        "reads:  p50={} p90={} p99={} n={}",
        fmt_us(rep.read_latency.p50()),
        fmt_us(rep.read_latency.p90()),
        fmt_us(rep.read_latency.p99()),
        rep.read_latency.count()
    );
    println!(
        "writes: p50={} p90={} p99={} n={}",
        fmt_us(rep.write_latency.p50()),
        fmt_us(rep.write_latency.p90()),
        fmt_us(rep.write_latency.p99()),
        rep.write_latency.count()
    );
    println!("elections={} events={} limbo={}", rep.elections, rep.events_processed, rep.limbo_len);
    // Per-shard check: with one group this is exactly the whole-history
    // check; with more it attributes violations to their Raft group.
    let map = leaseguard::shard::ShardMap::new(params.groups);
    let mut total = 0;
    for (g, viol) in linearizability::check_sharded(&rep.history, &map) {
        total += viol.len();
        for v in viol.iter().take(5) {
            println!("  group {g} op {} key {}: {}", v.op, v.key, v.detail);
        }
    }
    if total == 0 {
        println!(
            "linearizability: OK ({} ops across {} group(s))",
            rep.history.entries.len(),
            params.groups
        );
    } else {
        println!("linearizability: {total} VIOLATIONS");
        bail!("history not linearizable");
    }
    Ok(())
}

fn cmd_scenarios(args: &Args, params: Params) -> Result<()> {
    let seed = params.seed;
    println!("# Nemesis scenario matrix (seed {seed})");
    // `--param` overrides flow into every run (scenario tunes win).
    let rows = scenario::run_matrix_from(&params);
    let mut t = Table::new([
        "scenario",
        "mode",
        "linearizable",
        "reads ok/fail",
        "writes ok/fail",
        "read p99",
        "write p99",
        "elections",
        "faults",
    ]);
    for r in &rows {
        let verdict = if r.violations == 0 {
            "OK".to_string()
        } else if r.expect_linearizable {
            format!("{} VIOLATIONS", r.violations)
        } else {
            format!("{} (allowed)", r.violations)
        };
        t.row([
            r.scenario.clone(),
            r.mode.to_string(),
            verdict,
            format!("{}/{}", r.reads_ok, r.reads_failed),
            format!("{}/{}", r.writes_ok, r.writes_failed),
            fmt_us(r.read_p99_us),
            fmt_us(r.write_p99_us),
            r.elections.to_string(),
            r.faults_injected.to_string(),
        ]);
    }
    print!("{}", t.render());
    if let Some(v) = args.get("json") {
        // `--json` alone parses as the boolean "true" → default path.
        let path = if v == "true" { "SCENARIOS.json" } else { v };
        write_scenarios_json(std::path::Path::new(path), seed, &rows)?;
        println!("wrote {path}");
    }
    let broken: Vec<&scenario::ScenarioOutcome> = rows.iter().filter(|r| !r.ok()).collect();
    if !broken.is_empty() {
        for r in &broken {
            eprintln!(
                "FAIL {} / {}: {} violations where the mode promises linearizability",
                r.scenario, r.mode, r.violations
            );
        }
        bail!("{} scenario run(s) violated a promised guarantee", broken.len());
    }
    println!(
        "all {} runs honor their mode's guarantee ({} scenarios x {} modes)",
        rows.len(),
        scenario::catalog().len(),
        scenario::MATRIX_MODES.len()
    );
    Ok(())
}

fn cmd_serve(args: &Args, mut params: Params) -> Result<()> {
    // `--snapshot-threshold N` (sugar for `--param snapshot_threshold=N`):
    // snapshot + compact the log every N committed entries; 0 = never.
    if let Some(t) = args.get_parse::<u64>("snapshot-threshold").map_err(|e| anyhow!(e))? {
        params.snapshot_threshold = t;
    }
    let id: usize = args
        .get_parse("node")
        .map_err(|e| anyhow!(e))?
        .ok_or_else(|| anyhow!("--node required"))?;
    let peers: Vec<String> = args
        .get("peers")
        .ok_or_else(|| anyhow!("--peers A,B,C required"))?
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let mut peer_addrs = peers;
    if let Some(listen) = args.get("listen") {
        if id < peer_addrs.len() {
            peer_addrs[id] = listen.to_string();
        }
    }
    let engine = if params.use_xla_admission {
        Some(EngineHandle::spawn(std::path::Path::new(&params.artifacts_dir))?)
    } else {
        None
    };
    let delay_ms: u64 = args.get_parse("delay-ms").map_err(|e| anyhow!(e))?.unwrap_or(0);
    // --data-dir enables crash durability (WAL + hard state); --fsync
    // picks the ack policy (always|group|never, default group).
    let data_dir = args.get("data-dir").map(std::path::PathBuf::from);
    let fsync: leaseguard::storage::FsyncPolicy =
        args.get("fsync").unwrap_or("group").parse().map_err(|e: String| anyhow!(e))?;
    let h = Server::spawn(ServerConfig {
        id,
        peer_addrs,
        params,
        one_way_delay: Duration::from_millis(delay_ms),
        engine,
        applies: None,
        data_dir,
        fsync,
    })?;
    println!("node {id} serving on {} (ctrl-c to stop)", h.addr);
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

/// Drive the open-loop workload against servers this process does NOT
/// own (`serve` instances on other PIDs) — the shell-level smoke's load
/// generator. No in-process apply log, so no linearizability verdict
/// here; the smoke asserts recovery via `stat` instead.
fn cmd_client(args: &Args, params: Params) -> Result<()> {
    let addrs: Vec<String> = args
        .get("peers")
        .ok_or_else(|| anyhow!("--peers A,B,C required"))?
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let rep = leaseguard::client::run_open_loop(&addrs, &params, None)?;
    println!(
        "sent={} completed={} read p90={} write p90={}",
        rep.sent,
        rep.completed,
        fmt_us(rep.read_latency.p90()),
        fmt_us(rep.write_latency.p90())
    );
    Ok(())
}

fn cmd_stat(args: &Args) -> Result<()> {
    let addr = args.get("addr").ok_or_else(|| anyhow!("--addr HOST:PORT required"))?;
    let tail: u32 = args.get_parse("tail").map_err(|e| anyhow!(e))?.unwrap_or(32);
    let snap = leaseguard::client::fetch_status(addr, tail)?;
    if args.get("json").is_some() {
        println!("{}", snap.to_json());
        return Ok(());
    }
    println!("server {addr}: {} group(s)", snap.groups.len());
    println!(
        "process: wal_barriers={} wal_syncs={} reads_batched={} engine_batches={}",
        snap.wal_barriers, snap.wal_syncs, snap.reads_batched, snap.engine_batches
    );
    for g in &snap.groups {
        println!(
            "group {} [{}] term={} commit={} limbo={}",
            g.group,
            if g.is_leader { "LEADER" } else { "follower" },
            g.term,
            g.commit_index,
            g.limbo_len
        );
        println!(
            "  reads:  lease_local={} inherited={} quorum={} deferred={} rejected: no_lease={} limbo={}",
            g.reads_lease_local,
            g.reads_lease_inherited,
            g.reads_quorum,
            g.reads_deferred,
            g.reads_rejected_no_lease,
            g.reads_rejected_limbo
        );
        println!(
            "  writes: accepted={} blocked_transfer={} rejected_gate={}  elections_won={}",
            g.writes_accepted, g.writes_blocked_transfer, g.writes_rejected_gate, g.elections_won
        );
        println!(
            "  snaps:  taken={} installed={} rejected={} last_snapshot_index={}",
            g.snapshots_taken, g.snapshots_installed, g.snapshots_rejected, g.last_snapshot_index
        );
        for (name, st) in leaseguard::obs::registry::STAGE_NAMES.iter().zip(g.stages.iter()) {
            if st.count > 0 {
                println!(
                    "  stage {name:<9} n={:<7} p50={} p90={} p99={} max={}",
                    st.count,
                    fmt_us(st.p50_us),
                    fmt_us(st.p90_us),
                    fmt_us(st.p99_us),
                    fmt_us(st.max_us)
                );
            }
        }
        if !g.events.is_empty() {
            println!("  last {} flight-recorder event(s):", g.events.len());
            for e in &g.events {
                println!("  {}", e.render());
            }
        }
    }
    Ok(())
}

fn cmd_bench_cluster(args: &Args, params: Params) -> Result<()> {
    use leaseguard::figures::realcluster::RealCluster;
    let engine = if params.use_xla_admission {
        Some(EngineHandle::spawn(std::path::Path::new(&params.artifacts_dir))?)
    } else {
        None
    };
    let delay_ms: u64 = args.get_parse("delay-ms").map_err(|e| anyhow!(e))?.unwrap_or(0);
    let cluster = RealCluster::spawn(&params, Duration::from_millis(delay_ms), engine)?;
    if params.groups > 1 {
        cluster
            .wait_for_all_leaders(params.groups, Duration::from_secs(10))
            .ok_or_else(|| anyhow!("not all {} groups elected", params.groups))?;
    } else {
        cluster
            .wait_for_leader(Duration::from_secs(10))
            .ok_or_else(|| anyhow!("no leader"))?;
    }
    let rep =
        leaseguard::client::run_open_loop(&cluster.addrs, &params, Some(cluster.applies.clone()))?;
    cluster.shutdown();
    println!(
        "sent={} completed={} read p90={} write p90={}",
        rep.sent,
        rep.completed,
        fmt_us(rep.read_latency.p90()),
        fmt_us(rep.write_latency.p90())
    );
    let map = leaseguard::shard::ShardMap::new(params.groups);
    let viol: usize =
        linearizability::check_sharded(&rep.history, &map).iter().map(|(_, v)| v.len()).sum();
    println!(
        "linearizability: {}",
        if viol == 0 { "OK".to_string() } else { format!("{viol} VIOLATIONS") }
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    println!("== leaseguard microbenches ==");
    let results = leaseguard::bench::run_suite();
    if let Some(v) = args.get("json") {
        // `--json` alone parses as the boolean "true" → default path.
        let path = if v == "true" { "BENCH_micro.json" } else { v };
        leaseguard::bench::write_json(std::path::Path::new(path), &results)?;
        println!("wrote {path}");
    }
    println!("== done ==");
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    // Root resolution: --root wins; else prefer ./rust/src (running
    // from the repo root), else the manifest-relative source dir
    // (running via `cargo run` from anywhere inside the workspace).
    let root = match args.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => {
            let cwd_src = std::path::Path::new("rust/src");
            if cwd_src.is_dir() {
                cwd_src.to_path_buf()
            } else {
                std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src")
            }
        }
    };
    let report = leaseguard::lint::lint_tree(&root)
        .map_err(|e| anyhow!("lint walk of {} failed: {e}", root.display()))?;
    if args.get("json").is_some() {
        print!("{}", report.to_json());
    } else {
        print!("{}", report.render_text());
    }
    if report.unwaived_count() > 0 {
        bail!("{} unwaived lint finding(s)", report.unwaived_count());
    }
    Ok(())
}

fn cmd_check(params: &Params) -> Result<()> {
    let dir = std::path::Path::new(&params.artifacts_dir);
    let engine = AdmissionEngine::load(dir)?;
    println!("loaded artifacts from {}: shapes {:?}", dir.display(), engine.shapes());
    // Cross-check against the scalar oracle on randomized cases.
    let mut rng = leaseguard::prob::Rng::new(2024);
    let mut checked = 0;
    for _ in 0..50 {
        let nq = 1 + rng.below(900) as usize;
        let nl = rng.below(300) as usize;
        let inp = AdmissionInputs {
            query_hashes: (0..nq).map(|_| hash_key(rng.below(64) as u32)).collect(),
            limbo_hashes: (0..nl).map(|_| hash_key(rng.below(64) as u32)).collect(),
            commit_age_us: rng.below(2_000_000) as i64,
            delta_us: 1_000_000,
            own_term_commit: rng.chance(0.25),
        };
        let got = engine.admit(&inp)?;
        if got != scalar_admission(&inp) {
            bail!("engine/oracle mismatch on case {inp:?}");
        }
        checked += 1;
    }
    println!("engine matches scalar oracle on {checked} randomized cases — OK");
    Ok(())
}
