//! The server main loop: the same [`Node`] the simulator drives, behind
//! real threads, sockets, and clocks (the paper's LogCabin role, §7).
//!
//! Two rules structure every loop iteration:
//!
//! * **Persist before route.** Outputs produced by the node are buffered,
//!   the durable deltas (`current_term`, `voted_for`, log appends and
//!   truncations) are written to [`crate::storage::Storage`] and synced,
//!   and only then are the outputs externalized. A vote, an
//!   AppendEntries ack, or a replicated entry is never on the wire
//!   before it is on disk (Raft §5's persistence points).
//! * **Never block on a peer.** The router's send path only consults its
//!   map of live links; reconnection lives on the [`Dialer`] thread and
//!   established links come back through the event channel.

use std::collections::{BinaryHeap, HashMap};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::clock::real::RealClock;
use crate::clock::Clock;
use crate::raft::{Message, Node, NodeConfig, Output, Role, TimerKind};
use crate::runtime::{scalar_admission, EngineHandle};
use crate::shard::{group_seed, GroupId, ShardMap, ShardRouter};
use crate::storage::{FsyncPolicy, MultiStorage};
use crate::{Micros, NodeId};

use super::dialer::Dialer;
use super::transport::{bind_reuse, write_frame, DelayedSender, FrameReader};
use super::wire::{self, ClientResp, Enc, Frame};

/// Shared in-process apply log (real-mode linearizability input). All
/// servers of an in-process cluster push (key, value, monotonic µs).
pub type SharedApplies = Arc<Mutex<Vec<(u32, u64, Micros)>>>;

#[derive(Clone)]
pub struct ServerConfig {
    pub id: NodeId,
    /// Peer addresses, indexed by node id (own slot = listen address,
    /// or `"auto"` for an ephemeral port).
    pub peer_addrs: Vec<String>,
    pub params: crate::config::Params,
    /// Injected one-way delay on *peer* links (the paper's `tc` WAN
    /// emulation; client links are unaffected, §7.2).
    pub one_way_delay: Duration,
    /// Batched XLA read admission (None = scalar path).
    pub engine: Option<EngineHandle>,
    pub applies: Option<SharedApplies>,
    /// Durable-state directory (WAL + hard-state). None = volatile mode:
    /// the pre-durability behavior, still used by throughput benches.
    pub data_dir: Option<PathBuf>,
    /// When an append becomes durable relative to ack (ignored without
    /// `data_dir`).
    pub fsync: FsyncPolicy,
}

/// Externally visible, lock-free server status.
///
/// With `params.groups > 1` the scalar fields keep their historical
/// group-0 semantics (single-group callers are unaffected) and the
/// bitmask fields report all groups: bit g is set when this server
/// leads / has committed in group g.
#[derive(Default)]
pub struct Status {
    pub is_leader: AtomicBool,
    pub term: AtomicU64,
    pub commit_index: AtomicU64,
    pub limbo_len: AtomicU64,
    pub reads_batched: AtomicU64,
    pub engine_batches: AtomicU64,
    /// Bit per group: this server is that group's leader.
    pub leader_groups: AtomicU64,
    /// Bit per group: that group's commit index is >= 1 here.
    pub committed_groups: AtomicU64,
    /// Cross-group durability barriers hit (event batches that had
    /// anything to persist).
    pub wal_barriers: AtomicU64,
    /// Shared fsyncs those barriers issued. The multi-Raft claim is
    /// `wal_syncs ≈ wal_barriers` regardless of group count — G dirty
    /// groups cost one shared sync, not G.
    pub wal_syncs: AtomicU64,
}

enum Ev {
    /// New inbound connection: the write half for replies.
    NewConn(u64, TcpStream),
    Peer(GroupId, Message),
    Client { conn: u64, req: wire::ClientReq },
    ConnClosed(u64),
    /// The background dialer established an outgoing peer link.
    PeerUp(NodeId, DelayedSender),
    Shutdown,
}

pub struct ServerHandle {
    pub id: NodeId,
    pub addr: String,
    pub status: Arc<Status>,
    tx: Sender<Ev>,
    main: Option<JoinHandle<()>>,
    accept: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
}

impl ServerHandle {
    /// Stop the server (models a crash: connections drop, no flush).
    pub fn kill(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Ev::Shutdown);
        let _ = TcpStream::connect(&self.addr); // unblock acceptor
        if let Some(h) = self.main.take() {
            let _ = h.join();
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

pub struct Server;

impl Server {
    /// Bind and spawn. The actual bound address is in the handle.
    pub fn spawn(mut cfg: ServerConfig) -> std::io::Result<ServerHandle> {
        let bind_to = if cfg.peer_addrs[cfg.id] == "auto" {
            "127.0.0.1:0".to_string()
        } else {
            cfg.peer_addrs[cfg.id].clone()
        };
        // SO_REUSEADDR: a respawn on a fixed port must not trip over the
        // killed predecessor's TIME_WAIT sockets.
        let listener = bind_reuse(&bind_to)?;
        let addr = listener.local_addr()?.to_string();
        cfg.peer_addrs[cfg.id] = addr.clone();
        let (tx, rx) = channel::<Ev>();
        let stop = Arc::new(AtomicBool::new(false));
        let status = Arc::new(Status::default());
        let id = cfg.id;

        let accept = {
            let tx = tx.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut next_conn: u64 = 1;
                for stream in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    stream.set_nodelay(true).ok();
                    let conn = next_conn;
                    next_conn += 1;
                    if let Ok(w) = stream.try_clone() {
                        if tx.send(Ev::NewConn(conn, w)).is_err() {
                            break;
                        }
                    }
                    let tx = tx.clone();
                    std::thread::spawn(move || reader_loop(stream, conn, tx));
                }
            })
        };

        let main = {
            let status = status.clone();
            let stop = stop.clone();
            let tx = tx.clone(); // for the dialer's PeerUp deliveries
            std::thread::spawn(move || main_loop(cfg, tx, rx, status, stop))
        };

        Ok(ServerHandle { id, addr, status, tx, main: Some(main), accept: Some(accept), stop })
    }
}

/// Decode frames off one inbound connection into the event channel.
/// Buffered reads + a reusable body scratch: zero per-frame allocation
/// on a warm connection (decode still owns its output).
fn reader_loop(stream: TcpStream, conn: u64, tx: Sender<Ev>) {
    let mut frames = FrameReader::new(stream);
    loop {
        match frames.next_frame() {
            Ok(Some(body)) => match wire::decode(body) {
                Ok(Frame::Raft { group, msg, .. }) => {
                    if tx.send(Ev::Peer(group, msg)).is_err() {
                        break;
                    }
                }
                Ok(Frame::HelloPeer { .. }) => {}
                Ok(Frame::ClientReq(req)) => {
                    if tx.send(Ev::Client { conn, req }).is_err() {
                        break;
                    }
                }
                Ok(Frame::ClientResp(_)) | Err(_) => break, // protocol error
            },
            _ => break,
        }
    }
    let _ = tx.send(Ev::ConnClosed(conn));
}

/// Mutable state the output router needs (bundled to keep borrows sane).
///
/// One Router serves every group of the process: all groups share the
/// peer map (G groups × one link per peer, not G sockets), and timers
/// are keyed by group so each fires back into its own node.
struct Router {
    cfg: ServerConfig,
    timers: BinaryHeap<std::cmp::Reverse<(Micros, GroupId, u8)>>,
    peers: HashMap<NodeId, DelayedSender>,
    /// Owns reconnection for everything missing from `peers`.
    dialer: Dialer,
    op_conn: HashMap<u64, u64>,
    conns: HashMap<u64, TcpStream>,
    /// Reusable frame-encode scratch for every outgoing frame.
    enc: Enc,
}

fn kind_of(k: TimerKind) -> u8 {
    match k {
        TimerKind::Election => 0,
        TimerKind::Heartbeat => 1,
        TimerKind::LeaseCheck => 2,
    }
}

fn kind_from(b: u8) -> TimerKind {
    match b {
        0 => TimerKind::Election,
        1 => TimerKind::Heartbeat,
        _ => TimerKind::LeaseCheck,
    }
}

impl Router {
    /// Route a batch of group-tagged outputs, draining `outs` (the
    /// caller reuses the buffer). Callers must have persisted durable
    /// state first — this is the externalization point.
    fn handle(&mut self, outs: &mut Vec<(GroupId, Output)>) {
        // A replication fan-out arrives as Sends whose payloads repeat
        // (shared EntryBatch + one round seq): encode once, hand every
        // DelayedSender the same Arc'd bytes. Keyed by (group, message)
        // — per-group fan-outs are contiguous within a batch, so two
        // slots still absorb one lagging peer's catch-up frame without
        // evicting the aligned majority's frame.
        let mut encoded: Vec<(GroupId, Message, Arc<[u8]>)> = Vec::with_capacity(2);
        for (g, o) in outs.drain(..) {
            match o {
                Output::Send { to, msg } => {
                    // No link: drop the frame. The dialer is already
                    // redialing (down links are reported the moment a
                    // send fails) and Raft's timers re-send; blocking
                    // the main loop on a connect would stall every
                    // other peer and client.
                    let Some(sender) = self.peers.get(&to) else { continue };
                    // Cheap compare: shared-batch views hit the
                    // pointer-equality fast path.
                    let body: Arc<[u8]> =
                        match encoded.iter().find(|(eg, m, _)| *eg == g && *m == msg) {
                            Some((_, _, b)) => b.clone(),
                            None => {
                                self.enc.reset();
                                wire::encode_raft_into(self.cfg.id, g, &msg, &mut self.enc);
                                let b: Arc<[u8]> = Arc::from(&self.enc.buf[..]);
                                if encoded.len() == 2 {
                                    encoded.remove(0);
                                }
                                encoded.push((g, msg, b.clone()));
                                b
                            }
                        };
                    if !sender.send(body) {
                        self.peers.remove(&to);
                        self.dialer.notify_down(to);
                    }
                }
                Output::SetTimer { kind, after } => {
                    self.timers.push(std::cmp::Reverse((
                        RealClock::monotonic_us() + after,
                        g,
                        kind_of(kind),
                    )));
                }
                Output::Reply { op, result } => {
                    if let Some(conn) = self.op_conn.remove(&op) {
                        if let Some(stream) = self.conns.get_mut(&conn) {
                            let resp = Frame::ClientResp(ClientResp {
                                op,
                                exec_us: RealClock::monotonic_us(),
                                result,
                            });
                            self.enc.reset();
                            wire::encode_into(&resp, &mut self.enc);
                            if write_frame(stream, &self.enc.buf).is_err() {
                                self.conns.remove(&conn);
                            }
                        }
                    }
                }
                Output::Applied { key, value } => {
                    if let Some(a) = &self.cfg.applies {
                        a.lock().unwrap().push((key, value, RealClock::monotonic_us()));
                    }
                }
                Output::ElectedLeader { .. } | Output::SteppedDown => {}
            }
        }
    }
}

/// Flush every group's durable deltas to its storage namespace, then
/// hit ONE cross-group durability barrier. Must run after node
/// interactions and before their outputs are routed. Without a data dir
/// the watermarks are drained and dropped (volatile mode). Storage
/// errors are fatal: continuing to vote or ack on a broken disk
/// silently voids every crash-safety guarantee.
fn persist_all(shards: &mut ShardRouter, storage: &mut Option<MultiStorage>, status: &Status) {
    let Some(ms) = storage.as_mut() else {
        for (_, node) in shards.iter_mut() {
            node.take_log_dirty();
        }
        return;
    };
    let mut wrote = false;
    for (g, node) in shards.iter_mut() {
        let s = ms.group(g as usize);
        s.persist_hard_state(node.term(), node.voted_for()).expect("hard-state persist");
        if let Some((from, truncated)) = node.take_log_dirty() {
            if truncated {
                s.truncate(from - 1).expect("wal truncate");
            }
            let last = node.log().last_index();
            for (idx, e) in node.log().iter_range(from - 1, last) {
                s.append(idx, e).expect("wal append");
            }
            wrote = true;
        }
    }
    let syncs_before = ms.syncs();
    ms.barrier().expect("wal barrier");
    if wrote {
        status.wal_barriers.fetch_add(1, Ordering::Relaxed);
        status.wal_syncs.fetch_add(ms.syncs() - syncs_before, Ordering::Relaxed);
    }
}

fn main_loop(
    cfg: ServerConfig,
    tx: Sender<Ev>,
    rx: Receiver<Ev>,
    status: Arc<Status>,
    stop: Arc<AtomicBool>,
) {
    let mut clock = RealClock::new(cfg.params.clock_error_us);
    let now = clock.interval_now();
    let groups = cfg.params.groups;
    // One Node per group, each seeded independently (group 0 keeps the
    // process seed so single-group deployments replay unchanged).
    // Durable mode recovers every group from its own `g<id>/` namespace
    // under one MultiStorage.
    let mut pending: Vec<(GroupId, Output)> = Vec::new();
    let (mut storage, nodes) = match &cfg.data_dir {
        Some(dir) => {
            let (ms, durable) =
                MultiStorage::open(dir, groups, cfg.fsync).expect("open storage");
            let mut nodes = Vec::with_capacity(groups);
            for (g, d) in durable.into_iter().enumerate() {
                let node_cfg = NodeConfig::from_params(cfg.id, &cfg.params);
                let (n, o) =
                    Node::recover(node_cfg, group_seed(cfg.params.seed, g as GroupId), d, now);
                pending.extend(o.into_iter().map(|out| (g as GroupId, out)));
                nodes.push(n);
            }
            (Some(ms), nodes)
        }
        None => {
            let mut nodes = Vec::with_capacity(groups);
            for g in 0..groups {
                let node_cfg = NodeConfig::from_params(cfg.id, &cfg.params);
                let (n, o) = Node::new(node_cfg, group_seed(cfg.params.seed, g as GroupId), now);
                pending.extend(o.into_iter().map(|out| (g as GroupId, out)));
                nodes.push(n);
            }
            (None, nodes)
        }
    };
    let mut shards = ShardRouter::new(ShardMap::new(groups), nodes);
    let engine = cfg.engine.clone();
    let dialer = {
        let tx = tx.clone();
        Dialer::spawn(
            cfg.id,
            cfg.peer_addrs.clone(),
            cfg.one_way_delay,
            cfg.params.seed ^ (cfg.id as u64) << 32,
            move |peer, sender| tx.send(Ev::PeerUp(peer, sender)).is_ok(),
        )
    };
    // Every peer link starts down; the dialer brings them up.
    for p in 0..cfg.peer_addrs.len() {
        if p != cfg.id {
            dialer.notify_down(p);
        }
    }
    let mut router = Router {
        cfg,
        timers: BinaryHeap::new(),
        peers: HashMap::new(),
        dialer,
        op_conn: HashMap::new(),
        conns: HashMap::new(),
        enc: Enc::new(),
    };
    persist_all(&mut shards, &mut storage, &status);
    router.handle(&mut pending);

    let publish = |shards: &ShardRouter, status: &Status| {
        // Scalars keep group-0 semantics; bitmasks cover all groups.
        let n0 = shards.node(0);
        status.is_leader.store(n0.role() == Role::Leader, Ordering::Relaxed);
        status.term.store(n0.term(), Ordering::Relaxed);
        status.commit_index.store(n0.commit_index(), Ordering::Relaxed);
        let mut limbo = 0u64;
        let mut leaders = 0u64;
        let mut committed = 0u64;
        for (g, n) in shards.iter() {
            limbo += n.lease_state().map(|l| l.limbo_len()).unwrap_or(0);
            if n.role() == Role::Leader {
                leaders |= 1 << g;
            }
            if n.commit_index() >= 1 {
                committed |= 1 << g;
            }
        }
        status.limbo_len.store(limbo, Ordering::Relaxed);
        status.leader_groups.store(leaders, Ordering::Relaxed);
        status.committed_groups.store(committed, Ordering::Relaxed);
    };

    // Per-group read batches, reused across iterations.
    let mut read_batches: Vec<Vec<(u64, u32)>> = vec![Vec::new(); groups];
    while !stop.load(Ordering::SeqCst) {
        // Fire due timers. Status publication is folded into the
        // timer-fire branch: an idle loop iteration performs no atomic
        // stores (the post-drain publish below covers event-driven
        // changes).
        let now_us = RealClock::monotonic_us();
        let mut timer_fired = false;
        while let Some(&std::cmp::Reverse((due, g, kb))) = router.timers.peek() {
            if due > now_us {
                break;
            }
            router.timers.pop();
            timer_fired = true;
            let now = clock.interval_now();
            let outs = shards.node_mut(g).on_timer(now, kind_from(kb));
            pending.extend(outs.into_iter().map(|o| (g, o)));
        }
        if timer_fired {
            // A timer can start an election (term bump + self-vote) —
            // durable before the RequestVotes leave.
            persist_all(&mut shards, &mut storage, &status);
            router.handle(&mut pending);
            publish(&shards, &status);
        }
        // Wait for events until the next timer (bounded poll).
        let wait_us = router
            .timers
            .peek()
            .map(|&std::cmp::Reverse((due, _, _))| (due - RealClock::monotonic_us()).max(0) as u64)
            .unwrap_or(2_000)
            .min(2_000);
        let first = match rx.recv_timeout(Duration::from_micros(wait_us)) {
            Ok(ev) => Some(ev),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
            Err(_) => break,
        };
        let mut events: Vec<Ev> = Vec::new();
        if let Some(f) = first {
            events.push(f);
        }
        while let Ok(ev) = rx.try_recv() {
            events.push(ev);
            if events.len() >= 1024 {
                break;
            }
        }
        let had_events = !events.is_empty();
        for b in &mut read_batches {
            b.clear();
        }
        for ev in events {
            match ev {
                Ev::Shutdown => return,
                Ev::NewConn(conn, stream) => {
                    router.conns.insert(conn, stream);
                }
                Ev::PeerUp(peer, sender) => {
                    router.peers.insert(peer, sender);
                }
                Ev::Peer(g, msg) => {
                    // A frame for a group this process doesn't host
                    // (mismatched configs) is dropped, not a panic.
                    if (g as usize) < shards.len() {
                        let now = clock.interval_now();
                        let outs = shards.node_mut(g).on_message(now, msg);
                        pending.extend(outs.into_iter().map(|o| (g, o)));
                    }
                }
                Ev::Client { conn, req } => {
                    router.op_conn.insert(req.op, conn);
                    // The server routes by key through the canonical
                    // ShardMap — clients need not be trusted to route.
                    let g = shards.group_for_key(req.key);
                    match req.write_value {
                        Some(v) => {
                            let now = clock.interval_now();
                            let outs = shards.node_mut(g).client_write(
                                now,
                                req.op,
                                req.key,
                                v,
                                req.payload.len() as u32,
                            );
                            pending.extend(outs.into_iter().map(|o| (g, o)));
                        }
                        None => read_batches[g as usize].push((req.op, req.key)),
                    }
                }
                Ev::ConnClosed(conn) => {
                    router.conns.remove(&conn);
                    // Purge op→conn routes owned by the closed conn:
                    // their replies have nowhere to go, and without this
                    // the map grows without bound under client churn.
                    router.op_conn.retain(|_, c| *c != conn);
                }
            }
        }
        // Reads batched per loop iteration and per group: one admission
        // decision per group for everything that arrived together (the
        // XLA engine's raison d'être during post-election thundering
        // herds).
        for (gi, batch) in read_batches.iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            status.reads_batched.fetch_add(batch.len() as u64, Ordering::Relaxed);
            let now = clock.interval_now();
            let g = gi as GroupId;
            let outs = shards.node_mut(g).client_read_batch(now, batch, |inp| match &engine {
                Some(e) => {
                    status.engine_batches.fetch_add(1, Ordering::Relaxed);
                    e.admit(inp).unwrap_or_else(|_| scalar_admission(inp))
                }
                None => scalar_admission(inp),
            });
            pending.extend(outs.into_iter().map(|o| (g, o)));
        }
        // One durability barrier for everything the batch changed across
        // ALL groups (the `Group` fsync policy's whole point, now shared
        // cross-group), then externalize. Skipped on idle iterations
        // (recv timeout with no due timers) — those change no node state.
        if had_events {
            persist_all(&mut shards, &mut storage, &status);
            router.handle(&mut pending);
            publish(&shards, &status);
        }
    }
}
