//! The server main loop: the same [`Node`] the simulator drives, behind
//! real threads, sockets, and clocks (the paper's LogCabin role, §7).

use std::collections::{BinaryHeap, HashMap};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::clock::real::RealClock;
use crate::clock::Clock;
use crate::raft::{Message, Node, NodeConfig, Output, Role, TimerKind};
use crate::runtime::{scalar_admission, EngineHandle};
use crate::{Micros, NodeId};

use super::transport::{write_frame, DelayedSender, FrameReader};
use super::wire::{self, ClientResp, Enc, Frame};

/// Shared in-process apply log (real-mode linearizability input). All
/// servers of an in-process cluster push (key, value, monotonic µs).
pub type SharedApplies = Arc<Mutex<Vec<(u32, u64, Micros)>>>;

#[derive(Clone)]
pub struct ServerConfig {
    pub id: NodeId,
    /// Peer addresses, indexed by node id (own slot = listen address,
    /// or `"auto"` for an ephemeral port).
    pub peer_addrs: Vec<String>,
    pub params: crate::config::Params,
    /// Injected one-way delay on *peer* links (the paper's `tc` WAN
    /// emulation; client links are unaffected, §7.2).
    pub one_way_delay: Duration,
    /// Batched XLA read admission (None = scalar path).
    pub engine: Option<EngineHandle>,
    pub applies: Option<SharedApplies>,
}

/// Externally visible, lock-free server status.
#[derive(Default)]
pub struct Status {
    pub is_leader: AtomicBool,
    pub term: AtomicU64,
    pub commit_index: AtomicU64,
    pub limbo_len: AtomicU64,
    pub reads_batched: AtomicU64,
    pub engine_batches: AtomicU64,
}

enum Ev {
    /// New inbound connection: the write half for replies.
    NewConn(u64, TcpStream),
    Peer(Message),
    Client { conn: u64, req: wire::ClientReq },
    ConnClosed(u64),
    Shutdown,
}

pub struct ServerHandle {
    pub id: NodeId,
    pub addr: String,
    pub status: Arc<Status>,
    tx: Sender<Ev>,
    main: Option<JoinHandle<()>>,
    accept: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
}

impl ServerHandle {
    /// Stop the server (models a crash: connections drop, no flush).
    pub fn kill(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Ev::Shutdown);
        let _ = TcpStream::connect(&self.addr); // unblock acceptor
        if let Some(h) = self.main.take() {
            let _ = h.join();
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

pub struct Server;

impl Server {
    /// Bind and spawn. The actual bound address is in the handle.
    pub fn spawn(mut cfg: ServerConfig) -> std::io::Result<ServerHandle> {
        let bind_to = if cfg.peer_addrs[cfg.id] == "auto" {
            "127.0.0.1:0".to_string()
        } else {
            cfg.peer_addrs[cfg.id].clone()
        };
        let listener = TcpListener::bind(&bind_to)?;
        let addr = listener.local_addr()?.to_string();
        cfg.peer_addrs[cfg.id] = addr.clone();
        let (tx, rx) = channel::<Ev>();
        let stop = Arc::new(AtomicBool::new(false));
        let status = Arc::new(Status::default());
        let id = cfg.id;

        let accept = {
            let tx = tx.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut next_conn: u64 = 1;
                for stream in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    stream.set_nodelay(true).ok();
                    let conn = next_conn;
                    next_conn += 1;
                    if let Ok(w) = stream.try_clone() {
                        if tx.send(Ev::NewConn(conn, w)).is_err() {
                            break;
                        }
                    }
                    let tx = tx.clone();
                    std::thread::spawn(move || reader_loop(stream, conn, tx));
                }
            })
        };

        let main = {
            let status = status.clone();
            let stop = stop.clone();
            std::thread::spawn(move || main_loop(cfg, rx, status, stop))
        };

        Ok(ServerHandle { id, addr, status, tx, main: Some(main), accept: Some(accept), stop })
    }
}

/// Decode frames off one inbound connection into the event channel.
/// Buffered reads + a reusable body scratch: zero per-frame allocation
/// on a warm connection (decode still owns its output).
fn reader_loop(stream: TcpStream, conn: u64, tx: Sender<Ev>) {
    let mut frames = FrameReader::new(stream);
    loop {
        match frames.next_frame() {
            Ok(Some(body)) => match wire::decode(body) {
                Ok(Frame::Raft { msg, .. }) => {
                    if tx.send(Ev::Peer(msg)).is_err() {
                        break;
                    }
                }
                Ok(Frame::HelloPeer { .. }) => {}
                Ok(Frame::ClientReq(req)) => {
                    if tx.send(Ev::Client { conn, req }).is_err() {
                        break;
                    }
                }
                Ok(Frame::ClientResp(_)) | Err(_) => break, // protocol error
            },
            _ => break,
        }
    }
    let _ = tx.send(Ev::ConnClosed(conn));
}

/// Mutable state the output router needs (bundled to keep borrows sane).
struct Router {
    cfg: ServerConfig,
    timers: BinaryHeap<std::cmp::Reverse<(Micros, u8)>>,
    peers: HashMap<NodeId, DelayedSender>,
    op_conn: HashMap<u64, u64>,
    conns: HashMap<u64, TcpStream>,
    /// Reusable frame-encode scratch for every outgoing frame.
    enc: Enc,
}

fn kind_of(k: TimerKind) -> u8 {
    match k {
        TimerKind::Election => 0,
        TimerKind::Heartbeat => 1,
        TimerKind::LeaseCheck => 2,
    }
}

fn kind_from(b: u8) -> TimerKind {
    match b {
        0 => TimerKind::Election,
        1 => TimerKind::Heartbeat,
        _ => TimerKind::LeaseCheck,
    }
}

impl Router {
    fn handle(&mut self, outs: Vec<Output>) {
        // A replication fan-out arrives as Sends whose payloads repeat
        // (shared EntryBatch + one round seq): encode once, hand every
        // DelayedSender the same Arc'd bytes. Two slots so one lagging
        // peer's catch-up frame interleaved mid-round doesn't evict the
        // aligned majority's frame.
        let mut encoded: Vec<(Message, Arc<[u8]>)> = Vec::with_capacity(2);
        for o in outs {
            match o {
                Output::Send { to, msg } => {
                    if !self.peers.contains_key(&to) {
                        if let Some(s) =
                            connect_peer(&self.cfg.peer_addrs[to], self.cfg.id, self.cfg.one_way_delay)
                        {
                            self.peers.insert(to, s);
                        }
                    }
                    if let Some(sender) = self.peers.get(&to) {
                        // Cheap compare: shared-batch views hit the
                        // pointer-equality fast path.
                        let body: Arc<[u8]> = match encoded.iter().find(|(m, _)| *m == msg) {
                            Some((_, b)) => b.clone(),
                            None => {
                                self.enc.reset();
                                wire::encode_raft_into(self.cfg.id, &msg, &mut self.enc);
                                let b: Arc<[u8]> = Arc::from(&self.enc.buf[..]);
                                if encoded.len() == 2 {
                                    encoded.remove(0);
                                }
                                encoded.push((msg, b.clone()));
                                b
                            }
                        };
                        if !sender.send(body) {
                            self.peers.remove(&to); // reconnect next send
                        }
                    }
                }
                Output::SetTimer { kind, after } => {
                    self.timers
                        .push(std::cmp::Reverse((RealClock::monotonic_us() + after, kind_of(kind))));
                }
                Output::Reply { op, result } => {
                    if let Some(conn) = self.op_conn.remove(&op) {
                        if let Some(stream) = self.conns.get_mut(&conn) {
                            let resp = Frame::ClientResp(ClientResp {
                                op,
                                exec_us: RealClock::monotonic_us(),
                                result,
                            });
                            self.enc.reset();
                            wire::encode_into(&resp, &mut self.enc);
                            if write_frame(stream, &self.enc.buf).is_err() {
                                self.conns.remove(&conn);
                            }
                        }
                    }
                }
                Output::Applied { key, value } => {
                    if let Some(a) = &self.cfg.applies {
                        a.lock().unwrap().push((key, value, RealClock::monotonic_us()));
                    }
                }
                Output::ElectedLeader { .. } | Output::SteppedDown => {}
            }
        }
    }
}

fn main_loop(cfg: ServerConfig, rx: Receiver<Ev>, status: Arc<Status>, stop: Arc<AtomicBool>) {
    let mut clock = RealClock::new(cfg.params.clock_error_us);
    let now = clock.interval_now();
    let (mut node, outs) =
        Node::new(NodeConfig::from_params(cfg.id, &cfg.params), cfg.params.seed, now);
    let engine = cfg.engine.clone();
    let mut router = Router {
        cfg,
        timers: BinaryHeap::new(),
        peers: HashMap::new(),
        op_conn: HashMap::new(),
        conns: HashMap::new(),
        enc: Enc::new(),
    };
    router.handle(outs);

    let publish = |node: &Node, status: &Status| {
        status.is_leader.store(node.role() == Role::Leader, Ordering::Relaxed);
        status.term.store(node.term(), Ordering::Relaxed);
        status.commit_index.store(node.commit_index(), Ordering::Relaxed);
        status
            .limbo_len
            .store(node.lease_state().map(|l| l.limbo_len()).unwrap_or(0), Ordering::Relaxed);
    };

    let mut read_batch: Vec<(u64, u32)> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        // Fire due timers. Status publication is folded into the
        // timer-fire branch: an idle loop iteration performs no atomic
        // stores (the post-drain publish below covers event-driven
        // changes).
        let now_us = RealClock::monotonic_us();
        let mut timer_fired = false;
        while let Some(&std::cmp::Reverse((due, kb))) = router.timers.peek() {
            if due > now_us {
                break;
            }
            router.timers.pop();
            timer_fired = true;
            let now = clock.interval_now();
            let outs = node.on_timer(now, kind_from(kb));
            router.handle(outs);
        }
        if timer_fired {
            publish(&node, &status);
        }
        // Wait for events until the next timer (bounded poll).
        let wait_us = router
            .timers
            .peek()
            .map(|&std::cmp::Reverse((due, _))| (due - RealClock::monotonic_us()).max(0) as u64)
            .unwrap_or(2_000)
            .min(2_000);
        let first = match rx.recv_timeout(Duration::from_micros(wait_us)) {
            Ok(ev) => Some(ev),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
            Err(_) => break,
        };
        let mut events: Vec<Ev> = Vec::new();
        if let Some(f) = first {
            events.push(f);
        }
        while let Ok(ev) = rx.try_recv() {
            events.push(ev);
            if events.len() >= 1024 {
                break;
            }
        }
        let had_events = !events.is_empty();
        read_batch.clear();
        for ev in events {
            match ev {
                Ev::Shutdown => return,
                Ev::NewConn(conn, stream) => {
                    router.conns.insert(conn, stream);
                }
                Ev::Peer(msg) => {
                    let now = clock.interval_now();
                    let outs = node.on_message(now, msg);
                    router.handle(outs);
                }
                Ev::Client { conn, req } => {
                    router.op_conn.insert(req.op, conn);
                    match req.write_value {
                        Some(v) => {
                            let now = clock.interval_now();
                            let outs =
                                node.client_write(now, req.op, req.key, v, req.payload.len() as u32);
                            router.handle(outs);
                        }
                        None => read_batch.push((req.op, req.key)),
                    }
                }
                Ev::ConnClosed(conn) => {
                    router.conns.remove(&conn);
                    // Purge op→conn routes owned by the closed conn:
                    // their replies have nowhere to go, and without this
                    // the map grows without bound under client churn.
                    router.op_conn.retain(|_, c| *c != conn);
                }
            }
        }
        // Reads batched per loop iteration: one admission decision for
        // everything that arrived together (the XLA engine's raison
        // d'être during post-election thundering herds).
        if !read_batch.is_empty() {
            status.reads_batched.fetch_add(read_batch.len() as u64, Ordering::Relaxed);
            let now = clock.interval_now();
            let outs = node.client_read_batch(now, &read_batch, |inp| match &engine {
                Some(e) => {
                    status.engine_batches.fetch_add(1, Ordering::Relaxed);
                    e.admit(inp).unwrap_or_else(|_| scalar_admission(inp))
                }
                None => scalar_admission(inp),
            });
            router.handle(outs);
        }
        // Post-drain publish, skipped on idle iterations (recv timeout
        // with no due timers) — those change no node state.
        if had_events {
            publish(&node, &status);
        }
    }
}

/// One connection attempt; None if the peer is down (retried on the
/// next send).
fn connect_peer(addr: &str, from: NodeId, delay: Duration) -> Option<DelayedSender> {
    let s = TcpStream::connect_timeout(&addr.parse().ok()?, Duration::from_millis(50)).ok()?;
    s.set_nodelay(true).ok();
    let ds = DelayedSender::new(s, delay);
    ds.send_vec(wire::encode(&Frame::HelloPeer { from }));
    Some(ds)
}
