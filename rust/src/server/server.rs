//! The server main loop: the same [`Node`] the simulator drives, behind
//! real threads, sockets, and clocks (the paper's LogCabin role, §7).
//!
//! Two rules structure every loop iteration:
//!
//! * **Persist before route.** Outputs produced by the node are buffered,
//!   the durable deltas (`current_term`, `voted_for`, log appends and
//!   truncations) are written to [`crate::storage::Storage`] and synced,
//!   and only then are the outputs externalized. A vote, an
//!   AppendEntries ack, or a replicated entry is never on the wire
//!   before it is on disk (Raft §5's persistence points).
//! * **Never block on a peer.** The router's send path only consults its
//!   map of live links; reconnection lives on the [`Dialer`] thread and
//!   established links come back through the event channel.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::clock::real::RealClock;
use crate::clock::Clock;
use crate::obs::registry::{STAGE_APPLY, STAGE_COMMIT, STAGE_PERSIST, STAGE_QUEUE, STAGE_REPLICATE, STAGE_REPLY};
use crate::obs::{EventKind, Registry};
use crate::raft::{Message, Node, NodeConfig, Output, Role, TimerKind};
use crate::runtime::{scalar_admission, EngineHandle};
use crate::shard::{group_seed, GroupId, ShardMap, ShardRouter};
use crate::storage::{FsyncPolicy, MultiStorage};
use crate::{Micros, NodeId};

use super::dialer::Dialer;
use super::transport::{bind_reuse, write_frame, DelayedSender, FrameReader};
use super::wire::{self, ClientResp, Enc, Frame};

/// Shared in-process apply log (real-mode linearizability input). All
/// servers of an in-process cluster push (key, value, monotonic µs).
pub type SharedApplies = Arc<Mutex<Vec<(u32, u64, Micros)>>>;

#[derive(Clone)]
pub struct ServerConfig {
    pub id: NodeId,
    /// Peer addresses, indexed by node id (own slot = listen address,
    /// or `"auto"` for an ephemeral port).
    pub peer_addrs: Vec<String>,
    pub params: crate::config::Params,
    /// Injected one-way delay on *peer* links (the paper's `tc` WAN
    /// emulation; client links are unaffected, §7.2).
    pub one_way_delay: Duration,
    /// Batched XLA read admission (None = scalar path).
    pub engine: Option<EngineHandle>,
    pub applies: Option<SharedApplies>,
    /// Durable-state directory (WAL + hard-state). None = volatile mode:
    /// the pre-durability behavior, still used by throughput benches.
    pub data_dir: Option<PathBuf>,
    /// When an append becomes durable relative to ack (ignored without
    /// `data_dir`).
    pub fsync: FsyncPolicy,
}

enum Ev {
    /// New inbound connection: the write half for replies.
    NewConn(u64, TcpStream),
    Peer(GroupId, Message),
    Client { conn: u64, req: wire::ClientReq, recv_us: Micros },
    /// Live-introspection request: snapshot the registry + recorder tails.
    Status { conn: u64, tail: u32 },
    ConnClosed(u64),
    /// The background dialer established an outgoing peer link.
    PeerUp(NodeId, DelayedSender),
    Shutdown,
}

pub struct ServerHandle {
    pub id: NodeId,
    pub addr: String,
    /// Externally visible lock-free metrics: per-group gauges, lease
    /// accounting, stage latency, WAL counters (replaces the old flat
    /// `Status` struct whose scalar fields silently meant group 0 only).
    pub status: Arc<Registry>,
    tx: Sender<Ev>,
    main: Option<JoinHandle<()>>,
    accept: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
}

impl ServerHandle {
    /// Stop the server (models a crash: connections drop, no flush).
    pub fn kill(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Ev::Shutdown);
        let _ = TcpStream::connect(&self.addr); // unblock acceptor
        if let Some(h) = self.main.take() {
            let _ = h.join();
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

pub struct Server;

impl Server {
    /// Bind and spawn. The actual bound address is in the handle.
    pub fn spawn(mut cfg: ServerConfig) -> std::io::Result<ServerHandle> {
        let bind_to = if cfg.peer_addrs[cfg.id] == "auto" {
            "127.0.0.1:0".to_string()
        } else {
            cfg.peer_addrs[cfg.id].clone()
        };
        // SO_REUSEADDR: a respawn on a fixed port must not trip over the
        // killed predecessor's TIME_WAIT sockets.
        let listener = bind_reuse(&bind_to)?;
        let addr = listener.local_addr()?.to_string();
        cfg.peer_addrs[cfg.id] = addr.clone();
        let (tx, rx) = channel::<Ev>();
        let stop = Arc::new(AtomicBool::new(false));
        let status = Arc::new(Registry::new(cfg.params.groups));
        let id = cfg.id;

        let accept = {
            let tx = tx.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut next_conn: u64 = 1;
                for stream in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    stream.set_nodelay(true).ok();
                    let conn = next_conn;
                    next_conn += 1;
                    if let Ok(w) = stream.try_clone() {
                        if tx.send(Ev::NewConn(conn, w)).is_err() {
                            break;
                        }
                    }
                    let tx = tx.clone();
                    std::thread::spawn(move || reader_loop(stream, conn, tx));
                }
            })
        };

        let main = {
            let status = status.clone();
            let stop = stop.clone();
            let tx = tx.clone(); // for the dialer's PeerUp deliveries
            std::thread::spawn(move || main_loop(cfg, tx, rx, status, stop))
        };

        Ok(ServerHandle { id, addr, status, tx, main: Some(main), accept: Some(accept), stop })
    }
}

/// Decode frames off one inbound connection into the event channel.
/// Buffered reads + a reusable body scratch: zero per-frame allocation
/// on a warm connection (decode still owns its output).
fn reader_loop(stream: TcpStream, conn: u64, tx: Sender<Ev>) {
    let mut frames = FrameReader::new(stream);
    loop {
        match frames.next_frame() {
            Ok(Some(body)) => match wire::decode(body) {
                Ok(Frame::Raft { group, msg, .. }) => {
                    if tx.send(Ev::Peer(group, msg)).is_err() {
                        break;
                    }
                }
                Ok(Frame::HelloPeer { .. }) => {}
                Ok(Frame::ClientReq(req)) => {
                    // Stamp arrival here, not in the main loop: the gap
                    // between the two is the queue stage.
                    let recv_us = RealClock::monotonic_us();
                    if tx.send(Ev::Client { conn, req, recv_us }).is_err() {
                        break;
                    }
                }
                Ok(Frame::StatusReq { tail }) => {
                    if tx.send(Ev::Status { conn, tail }).is_err() {
                        break;
                    }
                }
                Ok(Frame::ClientResp(_)) | Ok(Frame::StatusResp(_)) | Err(_) => break, // protocol error
            },
            _ => break,
        }
    }
    let _ = tx.send(Ev::ConnClosed(conn));
}

/// Mutable state the output router needs (bundled to keep borrows sane).
///
/// One Router serves every group of the process: all groups share the
/// peer map (G groups × one link per peer, not G sockets), and timers
/// are keyed by group so each fires back into its own node.
struct Router {
    cfg: ServerConfig,
    timers: BinaryHeap<std::cmp::Reverse<(Micros, GroupId, u8)>>,
    peers: HashMap<NodeId, DelayedSender>,
    /// Owns reconnection for everything missing from `peers`.
    dialer: Dialer,
    op_conn: HashMap<u64, u64>,
    conns: HashMap<u64, TcpStream>,
    /// Reusable frame-encode scratch for every outgoing frame.
    enc: Enc,
    /// Per-stage latency sink (shared with external observers).
    registry: Arc<Registry>,
    /// Canonical key → group map (for group-attributing applies).
    map: ShardMap,
    /// In-flight op milestones, keyed by the wire op id; removed when
    /// the reply goes out (or the owning connection closes).
    traces: HashMap<u64, OpTrace>,
}

/// Milestone timestamps of one in-flight client op, for the per-stage
/// latency breakdown. Stage boundaries (all `RealClock::monotonic_us`):
///
/// * queue     = reader-thread receive → main-loop dequeue
/// * persist   = dequeue → the accepting iteration's WAL barrier
/// * replicate = barrier → local commit index covers the entry
///   (the quorum round trip; the node commits *and* applies inside
///   that observation window)
/// * commit    = commit observed → reply frame emission (the cost of
///   externalizing the commit: output routing + response encode)
/// * apply     = publication of one applied op to the shared apply log
/// * reply     = reply emission → socket write complete
///
/// Reads that never hit the log leave `persisted_us`/`committed_us`
/// at 0 and contribute only queue + reply samples.
struct OpTrace {
    group: GroupId,
    dequeue_us: Micros,
    persisted_us: Micros,
    committed_us: Micros,
}

fn kind_of(k: TimerKind) -> u8 {
    match k {
        TimerKind::Election => 0,
        TimerKind::Heartbeat => 1,
        TimerKind::LeaseCheck => 2,
    }
}

fn kind_from(b: u8) -> TimerKind {
    match b {
        0 => TimerKind::Election,
        1 => TimerKind::Heartbeat,
        _ => TimerKind::LeaseCheck,
    }
}

impl Router {
    /// Route a batch of group-tagged outputs, draining `outs` (the
    /// caller reuses the buffer). Callers must have persisted durable
    /// state first — this is the externalization point.
    fn handle(&mut self, outs: &mut Vec<(GroupId, Output)>) {
        // A replication fan-out arrives as Sends whose payloads repeat
        // (shared EntryBatch + one round seq): encode once, hand every
        // DelayedSender the same Arc'd bytes. Keyed by (group, message)
        // — per-group fan-outs are contiguous within a batch, so two
        // slots still absorb one lagging peer's catch-up frame without
        // evicting the aligned majority's frame.
        let mut encoded: Vec<(GroupId, Message, Arc<[u8]>)> = Vec::with_capacity(2);
        for (g, o) in outs.drain(..) {
            match o {
                Output::Send { to, msg } => {
                    // No link: drop the frame. The dialer is already
                    // redialing (down links are reported the moment a
                    // send fails) and Raft's timers re-send; blocking
                    // the main loop on a connect would stall every
                    // other peer and client.
                    let Some(sender) = self.peers.get(&to) else { continue };
                    // Cheap compare: shared-batch views hit the
                    // pointer-equality fast path.
                    let body: Arc<[u8]> =
                        match encoded.iter().find(|(eg, m, _)| *eg == g && *m == msg) {
                            Some((_, _, b)) => b.clone(),
                            None => {
                                self.enc.reset();
                                wire::encode_raft_into(self.cfg.id, g, &msg, &mut self.enc);
                                let b: Arc<[u8]> = Arc::from(&self.enc.buf[..]);
                                if encoded.len() == 2 {
                                    encoded.remove(0);
                                }
                                encoded.push((g, msg, b.clone()));
                                b
                            }
                        };
                    if !sender.send(body) {
                        self.peers.remove(&to);
                        self.dialer.notify_down(to);
                    }
                }
                Output::SetTimer { kind, after } => {
                    self.timers.push(std::cmp::Reverse((
                        RealClock::monotonic_us() + after,
                        g,
                        kind_of(kind),
                    )));
                }
                Output::Reply { op, result } => {
                    let trace = self.traces.remove(&op);
                    if let Some(conn) = self.op_conn.remove(&op) {
                        if let Some(stream) = self.conns.get_mut(&conn) {
                            let emit_us = RealClock::monotonic_us();
                            let resp = Frame::ClientResp(ClientResp { op, exec_us: emit_us, result });
                            self.enc.reset();
                            wire::encode_into(&resp, &mut self.enc);
                            if write_frame(stream, &self.enc.buf).is_err() {
                                self.conns.remove(&conn);
                            }
                            if let Some(t) = trace {
                                let m = self.registry.group(t.group);
                                if t.committed_us > 0 {
                                    m.stages[STAGE_COMMIT].record(emit_us - t.committed_us);
                                }
                                m.stages[STAGE_REPLY].record(RealClock::monotonic_us() - emit_us);
                            }
                        }
                    }
                }
                Output::Applied { key, value } => {
                    if let Some(a) = &self.cfg.applies {
                        let t0 = RealClock::monotonic_us();
                        a.lock().unwrap().push((key, value, t0));
                        // Apply-log publication cost; group attribution
                        // via the canonical key → group map.
                        let g = self.map.group_of(key);
                        self.registry.group(g).stages[STAGE_APPLY].record(RealClock::monotonic_us() - t0);
                    }
                }
                Output::ElectedLeader { .. } | Output::SteppedDown => {}
            }
        }
    }
}

/// Flush every group's durable deltas to its storage namespace, then
/// hit ONE cross-group durability barrier. Must run after node
/// interactions and before their outputs are routed. Without a data dir
/// the watermarks are drained and dropped (volatile mode). Storage
/// errors are fatal: continuing to vote or ack on a broken disk
/// silently voids every crash-safety guarantee.
fn persist_all(shards: &mut ShardRouter, storage: &mut Option<MultiStorage>, status: &Registry) {
    let Some(ms) = storage.as_mut() else {
        for (_, node) in shards.iter_mut() {
            node.take_log_dirty();
            node.take_pending_snap();
        }
        return;
    };
    let mut dirty: Vec<GroupId> = Vec::new();
    for (g, node) in shards.iter_mut() {
        let s = ms.group(g as usize);
        s.persist_hard_state(node.term(), node.voted_for()).expect("hard-state persist");
        if let Some(snap) = node.take_pending_snap() {
            // Snapshot taken or installed this batch: the atomic file
            // write + WAL segment rotation subsumes the dirty suffix
            // (the fresh segment is seeded with the node's entire
            // in-memory tail), so the watermark is drained and dropped.
            // install_snapshot syncs internally — rotation is rare
            // enough that it pays its own barrier.
            s.install_snapshot(&snap, node.log()).expect("snapshot persist");
            node.take_log_dirty();
            continue;
        }
        if let Some((from, truncated)) = node.take_log_dirty() {
            if truncated {
                s.truncate(from - 1).expect("wal truncate");
            }
            let last = node.log().last_index();
            for (idx, e) in node.log().iter_range(from - 1, last) {
                s.append(idx, e).expect("wal append");
            }
            dirty.push(g);
        }
    }
    let syncs_before = ms.syncs();
    ms.barrier().expect("wal barrier");
    if !dirty.is_empty() {
        let syncs = ms.syncs() - syncs_before;
        status.wal_barriers.inc();
        status.wal_syncs.add(syncs);
        // Flight-record the barrier into every group it flushed.
        let at = RealClock::monotonic_us();
        for &g in &dirty {
            let node = shards.node_mut(g);
            let term = node.term();
            node.recorder_mut().record(at, term, EventKind::WalBarrier, dirty.len() as u64, syncs);
        }
    }
}

fn main_loop(
    cfg: ServerConfig,
    tx: Sender<Ev>,
    rx: Receiver<Ev>,
    status: Arc<Registry>,
    stop: Arc<AtomicBool>,
) {
    let mut clock = RealClock::new(cfg.params.clock_error_us);
    let now = clock.interval_now();
    let groups = cfg.params.groups;
    // One Node per group, each seeded independently (group 0 keeps the
    // process seed so single-group deployments replay unchanged).
    // Durable mode recovers every group from its own `g<id>/` namespace
    // under one MultiStorage.
    let mut pending: Vec<(GroupId, Output)> = Vec::new();
    let (mut storage, nodes) = match &cfg.data_dir {
        Some(dir) => {
            let (ms, durable) =
                MultiStorage::open(dir, groups, cfg.fsync).expect("open storage");
            let mut nodes = Vec::with_capacity(groups);
            for (g, d) in durable.into_iter().enumerate() {
                let node_cfg = NodeConfig::from_params(cfg.id, &cfg.params).for_group(g as GroupId);
                let (n, o) =
                    Node::recover(node_cfg, group_seed(cfg.params.seed, g as GroupId), d, now);
                pending.extend(o.into_iter().map(|out| (g as GroupId, out)));
                nodes.push(n);
            }
            (Some(ms), nodes)
        }
        None => {
            let mut nodes = Vec::with_capacity(groups);
            for g in 0..groups {
                let node_cfg = NodeConfig::from_params(cfg.id, &cfg.params).for_group(g as GroupId);
                let (n, o) = Node::new(node_cfg, group_seed(cfg.params.seed, g as GroupId), now);
                pending.extend(o.into_iter().map(|out| (g as GroupId, out)));
                nodes.push(n);
            }
            (None, nodes)
        }
    };
    let mut shards = ShardRouter::new(ShardMap::new(groups), nodes);
    let engine = cfg.engine.clone();
    let dialer = {
        let tx = tx.clone();
        Dialer::spawn(
            cfg.id,
            cfg.peer_addrs.clone(),
            cfg.one_way_delay,
            cfg.params.seed ^ (cfg.id as u64) << 32,
            move |peer, sender| tx.send(Ev::PeerUp(peer, sender)).is_ok(),
        )
    };
    // Every peer link starts down; the dialer brings them up.
    for p in 0..cfg.peer_addrs.len() {
        if p != cfg.id {
            dialer.notify_down(p);
        }
    }
    let mut router = Router {
        map: shards.map().clone(),
        cfg,
        timers: BinaryHeap::new(),
        peers: HashMap::new(),
        dialer,
        op_conn: HashMap::new(),
        conns: HashMap::new(),
        enc: Enc::new(),
        registry: status.clone(),
        traces: HashMap::new(),
    };
    persist_all(&mut shards, &mut storage, &status);
    router.handle(&mut pending);

    // Mirror every group's node state + protocol stats into the
    // registry. The node stays the single source of truth; the registry
    // is the lock-free cross-thread view of it.
    let publish = |shards: &ShardRouter, status: &Registry| {
        for (g, n) in shards.iter() {
            let m = status.group(g);
            m.is_leader.store(n.role() == Role::Leader, Ordering::Relaxed);
            m.term.set(n.term() as i64);
            m.commit_index.set(n.commit_index() as i64);
            m.limbo_len.set(n.lease_state().map(|l| l.limbo_len()).unwrap_or(0) as i64);
            let st = &n.stats;
            // `reads_served_local` historically includes inherited-lease
            // reads; the registry splits them out.
            m.reads_lease_local.set(st.reads_served_local - st.reads_served_inherited);
            m.reads_lease_inherited.set(st.reads_served_inherited);
            m.reads_quorum.set(st.reads_served_quorum);
            m.reads_deferred.set(st.reads_deferred);
            m.reads_rejected_no_lease.set(st.reads_rejected_no_lease);
            m.reads_rejected_limbo.set(st.reads_rejected_limbo);
            m.writes_accepted.set(st.writes_accepted);
            m.writes_blocked_transfer.set(st.commit_gate_blocks);
            m.writes_rejected_gate.set(st.writes_rejected_gate);
            m.elections_won.set(st.elections_won);
            m.snapshots_taken.set(st.snapshots_taken);
            m.snapshots_installed.set(st.snapshots_installed);
            m.snapshots_rejected.set(st.snapshots_rejected);
            m.last_snapshot_index.set(n.log().base() as i64);
        }
    };

    // Per-group read batches, reused across iterations.
    let mut read_batches: Vec<Vec<(u64, u32)>> = vec![Vec::new(); groups];
    // Accepted writes awaiting local commit coverage: (entry index, op),
    // per group, index-ordered (appends are monotone).
    let mut pending_commit: Vec<VecDeque<(u64, u64)>> = vec![VecDeque::new(); groups];
    // Writes accepted this iteration (for the persist-stage stamp).
    let mut accepted_ops: Vec<u64> = Vec::new();
    // Introspection requests, answered after this batch publishes.
    let mut status_reqs: Vec<(u64, u32)> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        // Fire due timers. Status publication is folded into the
        // timer-fire branch: an idle loop iteration performs no atomic
        // stores (the post-drain publish below covers event-driven
        // changes).
        let now_us = RealClock::monotonic_us();
        let mut timer_fired = false;
        while let Some(&std::cmp::Reverse((due, g, kb))) = router.timers.peek() {
            if due > now_us {
                break;
            }
            router.timers.pop();
            timer_fired = true;
            let now = clock.interval_now();
            let outs = shards.node_mut(g).on_timer(now, kind_from(kb));
            pending.extend(outs.into_iter().map(|o| (g, o)));
        }
        if timer_fired {
            // A timer can start an election (term bump + self-vote) —
            // durable before the RequestVotes leave. A lease-check timer
            // can reopen the commit gate, so commits may advance here.
            persist_all(&mut shards, &mut storage, &status);
            stamp_commits(&shards, &mut pending_commit, &mut router.traces, &status);
            router.handle(&mut pending);
            publish(&shards, &status);
        }
        // Wait for events until the next timer (bounded poll).
        let wait_us = router
            .timers
            .peek()
            .map(|&std::cmp::Reverse((due, _, _))| (due - RealClock::monotonic_us()).max(0) as u64)
            .unwrap_or(2_000)
            .min(2_000);
        let first = match rx.recv_timeout(Duration::from_micros(wait_us)) {
            Ok(ev) => Some(ev),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
            Err(_) => break,
        };
        let mut events: Vec<Ev> = Vec::new();
        if let Some(f) = first {
            events.push(f);
        }
        while let Ok(ev) = rx.try_recv() {
            events.push(ev);
            if events.len() >= 1024 {
                break;
            }
        }
        let had_events = !events.is_empty();
        for b in &mut read_batches {
            b.clear();
        }
        for ev in events {
            match ev {
                Ev::Shutdown => return,
                Ev::NewConn(conn, stream) => {
                    router.conns.insert(conn, stream);
                }
                Ev::PeerUp(peer, sender) => {
                    router.peers.insert(peer, sender);
                }
                Ev::Peer(g, msg) => {
                    // A frame for a group this process doesn't host
                    // (mismatched configs) is dropped, not a panic.
                    if (g as usize) < shards.len() {
                        let now = clock.interval_now();
                        let outs = shards.node_mut(g).on_message(now, msg);
                        pending.extend(outs.into_iter().map(|o| (g, o)));
                    }
                }
                Ev::Client { conn, req, recv_us } => {
                    router.op_conn.insert(req.op, conn);
                    // The server routes by key through the canonical
                    // ShardMap — clients need not be trusted to route.
                    let g = shards.group_for_key(req.key);
                    let dequeue_us = RealClock::monotonic_us();
                    status.group(g).stages[STAGE_QUEUE].record(dequeue_us - recv_us);
                    router.traces.insert(
                        req.op,
                        OpTrace { group: g, dequeue_us, persisted_us: 0, committed_us: 0 },
                    );
                    match req.write_value {
                        Some(v) => {
                            let now = clock.interval_now();
                            let before = shards.node(g).log().last_index();
                            let outs = shards.node_mut(g).client_write(
                                now,
                                req.op,
                                req.key,
                                v,
                                req.payload.len() as u32,
                            );
                            if shards.node(g).log().last_index() > before {
                                // Accepted: watch for local commit
                                // coverage (the replicate stage's end).
                                pending_commit[g as usize]
                                    .push_back((shards.node(g).log().last_index(), req.op));
                                accepted_ops.push(req.op);
                            }
                            pending.extend(outs.into_iter().map(|o| (g, o)));
                        }
                        None => read_batches[g as usize].push((req.op, req.key)),
                    }
                }
                Ev::Status { conn, tail } => status_reqs.push((conn, tail)),
                Ev::ConnClosed(conn) => {
                    router.conns.remove(&conn);
                    // Purge op→conn routes owned by the closed conn:
                    // their replies have nowhere to go, and without this
                    // the map grows without bound under client churn.
                    // Their latency traces go with them.
                    let traces = &mut router.traces;
                    router.op_conn.retain(|op, c| {
                        if *c == conn {
                            traces.remove(op);
                            false
                        } else {
                            true
                        }
                    });
                }
            }
        }
        // Reads batched per loop iteration and per group: one admission
        // decision per group for everything that arrived together (the
        // XLA engine's raison d'être during post-election thundering
        // herds).
        for (gi, batch) in read_batches.iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            status.reads_batched.add(batch.len() as u64);
            let now = clock.interval_now();
            let g = gi as GroupId;
            let outs = shards.node_mut(g).client_read_batch(now, batch, |inp| match &engine {
                Some(e) => {
                    status.engine_batches.inc();
                    e.admit(inp).unwrap_or_else(|_| scalar_admission(inp))
                }
                None => scalar_admission(inp),
            });
            pending.extend(outs.into_iter().map(|o| (g, o)));
        }
        // One durability barrier for everything the batch changed across
        // ALL groups (the `Group` fsync policy's whole point, now shared
        // cross-group), then externalize. Skipped on idle iterations
        // (recv timeout with no due timers) — those change no node state.
        if had_events {
            persist_all(&mut shards, &mut storage, &status);
            if !accepted_ops.is_empty() {
                // This batch's accepted writes are now locally durable.
                let now_us = RealClock::monotonic_us();
                for op in accepted_ops.drain(..) {
                    if let Some(t) = router.traces.get_mut(&op) {
                        t.persisted_us = now_us;
                        status.group(t.group).stages[STAGE_PERSIST].record(now_us - t.dequeue_us);
                    }
                }
            }
            stamp_commits(&shards, &mut pending_commit, &mut router.traces, &status);
            router.handle(&mut pending);
            publish(&shards, &status);
        }
        // Serve introspection snapshots last, after this batch's
        // publish, splicing each group's flight-recorder tail in.
        for (conn, tail) in status_reqs.drain(..) {
            let mut snap = status.snapshot();
            for gs in snap.groups.iter_mut() {
                gs.events = shards.node(gs.group).recorder().tail((tail as usize).min(4096));
            }
            if let Some(stream) = router.conns.get_mut(&conn) {
                router.enc.reset();
                wire::encode_into(&Frame::StatusResp(Box::new(snap)), &mut router.enc);
                // lint:allow(R5): status snapshots are read-only introspection, not protocol output — nothing to persist first
                if write_frame(stream, &router.enc.buf).is_err() {
                    router.conns.remove(&conn);
                }
            }
        }
    }
}

/// Stamp `committed_us` on every pending write whose entry the local
/// commit index now covers, recording the replicate-stage latency
/// (barrier → quorum commit). Runs after `persist_all` and before the
/// outputs are routed, so a write's commit stamp exists by the time its
/// reply is emitted.
fn stamp_commits(
    shards: &ShardRouter,
    pending_commit: &mut [VecDeque<(u64, u64)>],
    traces: &mut HashMap<u64, OpTrace>,
    status: &Registry,
) {
    for (gi, q) in pending_commit.iter_mut().enumerate() {
        if q.is_empty() {
            continue;
        }
        let g = gi as GroupId;
        let ci = shards.node(g).commit_index();
        let now_us = RealClock::monotonic_us();
        while let Some(&(idx, op)) = q.front() {
            if idx > ci {
                break;
            }
            q.pop_front();
            if let Some(t) = traces.get_mut(&op) {
                if t.persisted_us > 0 {
                    t.committed_us = now_us;
                    status.group(g).stages[STAGE_REPLICATE].record(now_us - t.persisted_us);
                }
            }
        }
    }
}
