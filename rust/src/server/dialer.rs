//! Background peer dialer: supervised reconnection with jittered
//! exponential backoff.
//!
//! The router's send path used to call `TcpStream::connect_timeout(50ms)`
//! inline whenever a peer link was missing — with several peers down,
//! every replication fan-out stalled the main loop for up to 50 ms *per
//! down peer*. Now the router only ever consults its map: a missing peer
//! means the frame is dropped (Raft's retry machinery re-sends) and this
//! dialer owns reconnection on its own thread, handing each established
//! link back through the event channel.
//!
//! Protocol between router and dialer:
//! * router sees a send fail (or has no link at boot) → removes the
//!   sender and calls [`Dialer::notify_down`];
//! * dialer retries with exponential backoff (base 5 ms, ×2, capped at
//!   200 ms, plus up-to-one-backoff of seeded jitter so simultaneously
//!   restarted servers don't thundering-herd each other);
//! * on success it sends the peer hello and delivers the connected
//!   [`DelayedSender`] via the `deliver` callback (an `Ev::PeerUp`).
//!
//! Invariant: a peer is either in the router's map or pending in the
//! dialer — never neither — so every down link is eventually redialed.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use crate::prob::Rng;
use crate::NodeId;

use super::transport::DelayedSender;
use super::wire::{self, Frame};

const CONNECT_TIMEOUT: Duration = Duration::from_millis(50);
const BACKOFF_BASE: Duration = Duration::from_millis(5);
const BACKOFF_CAP: Duration = Duration::from_millis(200);

pub struct Dialer {
    tx: Sender<NodeId>,
}

struct Attempt {
    next: Instant,
    backoff: Duration,
}

impl Dialer {
    /// Spawn the dial thread. `deliver` hands a freshly connected link
    /// back to the owner (returning false stops the thread — the owner
    /// is gone). The thread also exits when the `Dialer` handle drops.
    pub fn spawn<F>(
        from: NodeId,
        peer_addrs: Vec<String>,
        delay: Duration,
        seed: u64,
        mut deliver: F,
    ) -> Dialer
    where
        F: FnMut(NodeId, DelayedSender) -> bool + Send + 'static,
    {
        let (tx, rx) = channel::<NodeId>();
        std::thread::spawn(move || {
            let mut rng = Rng::new(seed ^ 0xD1A1_E27_u64);
            let mut pending: HashMap<NodeId, Attempt> = HashMap::new();
            loop {
                // Park until the earliest pending attempt is due or a new
                // down-notification arrives (effectively forever if idle).
                let wait = pending
                    .values()
                    .map(|a| a.next)
                    .min()
                    .map(|t| t.saturating_duration_since(Instant::now()))
                    .unwrap_or(Duration::from_secs(3600));
                match rx.recv_timeout(wait) {
                    Ok(peer) => {
                        pending
                            .entry(peer)
                            .or_insert(Attempt { next: Instant::now(), backoff: BACKOFF_BASE });
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => return,
                }
                while let Ok(peer) = rx.try_recv() {
                    pending
                        .entry(peer)
                        .or_insert(Attempt { next: Instant::now(), backoff: BACKOFF_BASE });
                }
                let now = Instant::now();
                let due: Vec<NodeId> =
                    pending.iter().filter(|(_, a)| a.next <= now).map(|(&p, _)| p).collect();
                for peer in due {
                    match try_connect(&peer_addrs[peer], from, delay) {
                        Some(sender) => {
                            if !deliver(peer, sender) {
                                return;
                            }
                            pending.remove(&peer);
                        }
                        None => {
                            let a = pending.get_mut(&peer).expect("due peer is pending");
                            let jitter =
                                Duration::from_micros(rng.below(a.backoff.as_micros() as u64 + 1));
                            a.next = Instant::now() + a.backoff + jitter;
                            a.backoff = (a.backoff * 2).min(BACKOFF_CAP);
                        }
                    }
                }
            }
        });
        Dialer { tx }
    }

    /// Tell the dialer a peer link is down (idempotent while pending).
    pub fn notify_down(&self, peer: NodeId) {
        let _ = self.tx.send(peer);
    }
}

/// One connection attempt; None if the peer is down.
fn try_connect(addr: &str, from: NodeId, delay: Duration) -> Option<DelayedSender> {
    let s = TcpStream::connect_timeout(&addr.parse().ok()?, CONNECT_TIMEOUT).ok()?;
    s.set_nodelay(true).ok();
    let ds = DelayedSender::new(s, delay);
    ds.send_vec(wire::encode(&Frame::HelloPeer { from }));
    Some(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;
    use std::sync::mpsc::channel as ev_channel;

    #[test]
    fn dials_peer_that_comes_up_late() {
        // Reserve a port, then close the listener: the first attempts
        // must fail and back off without delivering anything.
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        drop(l);
        let (tx, rx) = ev_channel();
        let dialer = Dialer::spawn(
            0,
            vec!["unused".into(), addr.clone()],
            Duration::ZERO,
            7,
            move |peer, sender| tx.send((peer, sender)).is_ok(),
        );
        dialer.notify_down(1);
        assert!(
            rx.recv_timeout(Duration::from_millis(80)).is_err(),
            "nothing to deliver while the peer is down"
        );
        // Bring the peer up; the dialer should connect within a few
        // backoff periods and deliver a working sender.
        let l = TcpListener::bind(&addr).unwrap();
        let (peer, sender) = rx.recv_timeout(Duration::from_secs(5)).expect("peer up");
        assert_eq!(peer, 1);
        let (mut conn, _) = l.accept().unwrap();
        // First frame on the link is the hello.
        let hello = crate::server::transport::read_frame(&mut conn).unwrap().unwrap();
        assert_eq!(wire::decode(&hello).unwrap(), Frame::HelloPeer { from: 0 });
        assert!(sender.send_vec(b"after-hello".to_vec()));
        assert_eq!(
            crate::server::transport::read_frame(&mut conn).unwrap().unwrap(),
            b"after-hello"
        );
    }

    #[test]
    fn thread_exits_when_deliver_refuses() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        let dialer = Dialer::spawn(0, vec![addr], Duration::ZERO, 1, |_, _| false);
        dialer.notify_down(0);
        // Nothing to assert beyond "does not hang/leak": the deliver
        // refusal ends the thread; dropping the handle is a no-op then.
        std::thread::sleep(Duration::from_millis(50));
        drop(dialer);
    }
}
