//! Hand-rolled binary wire format (no serde offline): length-prefixed
//! frames, little-endian integers, u8 tags. Covers peer RPCs
//! ([`crate::raft::Message`]) and the client protocol.
//!
//! Frame = u32 length || payload. Payload starts with a two-byte
//! header (magic + protocol version, so the format can evolve and
//! mismatched peers are rejected gracefully instead of misparsed),
//! then a u8 frame kind. Raft frames carry the [`GroupId`] of the
//! Raft group they belong to: G groups sharing a peer link multiplex
//! over one socket and the group id demultiplexes on arrival.

use crate::clock::TimeInterval;
use crate::kv::Command;
use crate::obs::{EventKind, FlightEvent, GroupSnapshot, StageSummary, StatusSnapshot};
use crate::raft::log::Entry;
use crate::raft::types::{FailReason, OpResult};
use crate::raft::{EntryBatch, Message};
use crate::shard::GroupId;
use crate::NodeId;

/// First byte of every frame body. Deliberately not a printable ASCII
/// frame-kind value, so pre-header peers (or random TCP scanners) fail
/// the magic check on byte one.
pub const WIRE_MAGIC: u8 = 0xA7;
/// Wire protocol version. v1: header introduced + per-frame group ids.
pub const WIRE_VERSION: u8 = 1;

/// Top-level frame kinds.
pub const FRAME_HELLO_PEER: u8 = 1;
pub const FRAME_RAFT: u8 = 2;
pub const FRAME_CLIENT_REQ: u8 = 3;
pub const FRAME_CLIENT_RESP: u8 = 4;
pub const FRAME_STATUS_REQ: u8 = 5;
pub const FRAME_STATUS_RESP: u8 = 6;

/// Client request: a read or a write.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientReq {
    pub op: u64,
    pub key: u32,
    /// None = read; Some(value) = append.
    pub write_value: Option<u64>,
    /// Payload carried on the wire for writes (bandwidth realism).
    pub payload: Vec<u8>,
}

/// Client response. `exec_us` is the server's monotonic timestamp at
/// execution (same epoch as the in-process apply log), letting the
/// omniscient linearizability checker run on real-mode histories too.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientResp {
    pub op: u64,
    pub result: OpResult,
    pub exec_us: i64,
}

/// Anything decodable from a frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Peer identification sent once per outgoing peer link.
    HelloPeer { from: NodeId },
    Raft { from: NodeId, group: GroupId, msg: Message },
    ClientReq(ClientReq),
    ClientResp(ClientResp),
    /// Live-introspection request: snapshot the server's metrics
    /// registry plus the last `tail` flight-recorder events per group.
    StatusReq { tail: u32 },
    /// The snapshot (boxed: ~50x larger than any other variant).
    StatusResp(Box<StatusSnapshot>),
}

// ---------------------------------------------------------------- encode

/// Reusable encode buffer. Hot paths keep one per connection/router and
/// call [`Enc::reset`] + [`encode_into`] instead of allocating a fresh
/// `Vec` per frame.
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Self {
        Enc { buf: Vec::with_capacity(256) }
    }
    /// Clear for reuse; capacity is retained.
    pub fn reset(&mut self) {
        self.buf.clear();
    }
    pub(crate) fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub(crate) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    fn interval(&mut self, t: TimeInterval) {
        self.i64(t.earliest);
        self.i64(t.latest);
    }

    fn command(&mut self, c: &Command) {
        match c {
            Command::Noop => self.u8(0),
            Command::EndLease => self.u8(1),
            Command::Put { key, value, payload_bytes } => {
                self.u8(2);
                self.u32(*key);
                self.u64(*value);
                self.u32(*payload_bytes);
            }
        }
    }

    /// Shared with the storage WAL, so log entries have one canonical
    /// binary form on the wire and on disk.
    pub(crate) fn entry(&mut self, e: &Entry) {
        self.u64(e.term);
        self.command(&e.command);
        self.interval(e.written_at);
    }

    fn stage(&mut self, s: &StageSummary) {
        self.u64(s.count);
        self.u64(s.sum_us);
        self.i64(s.min_us);
        self.i64(s.p50_us);
        self.i64(s.p90_us);
        self.i64(s.p99_us);
        self.i64(s.max_us);
    }

    fn event(&mut self, ev: &FlightEvent) {
        self.i64(ev.at);
        self.u64(ev.term);
        self.u8(ev.kind as u8);
        self.u64(ev.a);
        self.u64(ev.b);
    }

    fn group_snapshot(&mut self, g: &GroupSnapshot) {
        self.u32(g.group);
        self.u8(g.is_leader as u8);
        self.u64(g.term);
        self.u64(g.commit_index);
        self.u64(g.limbo_len);
        self.u64(g.reads_lease_local);
        self.u64(g.reads_lease_inherited);
        self.u64(g.reads_quorum);
        self.u64(g.reads_deferred);
        self.u64(g.reads_rejected_no_lease);
        self.u64(g.reads_rejected_limbo);
        self.u64(g.writes_accepted);
        self.u64(g.writes_blocked_transfer);
        self.u64(g.writes_rejected_gate);
        self.u64(g.elections_won);
        self.u64(g.snapshots_taken);
        self.u64(g.snapshots_installed);
        self.u64(g.snapshots_rejected);
        self.u64(g.last_snapshot_index);
        for st in &g.stages {
            self.stage(st);
        }
        self.u32(g.events.len() as u32);
        for ev in &g.events {
            self.event(ev);
        }
    }

    fn result(&mut self, r: &OpResult) {
        match r {
            OpResult::WriteOk => self.u8(0),
            OpResult::ReadOk(values) => {
                self.u8(1);
                self.u32(values.len() as u32);
                for v in values {
                    self.u64(*v);
                }
            }
            OpResult::Failed(reason) => {
                self.u8(2);
                self.u8(match reason {
                    FailReason::NotLeader => 0,
                    FailReason::NoLease => 1,
                    FailReason::LimboConflict => 2,
                    FailReason::CommitGateClosed => 3,
                    FailReason::MaybeCommitted => 4,
                    FailReason::Timeout => 5,
                });
            }
        }
    }
}

impl Default for Enc {
    fn default() -> Self {
        Self::new()
    }
}

/// Encode a frame body (without the length prefix) into a fresh `Vec`.
/// Hot paths should prefer [`encode_into`] with a reused [`Enc`].
pub fn encode(frame: &Frame) -> Vec<u8> {
    let mut e = Enc::new();
    encode_into(frame, &mut e);
    e.buf
}

/// Encode a Raft peer frame without constructing a [`Frame`] (the
/// server's send path borrows the message instead of cloning it).
pub fn encode_raft_into(from: NodeId, group: GroupId, msg: &Message, e: &mut Enc) {
    e.u8(WIRE_MAGIC);
    e.u8(WIRE_VERSION);
    e.u8(FRAME_RAFT);
    e.u32(from as u32);
    e.u32(group);
    match msg {
        Message::RequestVote { term, candidate, last_log_index, last_log_term } => {
            e.u8(0);
            e.u64(*term);
            e.u32(*candidate as u32);
            e.u64(*last_log_index);
            e.u64(*last_log_term);
        }
        Message::VoteReply { term, voter, granted } => {
            e.u8(1);
            e.u64(*term);
            e.u32(*voter as u32);
            e.u8(*granted as u8);
        }
        Message::AppendEntries { term, leader, prev_index, prev_term, entries, leader_commit, seq } => {
            e.u8(2);
            e.u64(*term);
            e.u32(*leader as u32);
            e.u64(*prev_index);
            e.u64(*prev_term);
            e.u64(*leader_commit);
            e.u64(*seq);
            e.u32(entries.len() as u32);
            for en in entries.iter() {
                e.entry(en);
            }
        }
        Message::AppendReply { term, from: f, success, match_index, seq } => {
            e.u8(3);
            e.u64(*term);
            e.u32(*f as u32);
            e.u8(*success as u8);
            e.u64(*match_index);
            e.u64(*seq);
        }
        Message::SnapInstall { term, leader, last_index, last_term, offset, data, done, seq } => {
            e.u8(4);
            e.u64(*term);
            e.u32(*leader as u32);
            e.u64(*last_index);
            e.u64(*last_term);
            e.u64(*offset);
            e.u8(*done as u8);
            e.u64(*seq);
            e.bytes(data);
        }
        Message::SnapAck { term, from: f, last_index, offset, installed, seq } => {
            e.u8(5);
            e.u64(*term);
            e.u32(*f as u32);
            e.u64(*last_index);
            e.u64(*offset);
            e.u8(*installed as u8);
            e.u64(*seq);
        }
    }
}

/// Encode a frame body (without the length prefix) into a reused buffer.
/// Appends to `e.buf`; callers [`Enc::reset`] between frames.
pub fn encode_into(frame: &Frame, e: &mut Enc) {
    match frame {
        Frame::HelloPeer { from } => {
            e.u8(WIRE_MAGIC);
            e.u8(WIRE_VERSION);
            e.u8(FRAME_HELLO_PEER);
            e.u32(*from as u32);
        }
        Frame::Raft { from, group, msg } => {
            encode_raft_into(*from, *group, msg, e);
        }
        Frame::ClientReq(r) => {
            e.u8(WIRE_MAGIC);
            e.u8(WIRE_VERSION);
            e.u8(FRAME_CLIENT_REQ);
            e.u64(r.op);
            e.u32(r.key);
            match r.write_value {
                None => e.u8(0),
                Some(v) => {
                    e.u8(1);
                    e.u64(v);
                }
            }
            e.bytes(&r.payload);
        }
        Frame::ClientResp(r) => {
            e.u8(WIRE_MAGIC);
            e.u8(WIRE_VERSION);
            e.u8(FRAME_CLIENT_RESP);
            e.u64(r.op);
            e.i64(r.exec_us);
            e.result(&r.result);
        }
        Frame::StatusReq { tail } => {
            e.u8(WIRE_MAGIC);
            e.u8(WIRE_VERSION);
            e.u8(FRAME_STATUS_REQ);
            e.u32(*tail);
        }
        Frame::StatusResp(s) => {
            e.u8(WIRE_MAGIC);
            e.u8(WIRE_VERSION);
            e.u8(FRAME_STATUS_RESP);
            e.u32(s.groups.len() as u32);
            for g in &s.groups {
                e.group_snapshot(g);
            }
            e.u64(s.wal_barriers);
            e.u64(s.wal_syncs);
            e.u64(s.reads_batched);
            e.u64(s.engine_batches);
        }
    }
}

// ---------------------------------------------------------------- decode

pub struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DecodeError(pub String);

type R<T> = Result<T, DecodeError>;

impl<'a> Dec<'a> {
    pub fn new(b: &'a [u8]) -> Self {
        Dec { b, pos: 0 }
    }
    fn take(&mut self, n: usize) -> R<&'a [u8]> {
        // get() instead of slice-indexing: every byte here is
        // attacker-controlled, so even the bounds check must be an
        // error path, never a panic path (lint rule R4).
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| DecodeError(format!("length overflow at {} want {n}", self.pos)))?;
        let s = self
            .b
            .get(self.pos..end)
            .ok_or_else(|| DecodeError(format!("truncated at {} want {n}", self.pos)))?;
        self.pos = end;
        Ok(s)
    }
    /// Fixed-size read without panic paths: `try_into` only fails if
    /// `take` returned the wrong length, which it cannot, but the error
    /// is still an error — not an unwrap.
    fn arr<const N: usize>(&mut self) -> R<[u8; N]> {
        self.take(N)?
            .try_into()
            .map_err(|_| DecodeError(format!("bad fixed read of {N} at {}", self.pos)))
    }
    /// Bytes left in the frame body.
    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }
    /// True once the whole input has been consumed.
    pub(crate) fn done(&self) -> bool {
        self.pos == self.b.len()
    }
    /// Read an untrusted element count and validate it against the
    /// bytes actually left in the frame (each element occupies at
    /// least `min_bytes` on the wire), so a tiny corrupt frame cannot
    /// force a multi-GB `Vec::with_capacity` before per-element
    /// decoding hits Eof. `pub(crate)`: the snapshot payload decoder
    /// ([`crate::snap::decode`]) is held to the same standard and
    /// reuses this guard.
    pub(crate) fn count(&mut self, min_bytes: usize) -> R<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_bytes) > self.remaining() {
            return Err(DecodeError(format!(
                "count {n} x >={min_bytes}B exceeds remaining {} bytes",
                self.remaining()
            )));
        }
        Ok(n)
    }
    pub(crate) fn u8(&mut self) -> R<u8> {
        let [b] = self.arr::<1>()?;
        Ok(b)
    }
    pub(crate) fn u32(&mut self) -> R<u32> {
        Ok(u32::from_le_bytes(self.arr()?))
    }
    pub(crate) fn u64(&mut self) -> R<u64> {
        Ok(u64::from_le_bytes(self.arr()?))
    }
    pub(crate) fn i64(&mut self) -> R<i64> {
        Ok(i64::from_le_bytes(self.arr()?))
    }
    fn bytes(&mut self) -> R<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    fn interval(&mut self) -> R<TimeInterval> {
        let e = self.i64()?;
        let l = self.i64()?;
        Ok(TimeInterval::new(e, l))
    }

    fn command(&mut self) -> R<Command> {
        Ok(match self.u8()? {
            0 => Command::Noop,
            1 => Command::EndLease,
            2 => Command::Put { key: self.u32()?, value: self.u64()?, payload_bytes: self.u32()? },
            t => return Err(DecodeError(format!("bad command tag {t}"))),
        })
    }

    pub(crate) fn entry(&mut self) -> R<Entry> {
        Ok(Entry { term: self.u64()?, command: self.command()?, written_at: self.interval()? })
    }

    fn stage(&mut self) -> R<StageSummary> {
        Ok(StageSummary {
            count: self.u64()?,
            sum_us: self.u64()?,
            min_us: self.i64()?,
            p50_us: self.i64()?,
            p90_us: self.i64()?,
            p99_us: self.i64()?,
            max_us: self.i64()?,
        })
    }

    /// Decode one flight event, stamping the owning group. `None` for an
    /// unknown kind from a newer peer: the payload is consumed (so the
    /// stream stays aligned) but the event is dropped.
    fn event(&mut self, group: GroupId) -> R<Option<FlightEvent>> {
        let at = self.i64()?;
        let term = self.u64()?;
        let kind = EventKind::from_u8(self.u8()?);
        let a = self.u64()?;
        let b = self.u64()?;
        Ok(kind.map(|kind| FlightEvent { at, term, group, kind, a, b }))
    }

    fn group_snapshot(&mut self) -> R<GroupSnapshot> {
        let mut g = GroupSnapshot {
            group: self.u32()?,
            is_leader: self.u8()? != 0,
            term: self.u64()?,
            commit_index: self.u64()?,
            limbo_len: self.u64()?,
            reads_lease_local: self.u64()?,
            reads_lease_inherited: self.u64()?,
            reads_quorum: self.u64()?,
            reads_deferred: self.u64()?,
            reads_rejected_no_lease: self.u64()?,
            reads_rejected_limbo: self.u64()?,
            writes_accepted: self.u64()?,
            writes_blocked_transfer: self.u64()?,
            writes_rejected_gate: self.u64()?,
            elections_won: self.u64()?,
            snapshots_taken: self.u64()?,
            snapshots_installed: self.u64()?,
            snapshots_rejected: self.u64()?,
            last_snapshot_index: self.u64()?,
            ..GroupSnapshot::default()
        };
        for st in g.stages.iter_mut() {
            *st = self.stage()?;
        }
        // 33 = i64 at + u64 term + u8 kind + two u64 payloads.
        let n = self.count(33)?;
        g.events.reserve(n);
        for _ in 0..n {
            if let Some(ev) = self.event(g.group)? {
                g.events.push(ev);
            }
        }
        Ok(g)
    }

    fn result(&mut self) -> R<OpResult> {
        Ok(match self.u8()? {
            0 => OpResult::WriteOk,
            1 => {
                let n = self.count(8)?; // 8 bytes per u64 value
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(self.u64()?);
                }
                OpResult::ReadOk(v.into())
            }
            2 => OpResult::Failed(match self.u8()? {
                0 => FailReason::NotLeader,
                1 => FailReason::NoLease,
                2 => FailReason::LimboConflict,
                3 => FailReason::CommitGateClosed,
                4 => FailReason::MaybeCommitted,
                5 => FailReason::Timeout,
                t => return Err(DecodeError(format!("bad fail tag {t}"))),
            }),
            t => return Err(DecodeError(format!("bad result tag {t}"))),
        })
    }
}

/// Decode one frame body. The header is checked first: a bad magic
/// byte means a non-protocol peer (reject outright), a bad version a
/// peer from a different build (reject gracefully so the connection
/// handler can drop the link without panicking or misparsing).
pub fn decode(b: &[u8]) -> R<Frame> {
    let mut d = Dec::new(b);
    let magic = d.u8()?;
    if magic != WIRE_MAGIC {
        return Err(DecodeError(format!("bad magic {magic:#04x} (want {WIRE_MAGIC:#04x})")));
    }
    let version = d.u8()?;
    if version != WIRE_VERSION {
        return Err(DecodeError(format!(
            "unsupported wire version {version} (this build speaks {WIRE_VERSION})"
        )));
    }
    let frame = match d.u8()? {
        FRAME_HELLO_PEER => Frame::HelloPeer { from: d.u32()? as NodeId },
        FRAME_RAFT => {
            let from = d.u32()? as NodeId;
            let group = d.u32()?;
            let msg = match d.u8()? {
                0 => Message::RequestVote {
                    term: d.u64()?,
                    candidate: d.u32()? as NodeId,
                    last_log_index: d.u64()?,
                    last_log_term: d.u64()?,
                },
                1 => Message::VoteReply { term: d.u64()?, voter: d.u32()? as NodeId, granted: d.u8()? != 0 },
                2 => {
                    let term = d.u64()?;
                    let leader = d.u32()? as NodeId;
                    let prev_index = d.u64()?;
                    let prev_term = d.u64()?;
                    let leader_commit = d.u64()?;
                    let seq = d.u64()?;
                    // 25 = u64 term + 1-byte command tag + two i64 interval
                    // bounds: the smallest wire entry (a Noop).
                    let n = d.count(25)?;
                    let mut entries = Vec::with_capacity(n);
                    for _ in 0..n {
                        entries.push(d.entry()?);
                    }
                    Message::AppendEntries {
                        term,
                        leader,
                        prev_index,
                        prev_term,
                        entries: EntryBatch::from_vec(entries),
                        leader_commit,
                        seq,
                    }
                }
                3 => Message::AppendReply {
                    term: d.u64()?,
                    from: d.u32()? as NodeId,
                    success: d.u8()? != 0,
                    match_index: d.u64()?,
                    seq: d.u64()?,
                },
                4 => {
                    let term = d.u64()?;
                    let leader = d.u32()? as NodeId;
                    let last_index = d.u64()?;
                    let last_term = d.u64()?;
                    let offset = d.u64()?;
                    let done = d.u8()? != 0;
                    let seq = d.u64()?;
                    let data = d.bytes()?;
                    // Anti-DoS: a sender never produces chunks above
                    // SNAP_CHUNK_BYTES, and offset+chunk must stay under
                    // the whole-snapshot cap the receiver will buffer.
                    if data.len() > crate::snap::SNAP_CHUNK_BYTES {
                        return Err(DecodeError(format!(
                            "snapshot chunk of {} bytes exceeds cap",
                            data.len()
                        )));
                    }
                    if (offset as usize).saturating_add(data.len())
                        > crate::snap::MAX_SNAPSHOT_BYTES
                    {
                        return Err(DecodeError(format!(
                            "snapshot transfer past {offset} exceeds size cap"
                        )));
                    }
                    Message::SnapInstall { term, leader, last_index, last_term, offset, data, done, seq }
                }
                5 => Message::SnapAck {
                    term: d.u64()?,
                    from: d.u32()? as NodeId,
                    last_index: d.u64()?,
                    offset: d.u64()?,
                    installed: d.u8()? != 0,
                    seq: d.u64()?,
                },
                t => return Err(DecodeError(format!("bad raft tag {t}"))),
            };
            Frame::Raft { from, group, msg }
        }
        FRAME_CLIENT_REQ => {
            let op = d.u64()?;
            let key = d.u32()?;
            let write_value = match d.u8()? {
                0 => None,
                1 => Some(d.u64()?),
                t => return Err(DecodeError(format!("bad req tag {t}"))),
            };
            let payload = d.bytes()?;
            Frame::ClientReq(ClientReq { op, key, write_value, payload })
        }
        FRAME_CLIENT_RESP => {
            Frame::ClientResp(ClientResp { op: d.u64()?, exec_us: d.i64()?, result: d.result()? })
        }
        FRAME_STATUS_REQ => Frame::StatusReq { tail: d.u32()? },
        FRAME_STATUS_RESP => {
            // 481 = fixed group header: u32 group + u8 is_leader +
            // 17 u64 gauges/counters + 6 stage summaries of 7x8 bytes +
            // u32 event count.
            let n = d.count(481)?;
            let mut groups = Vec::with_capacity(n);
            for _ in 0..n {
                groups.push(d.group_snapshot()?);
            }
            Frame::StatusResp(Box::new(StatusSnapshot {
                groups,
                wal_barriers: d.u64()?,
                wal_syncs: d.u64()?,
                reads_batched: d.u64()?,
                engine_batches: d.u64()?,
            }))
        }
        t => return Err(DecodeError(format!("bad frame tag {t}"))),
    };
    if d.pos != b.len() {
        return Err(DecodeError(format!("{} trailing bytes", b.len() - d.pos)));
    }
    Ok(frame)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests only — production decode above stays panic-free (lint R4)
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let enc = encode(&f);
        let dec = decode(&enc).expect("decode");
        assert_eq!(dec, f);
    }

    #[test]
    fn roundtrip_all_raft_messages() {
        roundtrip(Frame::HelloPeer { from: 2 });
        roundtrip(Frame::Raft {
            from: 0,
            group: 0,
            msg: Message::RequestVote { term: 3, candidate: 0, last_log_index: 9, last_log_term: 2 },
        });
        roundtrip(Frame::Raft {
            from: 1,
            group: 7,
            msg: Message::VoteReply { term: 3, voter: 1, granted: true },
        });
        roundtrip(Frame::Raft {
            from: 0,
            group: 63,
            msg: Message::AppendEntries {
                term: 4,
                leader: 0,
                prev_index: 10,
                prev_term: 3,
                entries: vec![
                    Entry { term: 4, command: Command::Noop, written_at: TimeInterval::new(5, 9) },
                    Entry {
                        term: 4,
                        command: Command::Put { key: 7, value: 70, payload_bytes: 1024 },
                        written_at: TimeInterval::new(100, 180),
                    },
                    Entry { term: 4, command: Command::EndLease, written_at: TimeInterval::new(-5, 5) },
                ]
                .into(),
                leader_commit: 10,
                seq: 42,
            },
        });
        roundtrip(Frame::Raft {
            from: 2,
            group: 1,
            msg: Message::AppendReply { term: 4, from: 2, success: false, match_index: 0, seq: 42 },
        });
        roundtrip(Frame::Raft {
            from: 0,
            group: 9,
            msg: Message::SnapInstall {
                term: 6,
                leader: 0,
                last_index: 120,
                last_term: 5,
                offset: 16384,
                data: vec![0xEE; 700],
                done: true,
                seq: 51,
            },
        });
        roundtrip(Frame::Raft {
            from: 2,
            group: 9,
            msg: Message::SnapAck {
                term: 6,
                from: 2,
                last_index: 120,
                offset: 17084,
                installed: true,
                seq: 51,
            },
        });
        // Empty chunk (offset probe) is legal on the wire.
        roundtrip(Frame::Raft {
            from: 1,
            group: 0,
            msg: Message::SnapInstall {
                term: 1,
                leader: 1,
                last_index: 3,
                last_term: 1,
                offset: 0,
                data: vec![],
                done: false,
                seq: 2,
            },
        });
        // Empty entry batch (heartbeat frame).
        roundtrip(Frame::Raft {
            from: 1,
            group: 15,
            msg: Message::AppendEntries {
                term: 5,
                leader: 1,
                prev_index: 7,
                prev_term: 5,
                entries: crate::raft::EntryBatch::empty(),
                leader_commit: 7,
                seq: 43,
            },
        });
    }

    #[test]
    fn encode_into_reuse_matches_fresh_encode() {
        let frames = [
            Frame::HelloPeer { from: 1 },
            Frame::Raft {
                from: 0,
                group: 3,
                msg: Message::AppendEntries {
                    term: 2,
                    leader: 0,
                    prev_index: 1,
                    prev_term: 1,
                    entries: vec![Entry {
                        term: 2,
                        command: Command::Put { key: 3, value: 9, payload_bytes: 64 },
                        written_at: TimeInterval::new(1, 2),
                    }]
                    .into(),
                    leader_commit: 1,
                    seq: 5,
                },
            },
            Frame::ClientResp(ClientResp { op: 4, exec_us: 12, result: OpResult::WriteOk }),
        ];
        let mut e = Enc::new();
        for f in &frames {
            e.reset();
            encode_into(f, &mut e);
            assert_eq!(e.buf, encode(f), "reused-buffer encoding must be byte-identical");
        }
    }

    #[test]
    fn corrupt_entry_count_rejected_without_allocating() {
        // A tiny frame claiming u32::MAX AppendEntries entries must be
        // rejected by the count-vs-remaining-bytes check, not attempt a
        // ~200 GB Vec::with_capacity.
        let mut b = Vec::new();
        b.push(WIRE_MAGIC);
        b.push(WIRE_VERSION);
        b.push(FRAME_RAFT);
        b.extend_from_slice(&0u32.to_le_bytes()); // from
        b.extend_from_slice(&0u32.to_le_bytes()); // group
        b.push(2); // AppendEntries tag
        b.extend_from_slice(&1u64.to_le_bytes()); // term
        b.extend_from_slice(&0u32.to_le_bytes()); // leader
        b.extend_from_slice(&0u64.to_le_bytes()); // prev_index
        b.extend_from_slice(&0u64.to_le_bytes()); // prev_term
        b.extend_from_slice(&0u64.to_le_bytes()); // leader_commit
        b.extend_from_slice(&1u64.to_le_bytes()); // seq
        b.extend_from_slice(&u32::MAX.to_le_bytes()); // poison count
        let err = decode(&b).unwrap_err();
        assert!(err.0.contains("exceeds remaining"), "{err:?}");
        // Same guard on ReadOk value counts.
        let mut b = Vec::new();
        b.push(WIRE_MAGIC);
        b.push(WIRE_VERSION);
        b.push(FRAME_CLIENT_RESP);
        b.extend_from_slice(&7u64.to_le_bytes()); // op
        b.extend_from_slice(&0i64.to_le_bytes()); // exec_us
        b.push(1); // ReadOk tag
        b.extend_from_slice(&u32::MAX.to_le_bytes()); // poison count
        let err = decode(&b).unwrap_err();
        assert!(err.0.contains("exceeds remaining"), "{err:?}");
    }

    #[test]
    fn oversize_snapshot_chunk_rejected() {
        // A chunk above SNAP_CHUNK_BYTES is a protocol violation even if
        // the frame really carries the bytes: reject by name.
        let f = Frame::Raft {
            from: 0,
            group: 0,
            msg: Message::SnapInstall {
                term: 1,
                leader: 0,
                last_index: 5,
                last_term: 1,
                offset: 0,
                data: vec![0; crate::snap::SNAP_CHUNK_BYTES + 1],
                done: true,
                seq: 1,
            },
        };
        let err = decode(&encode(&f)).unwrap_err();
        assert!(err.0.contains("chunk"), "{err:?}");
        // An offset that would walk the reassembly buffer past the
        // whole-snapshot cap is rejected before any buffering.
        let f = Frame::Raft {
            from: 0,
            group: 0,
            msg: Message::SnapInstall {
                term: 1,
                leader: 0,
                last_index: 5,
                last_term: 1,
                offset: crate::snap::MAX_SNAPSHOT_BYTES as u64,
                data: vec![7],
                done: false,
                seq: 1,
            },
        };
        let err = decode(&encode(&f)).unwrap_err();
        assert!(err.0.contains("size cap"), "{err:?}");
    }

    #[test]
    fn every_truncated_prefix_rejected_without_panic() {
        // Every strict prefix of a valid frame must come back as a
        // decode ERROR — never a panic, never a silent partial parse.
        // (A prefix can't decode cleanly: field order is fixed and
        // counts are explicit, so truncation always lands mid-field.)
        let frames = [
            Frame::Raft {
                from: 0,
                group: 5,
                msg: Message::AppendEntries {
                    term: 4,
                    leader: 0,
                    prev_index: 10,
                    prev_term: 3,
                    entries: vec![
                        Entry { term: 4, command: Command::Noop, written_at: TimeInterval::new(5, 9) },
                        Entry {
                            term: 4,
                            command: Command::Put { key: 7, value: 70, payload_bytes: 64 },
                            written_at: TimeInterval::new(100, 180),
                        },
                    ]
                    .into(),
                    leader_commit: 10,
                    seq: 42,
                },
            },
            Frame::ClientReq(ClientReq { op: 10, key: 3, write_value: Some(33), payload: vec![0xCD; 100] }),
            Frame::ClientResp(ClientResp {
                op: 9,
                exec_us: 123,
                result: OpResult::ReadOk(vec![1, 2, 3].into()),
            }),
            Frame::StatusReq { tail: 16 },
            Frame::Raft {
                from: 0,
                group: 2,
                msg: Message::SnapInstall {
                    term: 3,
                    leader: 0,
                    last_index: 40,
                    last_term: 2,
                    offset: 0,
                    data: vec![0x5A; 96],
                    done: false,
                    seq: 9,
                },
            },
            Frame::Raft {
                from: 1,
                group: 2,
                msg: Message::SnapAck { term: 3, from: 1, last_index: 40, offset: 96, installed: false, seq: 9 },
            },
        ];
        for f in &frames {
            let enc = encode(f);
            for cut in 0..enc.len() {
                assert!(
                    decode(&enc[..cut]).is_err(),
                    "prefix of len {cut}/{} decoded cleanly for {f:?}",
                    enc.len()
                );
            }
        }
        // Seeded single-byte corruption sweep: a flipped byte may still
        // decode (it might just change a value) — the invariant is that
        // decode RETURNS on every input rather than panicking.
        let mut rng = crate::prob::Rng::new(0xC0FFEE);
        for f in &frames {
            let enc = encode(f);
            for _ in 0..200 {
                let mut b = enc.clone();
                let i = rng.below(b.len() as u64) as usize;
                b[i] ^= 1 << rng.below(8);
                let _ = decode(&b);
            }
        }
    }

    #[test]
    fn roundtrip_client_frames() {
        roundtrip(Frame::ClientReq(ClientReq { op: 9, key: 3, write_value: None, payload: vec![] }));
        roundtrip(Frame::ClientReq(ClientReq {
            op: 10,
            key: 3,
            write_value: Some(33),
            payload: vec![0xAB; 1024],
        }));
        roundtrip(Frame::ClientResp(ClientResp {
            op: 9,
            exec_us: 123,
            result: OpResult::ReadOk(vec![1, 2, 3].into()),
        }));
        roundtrip(Frame::ClientResp(ClientResp { op: 10, exec_us: -1, result: OpResult::WriteOk }));
        for r in [
            FailReason::NotLeader,
            FailReason::NoLease,
            FailReason::LimboConflict,
            FailReason::CommitGateClosed,
            FailReason::MaybeCommitted,
            FailReason::Timeout,
        ] {
            roundtrip(Frame::ClientResp(ClientResp { op: 1, exec_us: 0, result: OpResult::Failed(r) }));
        }
    }

    #[test]
    fn roundtrip_status_frames() {
        roundtrip(Frame::StatusReq { tail: 0 });
        roundtrip(Frame::StatusReq { tail: 128 });

        // Empty snapshot (server with zero groups never exists, but the
        // codec must not care).
        roundtrip(Frame::StatusResp(Box::new(StatusSnapshot::default())));

        let mut g0 = GroupSnapshot { group: 0, is_leader: true, term: 7, ..Default::default() };
        g0.commit_index = 142;
        g0.reads_lease_local = 900;
        g0.reads_lease_inherited = 33;
        g0.reads_rejected_limbo = 2;
        g0.writes_accepted = 120;
        g0.snapshots_taken = 4;
        g0.snapshots_installed = 1;
        g0.snapshots_rejected = 2;
        g0.last_snapshot_index = 130;
        g0.stages[1] =
            StageSummary { count: 5, sum_us: 900, min_us: 80, p50_us: 150, p90_us: 300, p99_us: 400, max_us: 410 };
        g0.events.push(FlightEvent {
            at: 1_000,
            term: 7,
            group: 0,
            kind: EventKind::LeaseInherited,
            a: 3,
            b: 140,
        });
        g0.events.push(FlightEvent {
            at: 1_050,
            term: 7,
            group: 0,
            kind: EventKind::ReadServedInherited,
            a: 42,
            b: 0,
        });
        let g1 = GroupSnapshot { group: 1, limbo_len: 4, elections_won: 2, ..Default::default() };
        roundtrip(Frame::StatusResp(Box::new(StatusSnapshot {
            groups: vec![g0, g1],
            wal_barriers: 17,
            wal_syncs: 51,
            reads_batched: 1200,
            engine_batches: 40,
        })));
    }

    #[test]
    fn status_resp_unknown_event_kind_dropped_not_misread() {
        // A newer peer may emit event kinds this build doesn't know.
        // The payload must be consumed (stream alignment) and the event
        // dropped, with the rest of the snapshot decoding intact.
        let mut g = GroupSnapshot { group: 3, ..Default::default() };
        g.events.push(FlightEvent {
            at: 9,
            term: 1,
            group: 3,
            kind: EventKind::CommitAdvance,
            a: 5,
            b: 0,
        });
        let f = Frame::StatusResp(Box::new(StatusSnapshot {
            groups: vec![g],
            wal_barriers: 1,
            wal_syncs: 2,
            reads_batched: 3,
            engine_batches: 4,
        }));
        let mut b = encode(&f);
        // The single event's kind byte sits before its two u64 payloads
        // and the snapshot's four trailing u64 scalars.
        let kpos = b.len() - 4 * 8 - 2 * 8 - 1;
        assert_eq!(b[kpos], EventKind::CommitAdvance as u8);
        b[kpos] = 250; // unknown discriminant
        match decode(&b).expect("decode") {
            Frame::StatusResp(s) => {
                assert!(s.groups[0].events.is_empty(), "unknown event must be dropped");
                assert_eq!(s.wal_barriers, 1);
                assert_eq!(s.engine_batches, 4);
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn status_resp_corrupt_counts_rejected() {
        // Poisoned group count.
        let mut b = Vec::new();
        b.push(WIRE_MAGIC);
        b.push(WIRE_VERSION);
        b.push(FRAME_STATUS_RESP);
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = decode(&b).unwrap_err();
        assert!(err.0.contains("exceeds remaining"), "{err:?}");
    }

    #[test]
    fn garbage_rejected() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[99]).is_err());
        assert!(decode(&[WIRE_MAGIC, WIRE_VERSION, 99]).is_err());
        assert!(decode(&[WIRE_MAGIC, WIRE_VERSION, FRAME_RAFT, 0, 0, 0, 0, 0, 0, 0, 0, 77]).is_err());
        // Trailing bytes rejected.
        let mut ok = encode(&Frame::HelloPeer { from: 1 });
        ok.push(0);
        assert!(decode(&ok).is_err());
    }

    #[test]
    fn header_mismatches_rejected_gracefully() {
        let good = encode(&Frame::HelloPeer { from: 1 });
        assert_eq!(good[0], WIRE_MAGIC);
        assert_eq!(good[1], WIRE_VERSION);

        // A pre-header peer's frame starts with a frame-kind byte, not
        // the magic: rejected on byte one with a named error.
        let mut old = good.clone();
        old.remove(0);
        old.remove(0);
        let err = decode(&old).unwrap_err();
        assert!(err.0.contains("bad magic"), "{err:?}");

        // A future version is rejected by name, not misparsed.
        let mut future = good.clone();
        future[1] = WIRE_VERSION + 1;
        let err = decode(&future).unwrap_err();
        assert!(err.0.contains("unsupported wire version"), "{err:?}");
    }
}
