//! Real-mode cluster: the LogCabin-equivalent testbed (paper §7).
//!
//! Each [`server::Server`] wraps the same [`crate::raft::Node`] the
//! simulator drives, but behind real threads, a real monotonic clock
//! with configured error bounds ([`crate::clock::real::RealClock`]),
//! and a length-prefixed binary protocol over TCP ([`wire`]).
//!
//! Threading model (per server):
//! * an acceptor thread takes peer + client connections;
//! * one reader thread per connection decodes frames into the server's
//!   event channel;
//! * one writer thread per outgoing peer link, with an optional injected
//!   one-way delay (the paper's `tc` WAN emulation, §7.2) — the delay
//!   queue preserves FIFO order per link, like netem;
//! * a background [`dialer`] thread that (re)establishes peer links with
//!   jittered exponential backoff, so the main loop never blocks on a
//!   connect;
//! * the main loop owns the Node: it drains events, fires due timers,
//!   batches concurrently-arrived reads through the XLA admission
//!   engine when enabled, persists durable state ([`crate::storage`])
//!   and then routes outputs — nothing is externalized before it is on
//!   disk.
//!
//! Python never appears anywhere here: the admission engine executes an
//! AOT artifact through PJRT.

pub mod dialer;
pub mod server;
pub mod transport;
pub mod wire;

pub use server::{Server, ServerConfig, ServerHandle};
