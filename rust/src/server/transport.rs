//! Framed TCP transport + WAN delay injection.
//!
//! Frames are u32-length-prefixed wire bodies. [`DelayedSender`] is the
//! `tc netem` stand-in from the paper's §7.2 latency experiments: an
//! outgoing queue thread that holds each frame for a configured one-way
//! delay before writing it, preserving per-link FIFO order.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Write one frame (length prefix + body).
pub fn write_frame(stream: &mut TcpStream, body: &[u8]) -> std::io::Result<()> {
    let len = (body.len() as u32).to_le_bytes();
    stream.write_all(&len)?;
    stream.write_all(body)
}

/// Read one frame body. Returns None on clean EOF.
pub fn read_frame(stream: &mut TcpStream) -> std::io::Result<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    match stream.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let n = u32::from_le_bytes(len) as usize;
    if n > 64 << 20 {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "frame too large"));
    }
    let mut body = vec![0u8; n];
    stream.read_exact(&mut body)?;
    Ok(Some(body))
}

/// An outgoing link with an injected one-way delay. Send is non-blocking
/// for the caller; a dedicated thread enforces the delay and writes in
/// FIFO order. Dropping the handle closes the link.
pub struct DelayedSender {
    tx: Sender<(Instant, Vec<u8>)>,
    _thread: JoinHandle<()>,
}

impl DelayedSender {
    pub fn new(mut stream: TcpStream, delay: Duration) -> Self {
        let (tx, rx) = channel::<(Instant, Vec<u8>)>();
        let thread = std::thread::spawn(move || {
            // netem-style: each frame departs `delay` after it was
            // enqueued; FIFO order is inherent to the channel.
            while let Ok((enqueued, body)) = rx.recv() {
                let due = enqueued + delay;
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                if write_frame(&mut stream, &body).is_err() {
                    break; // peer gone; drain & exit
                }
            }
        });
        DelayedSender { tx, _thread: thread }
    }

    /// Queue a frame; returns false if the link is down.
    pub fn send(&self, body: Vec<u8>) -> bool {
        self.tx.send((Instant::now(), body)).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    #[test]
    fn frame_roundtrip() {
        let (mut a, mut b) = pair();
        write_frame(&mut a, b"hello").unwrap();
        write_frame(&mut a, &[]).unwrap();
        assert_eq!(read_frame(&mut b).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut b).unwrap().unwrap(), Vec::<u8>::new());
        drop(a);
        assert!(read_frame(&mut b).unwrap().is_none());
    }

    #[test]
    fn delayed_sender_enforces_delay_and_order() {
        let (a, mut b) = pair();
        let tx = DelayedSender::new(a, Duration::from_millis(30));
        let t0 = Instant::now();
        assert!(tx.send(b"one".to_vec()));
        assert!(tx.send(b"two".to_vec()));
        assert_eq!(read_frame(&mut b).unwrap().unwrap(), b"one");
        let elapsed = t0.elapsed();
        assert!(elapsed >= Duration::from_millis(28), "{elapsed:?}");
        assert_eq!(read_frame(&mut b).unwrap().unwrap(), b"two");
        // Second frame was enqueued ~immediately, so it should arrive
        // shortly after the first, not 2x the delay.
        assert!(t0.elapsed() < Duration::from_millis(90));
    }

    #[test]
    fn zero_delay_passthrough() {
        let (a, mut b) = pair();
        let tx = DelayedSender::new(a, Duration::ZERO);
        tx.send(b"x".to_vec());
        assert_eq!(read_frame(&mut b).unwrap().unwrap(), b"x");
    }
}
