//! Framed TCP transport + WAN delay injection.
//!
//! Frames are u32-length-prefixed wire bodies. The hot path is
//! allocation-light end to end:
//!
//! * [`write_frame`] coalesces the length prefix and body into a single
//!   vectored write (one syscall per frame instead of two);
//! * [`FrameReader`] wraps the socket in a buffered reader and decodes
//!   bodies into a reusable scratch buffer — no per-frame allocation or
//!   zero-fill once warmed up;
//! * [`DelayedSender`] queues `Arc<[u8]>` bodies, so a broadcast frame
//!   is encoded once and every peer link shares the same bytes.
//!
//! [`DelayedSender`] is the `tc netem` stand-in from the paper's §7.2
//! latency experiments: an outgoing queue thread that holds each frame
//! for a configured one-way delay before writing it, preserving
//! per-link FIFO order.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::io::{self, BufReader, IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Refuse absurd frames (corrupt length prefix, protocol confusion).
const MAX_FRAME_BYTES: usize = 64 << 20;

/// [`FrameReader`] scratch retained across frames. One outsized frame
/// must not pin up to [`MAX_FRAME_BYTES`] per connection forever.
const SCRATCH_RETAIN_BYTES: usize = 256 << 10;

/// Bind a listener with `SO_REUSEADDR`, so a server respawned from its
/// data dir can rebind its fixed port immediately: the killed process's
/// accepted connections linger in TIME_WAIT on that port for ~60 s,
/// which makes a plain `TcpListener::bind` fail with AddrInUse. The std
/// library exposes no socket options pre-bind and external crates are
/// off the table, so on unix we make the three raw libc calls ourselves;
/// elsewhere (and for IPv6) this falls back to a plain bind.
#[cfg(unix)]
pub fn bind_reuse(addr: &str) -> io::Result<TcpListener> {
    use std::net::SocketAddr;
    use std::os::unix::io::FromRawFd;

    let sa: SocketAddr = addr
        .parse()
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidInput, format!("{addr}: {e}")))?;
    let SocketAddr::V4(v4) = sa else { return TcpListener::bind(addr) };

    extern "C" {
        fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        fn setsockopt(fd: i32, level: i32, name: i32, value: *const u8, len: u32) -> i32;
        fn bind(fd: i32, addr: *const u8, len: u32) -> i32;
        fn listen(fd: i32, backlog: i32) -> i32;
        fn close(fd: i32) -> i32;
    }
    const AF_INET: i32 = 2;
    const SOCK_STREAM: i32 = 1;
    const SOL_SOCKET: i32 = 1;
    const SO_REUSEADDR: i32 = 2;

    unsafe {
        let fd = socket(AF_INET, SOCK_STREAM, 0);
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        let fail = |fd: i32| -> io::Error {
            let e = io::Error::last_os_error();
            close(fd);
            e
        };
        let one: i32 = 1;
        if setsockopt(fd, SOL_SOCKET, SO_REUSEADDR, &one as *const i32 as *const u8, 4) != 0 {
            return Err(fail(fd));
        }
        // struct sockaddr_in: u16 family (host order), u16 port (BE),
        // u32 addr (BE), 8 bytes zero padding.
        let mut sin = [0u8; 16];
        sin[0..2].copy_from_slice(&(AF_INET as u16).to_ne_bytes());
        sin[2..4].copy_from_slice(&v4.port().to_be_bytes());
        sin[4..8].copy_from_slice(&v4.ip().octets());
        if bind(fd, sin.as_ptr(), sin.len() as u32) != 0 {
            return Err(fail(fd));
        }
        if listen(fd, 128) != 0 {
            return Err(fail(fd));
        }
        Ok(TcpListener::from_raw_fd(fd))
    }
}

#[cfg(not(unix))]
pub fn bind_reuse(addr: &str) -> io::Result<TcpListener> {
    TcpListener::bind(addr)
}

/// Write one frame (length prefix + body) as a single coalesced
/// vectored write. Loops only if the kernel takes a partial write.
pub fn write_frame<W: Write>(stream: &mut W, body: &[u8]) -> io::Result<()> {
    let len = (body.len() as u32).to_le_bytes();
    let mut hdr = 0usize; // prefix bytes written
    let mut done = 0usize; // body bytes written
    while hdr < len.len() || done < body.len() {
        let iov = [IoSlice::new(&len[hdr..]), IoSlice::new(&body[done..])];
        match stream.write_vectored(&iov) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::WriteZero,
                    "write_frame: connection made no progress",
                ))
            }
            Ok(n) => {
                let h = n.min(len.len() - hdr);
                hdr += h;
                done += n - h;
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Read one frame body into a fresh `Vec`. Returns None on clean EOF.
/// One-shot/test convenience — connection loops use [`FrameReader`].
pub fn read_frame(stream: &mut TcpStream) -> io::Result<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    match stream.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME_BYTES {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "frame too large"));
    }
    let mut body = vec![0u8; n];
    stream.read_exact(&mut body)?;
    Ok(Some(body))
}

/// Per-connection frame reader: buffered socket reads + a reusable body
/// scratch buffer. `next_frame` returns a borrow of the scratch, valid
/// until the next call — decode before reading again.
pub struct FrameReader {
    inner: BufReader<TcpStream>,
    scratch: Vec<u8>,
}

impl FrameReader {
    pub fn new(stream: TcpStream) -> Self {
        FrameReader { inner: BufReader::with_capacity(64 << 10, stream), scratch: Vec::new() }
    }

    /// Read one frame body. Returns Ok(None) on clean EOF. The scratch
    /// is reused across frames (newly grown capacity is the only
    /// zero-fill), so a warm connection reads frames with zero
    /// allocation; it shrinks back once an outsized frame has passed
    /// so one 64 MiB body can't pin that memory for the connection's
    /// lifetime.
    pub fn next_frame(&mut self) -> io::Result<Option<&[u8]>> {
        let mut len = [0u8; 4];
        match self.inner.read_exact(&mut len) {
            Ok(()) => {}
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(None),
            Err(e) => return Err(e),
        }
        let n = u32::from_le_bytes(len) as usize;
        if n > MAX_FRAME_BYTES {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "frame too large"));
        }
        if self.scratch.len() > SCRATCH_RETAIN_BYTES && n <= SCRATCH_RETAIN_BYTES {
            // Steadily large frames keep their buffer (n stays big);
            // a one-off spike is released here.
            self.scratch.truncate(SCRATCH_RETAIN_BYTES);
            self.scratch.shrink_to_fit();
        }
        if self.scratch.len() < n {
            self.scratch.resize(n, 0);
        }
        self.inner.read_exact(&mut self.scratch[..n])?;
        Ok(Some(&self.scratch[..n]))
    }
}

/// An outgoing link with an injected one-way delay. Send is non-blocking
/// for the caller; a dedicated thread enforces the delay and writes in
/// FIFO order. Dropping the handle closes the link. Bodies are shared
/// `Arc<[u8]>`: a broadcast costs one encode + N refcount bumps.
pub struct DelayedSender {
    tx: Sender<(Instant, Arc<[u8]>)>,
    _thread: JoinHandle<()>,
}

impl DelayedSender {
    pub fn new(mut stream: TcpStream, delay: Duration) -> Self {
        let (tx, rx) = channel::<(Instant, Arc<[u8]>)>();
        let thread = std::thread::spawn(move || {
            // netem-style: each frame departs `delay` after it was
            // enqueued; FIFO order is inherent to the channel.
            while let Ok((enqueued, body)) = rx.recv() {
                let due = enqueued + delay;
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                if write_frame(&mut stream, &body).is_err() {
                    break; // peer gone; drain & exit
                }
            }
        });
        DelayedSender { tx, _thread: thread }
    }

    /// Queue a shared frame body; returns false if the link is down.
    pub fn send(&self, body: Arc<[u8]>) -> bool {
        self.tx.send((Instant::now(), body)).is_ok()
    }

    /// Queue an owned body (one-off frames like the peer hello).
    pub fn send_vec(&self, body: Vec<u8>) -> bool {
        self.send(Arc::from(body))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    #[test]
    fn bind_reuse_rebinds_fixed_port() {
        let l = bind_reuse("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        // Put a connection through the port and close server-side first,
        // leaving a TIME_WAIT socket on the listener's port — the
        // situation a respawned server faces.
        let c = TcpStream::connect(&addr).unwrap();
        let (s, _) = l.accept().unwrap();
        drop(s);
        drop(c);
        drop(l);
        let l2 = bind_reuse(&addr).expect("rebind with lingering TIME_WAIT");
        assert_eq!(l2.local_addr().unwrap().to_string(), addr);
    }

    #[test]
    fn frame_roundtrip() {
        let (mut a, mut b) = pair();
        write_frame(&mut a, b"hello").unwrap();
        write_frame(&mut a, &[]).unwrap();
        assert_eq!(read_frame(&mut b).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut b).unwrap().unwrap(), Vec::<u8>::new());
        drop(a);
        assert!(read_frame(&mut b).unwrap().is_none());
    }

    #[test]
    fn frame_reader_reuses_scratch() {
        let (mut a, b) = pair();
        let mut r = FrameReader::new(b);
        write_frame(&mut a, &[7u8; 2048]).unwrap();
        write_frame(&mut a, b"tiny").unwrap();
        write_frame(&mut a, &[]).unwrap();
        assert_eq!(r.next_frame().unwrap().unwrap(), &[7u8; 2048][..]);
        assert_eq!(r.next_frame().unwrap().unwrap(), b"tiny");
        assert_eq!(r.next_frame().unwrap().unwrap(), b"");
        drop(a);
        assert!(r.next_frame().unwrap().is_none());
    }

    #[test]
    fn scratch_shrinks_after_outsized_frame() {
        let (mut a, b) = pair();
        let mut r = FrameReader::new(b);
        // Write from a thread: a 1 MiB frame overflows loopback socket
        // buffers, so writer and reader must run concurrently.
        let writer = std::thread::spawn(move || {
            write_frame(&mut a, &vec![7u8; 1 << 20]).unwrap();
            write_frame(&mut a, b"small").unwrap();
        });
        assert_eq!(r.next_frame().unwrap().unwrap().len(), 1 << 20);
        assert_eq!(r.next_frame().unwrap().unwrap(), b"small");
        writer.join().unwrap();
        assert!(
            r.scratch.len() <= SCRATCH_RETAIN_BYTES,
            "outsized scratch must be released: {} bytes retained",
            r.scratch.len()
        );
    }

    #[test]
    fn delayed_sender_enforces_delay_and_order() {
        let (a, mut b) = pair();
        let tx = DelayedSender::new(a, Duration::from_millis(30));
        let t0 = Instant::now();
        assert!(tx.send_vec(b"one".to_vec()));
        assert!(tx.send_vec(b"two".to_vec()));
        assert_eq!(read_frame(&mut b).unwrap().unwrap(), b"one");
        let elapsed = t0.elapsed();
        assert!(elapsed >= Duration::from_millis(28), "{elapsed:?}");
        assert_eq!(read_frame(&mut b).unwrap().unwrap(), b"two");
        // Second frame was enqueued ~immediately, so it should arrive
        // shortly after the first, not 2x the delay.
        assert!(t0.elapsed() < Duration::from_millis(90));
    }

    #[test]
    fn shared_body_broadcast() {
        // One Arc'd body through two links: both deliver, no copies made
        // by the transport.
        let (a1, mut b1) = pair();
        let (a2, mut b2) = pair();
        let tx1 = DelayedSender::new(a1, Duration::ZERO);
        let tx2 = DelayedSender::new(a2, Duration::ZERO);
        let body: Arc<[u8]> = Arc::from(&b"broadcast"[..]);
        assert!(tx1.send(body.clone()));
        assert!(tx2.send(body.clone()));
        assert_eq!(read_frame(&mut b1).unwrap().unwrap(), b"broadcast");
        assert_eq!(read_frame(&mut b2).unwrap().unwrap(), b"broadcast");
    }

    #[test]
    fn zero_delay_passthrough() {
        let (a, mut b) = pair();
        let tx = DelayedSender::new(a, Duration::ZERO);
        tx.send_vec(b"x".to_vec());
        assert_eq!(read_frame(&mut b).unwrap().unwrap(), b"x");
    }
}
