//! Minimal CLI argument parser (no clap offline): subcommand + repeated
//! `--key value` / `--key=value` flags + positionals.
//!
//! All `--param key=value` flags funnel into [`crate::config::Params`],
//! so every protocol and workload knob is reachable from the launcher:
//!
//! ```text
//! leaseguard sim --param consistency=quorum --param seed=7
//! leaseguard scenarios --json --param seed=3
//! leaseguard figure 7 --out results/
//! leaseguard serve --node 0 --listen 127.0.0.1:7100 --peers 127.0.0.1:7101,127.0.0.1:7102
//! ```

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    /// flag -> values (repeatable flags keep all values, in order).
    pub flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if flag.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                let (k, v) = if let Some((k, v)) = flag.split_once('=') {
                    (k.to_string(), v.to_string())
                } else {
                    // Value is the next token unless it's another flag or
                    // missing — then treat as boolean true.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            (flag.to_string(), it.next().unwrap())
                        }
                        _ => (flag.to_string(), "true".to_string()),
                    }
                };
                out.flags.entry(k).or_default().push(v);
            } else if out.subcommand.is_none() && out.positionals.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positionals.push(a);
            }
        }
        Ok(out)
    }

    /// Last value of a flag (last occurrence wins, like most CLIs).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values of a repeatable flag.
    pub fn get_all(&self, key: &str) -> &[String] {
        self.flags.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| format!("bad value for --{key}: '{v}' ({e})")),
        }
    }

    /// Apply `--config FILE` then all `--param k=v` flags to `params`.
    pub fn apply_params(&self, params: &mut crate::config::Params) -> Result<(), String> {
        if let Some(path) = self.get("config") {
            let body = std::fs::read_to_string(path)
                .map_err(|e| format!("cannot read config {path}: {e}"))?;
            params.apply_file(&body)?;
        }
        for kv in self.get_all("param") {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| format!("--param wants key=value, got '{kv}'"))?;
            params.set(k.trim(), v.trim())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("figure 7 extra");
        assert_eq!(a.subcommand.as_deref(), Some("figure"));
        assert_eq!(a.positionals, vec!["7", "extra"]);
    }

    #[test]
    fn flags_equals_and_space() {
        let a = parse("sim --seed=9 --out results");
        assert_eq!(a.get("seed"), Some("9"));
        assert_eq!(a.get("out"), Some("results"));
    }

    #[test]
    fn boolean_flags() {
        let a = parse("sim --verbose --seed 3");
        assert!(a.get_bool("verbose"));
        assert_eq!(a.get("seed"), Some("3"));
    }

    #[test]
    fn repeatable_params() {
        let a = parse("sim --param a=1 --param b=2");
        assert_eq!(a.get_all("param"), &["a=1".to_string(), "b=2".to_string()]);
    }

    #[test]
    fn apply_params_roundtrip() {
        let a = parse("sim --param consistency=quorum --param seed=42");
        let mut p = crate::config::Params::default();
        a.apply_params(&mut p).unwrap();
        assert_eq!(p.seed, 42);
        assert_eq!(p.consistency, crate::config::ConsistencyMode::Quorum);
    }

    #[test]
    fn bad_param_reported() {
        let a = parse("sim --param nonsense");
        let mut p = crate::config::Params::default();
        assert!(a.apply_params(&mut p).is_err());
    }

    #[test]
    fn last_flag_wins() {
        let a = parse("sim --seed 1 --seed 2");
        assert_eq!(a.get("seed"), Some("2"));
    }
}
