//! The simulated replica set harness: drives [`crate::raft::Node`]s over
//! the deterministic event loop and simulated network, runs the
//! open-loop workload, records the client history, and produces the
//! paper's metrics (the `run_with_params.py` of §6.1).
//!
//! Experiment shape (matching §6.5): bootstrap until a first leader has
//! committed its term-start no-op (that instant is the experiment's
//! *origin* `t0`), then run the workload under the installed
//! [`NemesisSchedule`] (the legacy single-fault `Params` knobs compile
//! onto the same rails); finally drain, fail leftover operations as
//! timeouts, and return a [`RunReport`].

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::collections::BTreeMap;

use crate::clock::sim::{SimClock, SimClockConfig};
use crate::clock::TimeInterval;
use crate::config::Params;
use crate::history::{History, HistoryEntry, OpKind};
use crate::metrics::{Histogram, TimeSeries};
use crate::obs::{dump_window, FlightRecorder};
use crate::prob::Rng;
use crate::raft::{FailReason, Message, Node, NodeConfig, OpId, OpResult, Output, Role, TimerKind};
use crate::shard::{group_seed, GroupId, ShardMap};
use crate::sim::network::{Delivery, NetConfig};
use crate::sim::{EventQueue, Fault, NemesisSchedule, SimNetwork, TimedFault};
use crate::workload::{OpSpec, Workload};
use crate::{Micros, NodeId};

/// Events in a simulated run.
///
/// `Deliver` holds the in-flight [`Message`] by value; since
/// AppendEntries batches are Arc-backed [`crate::raft::EntryBatch`]
/// views, a fan-out to N−1 peers queues N−1 deliveries that all share
/// one entry allocation — the simulator pays no per-delivery deep copy.
#[derive(Debug)]
enum Event {
    /// `epoch` is the destination's crash epoch at send time; a delivery
    /// queued before the destination's crash is dropped at delivery time
    /// (a rebooted process never receives its predecessor's packets).
    /// `group` routes the message to the right Raft group on the
    /// destination process (the sim analogue of the wire-frame group id).
    Deliver { to: NodeId, group: GroupId, msg: Message, epoch: u64 },
    /// Timers carry the same crash-epoch stamp: a timer armed by the
    /// pre-crash incarnation must not fire on the restarted one (each
    /// leaked election-timer chain re-arms itself forever).
    Timer { node: NodeId, group: GroupId, kind: TimerKind, epoch: u64 },
    ClientOp(OpSpec),
    OpTimeout(OpId),
    /// A Nemesis fault fires (role-relative faults resolve now).
    Fault(Fault),
    Restart(NodeId),
    End,
}

/// How long after a node crash its in-flight client ops fail (the
/// client's broken-connection detection, same scale as the 1ms
/// connection-refused fast-fail).
const CRASH_DETECT_US: Micros = 1000;

/// An operation in flight from the client's perspective.
#[derive(Debug)]
struct PendingOp {
    key: u32,
    group: GroupId,
    write_value: Option<u64>,
    start_ts: Micros,
}

/// Everything a figure driver needs from one run.
#[derive(Debug)]
pub struct RunReport {
    /// Origin: true time the first leader committed its no-op; all
    /// series/timestamps below are relative to it.
    pub t0: Micros,
    pub series: TimeSeries,
    pub read_latency: Histogram,
    pub write_latency: Histogram,
    pub history: History,
    pub elections: u64,
    pub events_processed: u64,
    /// Per-node counters, flattened `group * nodes + process` (so with
    /// one group this is simply indexed by process id, as before).
    pub node_stats: Vec<crate::raft::node::NodeStats>,
    /// Limbo-region length observed on the post-crash leader (paper
    /// Fig 9 reports 37).
    pub limbo_len: u64,
    /// Nemesis faults that actually fired (role-relative faults that
    /// found no live target still count as fired).
    pub faults_injected: u64,
    /// Per-node flight recorders, flattened `group * nodes + process`
    /// like `node_stats`. Empty rings when tracing is disabled.
    pub recorders: Vec<FlightRecorder>,
    /// Processes per group (the flattening stride for `recorders`).
    pub nodes_per_group: usize,
}

impl RunReport {
    /// Render every node's flight-recorder events inside `[from, to]`
    /// (true sim time, µs) — the evidence trail attached to a failed
    /// linearizability check. Labels are `g<group>/n<process>`.
    pub fn dump_flight_window(&self, title: &str, from: Micros, to: Micros) -> String {
        let n = self.nodes_per_group.max(1);
        let labels: Vec<String> = (0..self.recorders.len())
            .map(|i| format!("g{}/n{}", i / n, i % n))
            .collect();
        let refs: Vec<&FlightRecorder> = self.recorders.iter().collect();
        dump_window(title, &labels, &refs, from, to)
    }
}

pub struct Cluster {
    params: Params,
    queue: EventQueue<Event>,
    /// All Raft nodes across all groups, flattened `g * nodes + p`: a
    /// process `p` hosts one node per group, all sharing `clocks[p]` and
    /// the process-level `net` liveness/epoch state (a crash takes every
    /// group on the process down together, like the real server).
    nodes: Vec<Node>,
    /// Keyspace partition; group 0 is the whole keyspace when groups=1.
    shard_map: ShardMap,
    clocks: Vec<SimClock>,
    net: SimNetwork,
    rng: Rng,

    // client state (leader discovery is per group — each group elects
    // independently, so the client tracks a belief per group)
    believed_leader: Vec<Option<NodeId>>,
    client_rng: Rng,
    probe_next: Vec<NodeId>,
    /// Consecutive failed ops against the believed leader of each group;
    /// clients give up on a target that persistently fails (e.g. a
    /// deposed leader answering NoLease forever after a partition).
    fail_streak: Vec<u32>,
    // BTreeMap (lint R2): both maps are iterated on paths that feed
    // the history (end-of-run drain, crash sweep), so their order must
    // be OpId order, not hash order.
    pending: BTreeMap<OpId, PendingOp>,
    last_target_for: BTreeMap<OpId, NodeId>,
    next_op_id: OpId,

    // recording
    t0: Micros,
    series: TimeSeries,
    read_latency: Histogram,
    write_latency: Histogram,
    history: History,
    elections: u64,
    limbo_len: u64,
    faults_injected: u64,
    /// Fault schedule (relative to t0), installed via [`Self::with_nemesis`].
    nemesis: NemesisSchedule,
}

impl Cluster {
    pub fn new(params: Params) -> Self {
        params.validate().expect("invalid params");
        let mut rng = Rng::new(params.seed);
        let n = params.nodes;
        let net_cfg = NetConfig {
            one_way_mean_us: params.net_mean_us,
            one_way_variance_us2: params.net_variance_us2,
            min_delay_us: params.net_min_delay_us,
            loss: params.net_loss,
        };
        let net = SimNetwork::new(n, net_cfg, &mut rng);
        let clock_cfg = SimClockConfig {
            max_error_us: params.clock_error_us,
            drift: params.clock_drift,
            broken: params.clock_broken,
        };
        let client_rng = rng.fork();
        let groups = params.groups;
        let mut queue = EventQueue::new();
        let mut nodes = Vec::with_capacity(groups * n);
        let mut clocks = Vec::with_capacity(n);
        // Group-major node layout (`g * n + p`): group 0 is created
        // exactly as the pre-sharding single-group cluster was — same
        // RNG draws, same seed — so 1-group runs replay byte-identically.
        for g in 0..groups as GroupId {
            for id in 0..n {
                if g == 0 {
                    clocks.push(SimClock::new(clock_cfg.clone(), &mut rng));
                }
                let now = clocks[id].at(0);
                let (node, outs) = Node::new(
                    NodeConfig::from_params(id, &params).for_group(g),
                    group_seed(params.seed, g),
                    now,
                );
                nodes.push(node);
                for o in outs {
                    if let Output::SetTimer { kind, after } = o {
                        let epoch = net.epoch(id);
                        queue.schedule_in(after, Event::Timer { node: id, group: g, kind, epoch });
                    }
                }
            }
        }
        Cluster {
            series: TimeSeries::new(params.bucket_us, params.duration_us),
            shard_map: ShardMap::new(groups),
            params,
            queue,
            nodes,
            clocks,
            net,
            rng,
            believed_leader: vec![None; groups],
            client_rng,
            probe_next: vec![0; groups],
            fail_streak: vec![0; groups],
            pending: BTreeMap::new(),
            last_target_for: BTreeMap::new(),
            next_op_id: 1,
            t0: 0,
            read_latency: Histogram::new(),
            write_latency: Histogram::new(),
            history: History::new(),
            elections: 0,
            limbo_len: 0,
            faults_injected: 0,
            nemesis: NemesisSchedule::new(),
        }
    }

    /// Install a Nemesis fault schedule (times relative to t0). Runs on
    /// top of — and after, at equal instants — whatever the legacy
    /// single-fault `Params` knobs schedule.
    pub fn with_nemesis(mut self, schedule: NemesisSchedule) -> Self {
        self.nemesis = schedule;
        self
    }

    /// Run the full experiment and return the report.
    pub fn run(mut self) -> RunReport {
        // ---- phase 1: bootstrap to the first committed no-op ----
        // Multi-group: every group must have a committed leader before
        // the workload starts, so t0 means the same thing per shard.
        let deadline = 60_000_000; // sanity bound
        while !self.stable_leader_exists() {
            let Some((_, ev)) = self.queue.pop() else { panic!("bootstrap starved") };
            self.handle(ev);
            assert!(self.queue.now() < deadline, "no leader in every group within 60s of sim time");
        }
        self.t0 = self.queue.now();

        // ---- phase 2: schedule workload + fault schedule + end ----
        let mut workload = Workload::from_params(&self.params, &mut self.rng);
        let ops = workload.schedule(self.params.duration_us);
        for op in ops {
            self.queue.schedule(self.t0 + op.at, Event::ClientOp(op));
        }
        // Fault schedule: the legacy single-fault knobs compile onto the
        // same Nemesis rails as installed schedules.
        let mut schedule = NemesisSchedule::new();
        if self.params.crash_leader_at_us > 0 {
            let restart_after_us =
                (self.params.restart_after_us > 0).then_some(self.params.restart_after_us);
            schedule = schedule
                .at(self.params.crash_leader_at_us, Fault::CrashLeader { restart_after_us });
        }
        if self.params.partition_leader_at_us > 0 {
            schedule = schedule.at(self.params.partition_leader_at_us, Fault::PartitionLeader);
            if self.params.heal_after_us > 0 {
                schedule = schedule.at(
                    self.params.partition_leader_at_us + self.params.heal_after_us,
                    Fault::Heal,
                );
            }
        }
        schedule.events.extend(std::mem::take(&mut self.nemesis).events);
        for TimedFault { at, fault } in schedule.events {
            self.queue.schedule(self.t0 + at, Event::Fault(fault));
        }
        self.queue.schedule(self.t0 + self.params.duration_us, Event::End);

        // ---- phase 3: run ----
        let end_at = self.t0 + self.params.duration_us;
        while let Some((t, ev)) = self.queue.pop() {
            if matches!(ev, Event::End) || t > end_at + 1 {
                break;
            }
            self.handle(ev);
        }

        // Drain: remaining in-flight ops are client timeouts. BTreeMap
        // iteration is already OpId-ordered, so the history tail is
        // deterministic without a sort.
        let now = self.queue.now();
        let pending: Vec<OpId> = self.pending.keys().copied().collect();
        for op in pending {
            self.finish_op(op, OpResult::Failed(FailReason::Timeout), now);
        }

        RunReport {
            t0: self.t0,
            series: self.series,
            read_latency: self.read_latency,
            write_latency: self.write_latency,
            history: self.history,
            elections: self.elections,
            events_processed: self.queue.processed(),
            node_stats: self.nodes.iter().map(|n| n.stats).collect(),
            limbo_len: self.limbo_len,
            faults_injected: self.faults_injected,
            recorders: self.nodes.iter().map(|n| n.recorder().clone()).collect(),
            nodes_per_group: self.params.nodes,
        }
    }

    /// Flattened index of group `g`'s node on process `p`.
    fn idx(&self, g: GroupId, p: NodeId) -> usize {
        g as usize * self.params.nodes + p
    }

    fn stable_leader_exists(&self) -> bool {
        let n = self.params.nodes;
        (0..self.params.groups).all(|g| {
            self.nodes[g * n..(g + 1) * n]
                .iter()
                .any(|node| node.role() == Role::Leader && node.commit_index() >= 1)
        })
    }

    fn now_interval(&mut self, node: NodeId) -> TimeInterval {
        let t = self.queue.now();
        self.clocks[node].at(t)
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::Deliver { to, group, msg, epoch } => {
                // Crash-epoch check: `is_up` alone would happily deliver
                // a pre-crash message to the restarted incarnation.
                if !self.net.is_up(to) || self.net.epoch(to) != epoch {
                    return;
                }
                let now = self.now_interval(to);
                let i = self.idx(group, to);
                let outs = self.nodes[i].on_message(now, msg);
                self.process_outputs(to, group, outs);
            }
            Event::Timer { node, group, kind, epoch } => {
                if !self.net.is_up(node) || self.net.epoch(node) != epoch {
                    return;
                }
                let now = self.now_interval(node);
                let i = self.idx(group, node);
                let outs = self.nodes[i].on_timer(now, kind);
                self.process_outputs(node, group, outs);
            }
            Event::ClientOp(spec) => self.start_client_op(spec),
            Event::OpTimeout(op) => {
                if self.pending.contains_key(&op) {
                    let now = self.queue.now();
                    self.finish_op(op, OpResult::Failed(FailReason::Timeout), now);
                }
            }
            Event::Fault(f) => self.apply_fault(f),
            Event::Restart(node) => {
                // A process restart reboots every group it hosts.
                self.net.restart(node);
                let now = self.now_interval(node);
                for g in 0..self.params.groups as GroupId {
                    let i = self.idx(g, node);
                    let outs = self.nodes[i].restart(now);
                    self.process_outputs(node, g, outs);
                }
            }
            Event::End => {}
        }
    }

    fn process_outputs(&mut self, from: NodeId, group: GroupId, outs: Vec<Output>) {
        let now = self.queue.now();
        for o in outs {
            match o {
                Output::Send { to, msg } => {
                    let epoch = self.net.epoch(to);
                    // Snapshot chunks are *sized* deliveries: the chunk
                    // occupies line time proportional to its bytes, so a
                    // multi-chunk transfer spans many events and Nemesis
                    // faults can land mid-transfer. Everything else stays
                    // payload-agnostic (and RNG-identical to before).
                    let verdict = match &msg {
                        Message::SnapInstall { data, .. } => {
                            self.net.send_sized(from, to, data.len())
                        }
                        _ => self.net.send(from, to),
                    };
                    match verdict {
                        Delivery::After(d) => {
                            self.queue.schedule(now + d, Event::Deliver { to, group, msg, epoch });
                        }
                        Delivery::Twice(d1, d2) => {
                            // Duplication window: two copies, O(1) clone
                            // (entry batches are Arc-backed views).
                            self.queue.schedule(
                                now + d1,
                                Event::Deliver { to, group, msg: msg.clone(), epoch },
                            );
                            self.queue.schedule(now + d2, Event::Deliver { to, group, msg, epoch });
                        }
                        Delivery::Dropped => {}
                    }
                }
                Output::SetTimer { kind, after } => {
                    let epoch = self.net.epoch(from);
                    self.queue
                        .schedule(now + after, Event::Timer { node: from, group, kind, epoch });
                }
                Output::Reply { op, result } => self.finish_op(op, result, now),
                Output::Applied { key, value } => self.history.applies.record(key, value, now),
                Output::ElectedLeader { .. } => {
                    self.elections += 1;
                    // Record the new leader's limbo-region size once a
                    // post-crash election happens (Fig 9's "37 entries").
                    let i = self.idx(group, from);
                    if let Some(l) = self.nodes[i].lease_state() {
                        self.limbo_len = self.limbo_len.max(l.limbo_len());
                    }
                }
                Output::SteppedDown => {}
            }
        }
    }

    // ------------------------------------------------------------ client

    fn start_client_op(&mut self, spec: OpSpec) {
        let now = self.queue.now();
        let op = self.next_op_id;
        self.next_op_id += 1;
        // Route by key first — each group has its own leader, so the
        // target choice below is per group.
        let group = self.shard_map.group_of(spec.key);
        let g = group as usize;
        // Pick a target: believed leader, else probe round-robin. With
        // probability `client_stray_prob` the op goes to a random node
        // instead — modelling the paper's fleet of concurrent clients,
        // some of which still address a deposed leader.
        let stray = self.params.client_stray_prob > 0.0
            && self.client_rng.chance(self.params.client_stray_prob);
        let target = if stray {
            self.client_rng.below(self.params.nodes as u64) as usize
        } else {
            match self.believed_leader[g] {
                Some(l) => l,
                None => {
                    let t = self.probe_next[g] % self.params.nodes;
                    self.probe_next[g] = (self.probe_next[g] + 1) % self.params.nodes;
                    t
                }
            }
        };
        self.pending.insert(
            op,
            PendingOp { key: spec.key, group, write_value: spec.write_value, start_ts: now },
        );
        self.queue
            .schedule(now + self.params.op_timeout_us, Event::OpTimeout(op));
        if !self.net.is_up(target) {
            // Connection refused / broken pipe: fast failure, try
            // another node next time.
            self.believed_leader[g] = None;
            let fail_at = now + 1000;
            let op_copy = op;
            self.queue.schedule(fail_at, Event::OpTimeout(op_copy));
            return;
        }
        let nowi = self.now_interval(target);
        let i = self.idx(group, target);
        let outs = match spec.write_value {
            Some(v) => {
                self.nodes[i].client_write(nowi, op, spec.key, v, spec.payload_bytes)
            }
            None => self.nodes[i].client_read(nowi, op, spec.key),
        };
        // Leader-discovery belief update happens in finish_op (replies
        // other than NotLeader imply the target led).
        self.last_target_for.insert(op, target);
        self.process_outputs(target, group, outs);
    }

    fn finish_op(&mut self, op: OpId, result: OpResult, now: Micros) {
        let Some(p) = self.pending.remove(&op) else { return };
        let target = self.last_target_for.remove(&op);
        let g = p.group as usize;
        let is_read = p.write_value.is_none();
        let success = result.is_ok();
        // Client leader discovery: pin on success; unpin on NotLeader /
        // unreachability; leave unchanged on NoLease-style failures (the
        // node led, it just couldn't serve yet). All per group.
        match &result {
            OpResult::Failed(FailReason::NotLeader) => {
                if self.believed_leader[g] == target {
                    self.believed_leader[g] = None;
                }
            }
            OpResult::Failed(FailReason::Timeout) => {
                if self.believed_leader[g] == target || target.is_none() {
                    self.believed_leader[g] = None;
                }
            }
            OpResult::Failed(_) => {
                // NoLease / LimboConflict / gate failures: the target
                // led, so stay — but not forever (a partitioned deposed
                // leader answers NoLease indefinitely).
                if self.believed_leader[g].is_some() && self.believed_leader[g] == target {
                    self.fail_streak[g] += 1;
                    if self.fail_streak[g] >= 20 {
                        self.believed_leader[g] = None;
                        self.fail_streak[g] = 0;
                    }
                }
            }
            _ => {
                if let Some(t) = target {
                    self.believed_leader[g] = Some(t);
                    self.fail_streak[g] = 0;
                }
            }
        }
        // Metrics (relative to t0; bootstrap ops land in bucket 0).
        let rel = (now - self.t0).max(0);
        self.series.record(is_read, rel, success);
        if success {
            let lat = now - p.start_ts;
            if is_read {
                self.read_latency.record(lat);
            } else {
                self.write_latency.record(lat);
            }
        }
        // History.
        let (kind, exec) = match (&result, p.write_value) {
            (OpResult::ReadOk(v), _) => (OpKind::Read { result: (**v).clone() }, Some(now)),
            (_, Some(v)) => (OpKind::Append { value: v }, None),
            (_, None) => (OpKind::Read { result: Vec::new() }, None),
        };
        let fail = match result {
            OpResult::Failed(r) => Some(r),
            _ => None,
        };
        self.history.entries.push(HistoryEntry {
            op,
            key: p.key,
            kind,
            start_ts: p.start_ts,
            end_ts: now,
            execution_ts: exec,
            success,
            fail,
        });
    }

    // ------------------------------------------------------------ faults

    /// The highest-term live leader (the active one), if any.
    ///
    /// Multi-group: role-relative faults (CrashLeader, PartitionLeader,
    /// …) resolve against **group 0**'s leader. Crashes are process-wide
    /// anyway, and group 0 is the reference group for scenario authors;
    /// target other groups' leaders with node-addressed faults.
    fn live_leader(&self) -> Option<NodeId> {
        self.nodes[..self.params.nodes]
            .iter()
            .enumerate()
            .filter(|(i, n)| n.role() == Role::Leader && self.net.is_up(*i))
            .max_by_key(|(_, n)| n.term())
            .map(|(i, _)| i)
    }

    /// Schedule-author guard: a node-addressed fault naming a node this
    /// cluster doesn't have is a scenario bug — flag it loudly in debug
    /// builds, skip it (rather than panic mid-matrix) in release.
    fn valid_node(&self, node: NodeId) -> bool {
        debug_assert!(
            node < self.params.nodes,
            "fault addresses node {node}, cluster has {}",
            self.params.nodes
        );
        node < self.params.nodes
    }

    /// The lowest-id live non-leader (of group 0), if any.
    fn live_follower(&self) -> Option<NodeId> {
        self.nodes[..self.params.nodes]
            .iter()
            .enumerate()
            .find(|(i, n)| n.role() != Role::Leader && self.net.is_up(*i))
            .map(|(i, _)| i)
    }

    fn apply_fault(&mut self, fault: Fault) {
        self.faults_injected += 1;
        match fault {
            Fault::CrashLeader { restart_after_us } => {
                if let Some(v) = self.live_leader() {
                    self.crash_node(v, restart_after_us);
                }
            }
            Fault::CrashFollower { restart_after_us } => {
                if let Some(v) = self.live_follower() {
                    self.crash_node(v, restart_after_us);
                }
            }
            Fault::CrashNode { node, restart_after_us } => {
                if self.valid_node(node) && self.net.is_up(node) {
                    self.crash_node(node, restart_after_us);
                }
            }
            Fault::PartitionLeader => {
                // Isolate the active leader from its peers; clients can
                // still reach it (the §1 deposed-leader scenario).
                if let Some(v) = self.live_leader() {
                    self.net.partition(&[v]);
                }
            }
            Fault::PartitionNodes(nodes) => self.net.partition(&nodes),
            Fault::CutLeaderInbound => {
                if let Some(v) = self.live_leader() {
                    for p in 0..self.params.nodes {
                        if p != v {
                            self.net.cut_link(p, v);
                        }
                    }
                }
            }
            Fault::Heal => self.net.heal(),
            Fault::SetDuplicate(p) => self.net.set_duplicate(p),
            Fault::SetLoss(p) => self.net.set_loss(p),
            Fault::SetReorder(us) => self.net.set_reorder(us),
            Fault::ClearChaos => self.net.clear_chaos(),
            Fault::LeaderClockSkew(offset_us) => {
                if let Some(v) = self.live_leader() {
                    self.clocks[v].inject_skew(offset_us);
                }
            }
            Fault::NodeClockSkew { node, offset_us } => {
                if self.valid_node(node) {
                    self.clocks[node].inject_skew(offset_us);
                }
            }
            Fault::SetNodeDrift { node, drift } => {
                if self.valid_node(node) {
                    let now = self.queue.now();
                    self.clocks[node].set_drift(now, drift);
                }
            }
            Fault::PlannedHandover => {
                // Group 0's leader hands over (see [`Self::live_leader`]).
                if let Some(v) = self.live_leader() {
                    let now = self.now_interval(v);
                    let outs = self.nodes[v].begin_stepdown(now);
                    self.process_outputs(v, 0, outs);
                }
            }
        }
    }

    /// Crash `v`: cut it off (bumping its crash epoch), fail its
    /// in-flight client ops promptly — a crashed node can never answer,
    /// and letting them ride the full client timeout skews every
    /// availability/latency stat — and optionally schedule the reboot.
    /// Any number of nodes may be down at once: liveness is per-node in
    /// `SimNetwork` (`up` + crash epochs), with no single-victim state.
    fn crash_node(&mut self, v: NodeId, restart_after_us: Option<Micros>) {
        self.net.crash(v);
        let now = self.queue.now();
        // BTreeMap iteration is OpId-ordered, so the timeout schedule
        // is deterministic without a sort.
        let dead: Vec<OpId> = self
            .last_target_for
            .iter()
            .filter(|&(op, &t)| t == v && self.pending.contains_key(op))
            .map(|(&op, _)| op)
            .collect();
        for op in dead {
            self.queue.schedule(now + CRASH_DETECT_US, Event::OpTimeout(op));
        }
        if let Some(after) = restart_after_us {
            self.queue.schedule_in(after, Event::Restart(v));
        }
    }

    /// Test hook: partition a set of nodes away from the rest.
    pub fn inject_partition(&mut self, minority: &[NodeId]) {
        self.net.partition(minority);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ConsistencyMode;
    use crate::linearizability;

    fn base_params(mode: ConsistencyMode, seed: u64) -> Params {
        let mut p = Params::default();
        p.consistency = mode;
        p.seed = seed;
        p.duration_us = 1_500_000;
        p.interarrival_us = 1000.0;
        p
    }

    #[test]
    fn steady_state_all_modes_linearizable_but_inconsistent() {
        for mode in ConsistencyMode::ALL {
            let rep = Cluster::new(base_params(mode, 42)).run();
            let ok_reads = rep.series.window_totals(true, 0, i64::MAX).ok;
            let ok_writes = rep.series.window_totals(false, 0, i64::MAX).ok;
            assert!(ok_reads > 100, "{mode}: only {ok_reads} reads ok");
            assert!(ok_writes > 50, "{mode}: only {ok_writes} writes ok");
            // No elections, no crash: every mode is linearizable here.
            linearizability::assert_linearizable(&rep.history);
        }
    }

    #[test]
    fn deterministic_runs() {
        let a = Cluster::new(base_params(ConsistencyMode::LeaseGuard, 7)).run();
        let b = Cluster::new(base_params(ConsistencyMode::LeaseGuard, 7)).run();
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.t0, b.t0);
        // Byte-identical histories, entry for entry — not just counts.
        // This is the regression guard for the R2 class of bugs
        // (unordered-map iteration feeding the history).
        assert_eq!(a.history.entries.len(), b.history.entries.len());
        for (ea, eb) in a.history.entries.iter().zip(b.history.entries.iter()) {
            assert_eq!(format!("{ea:?}"), format!("{eb:?}"));
        }
    }

    #[test]
    fn deterministic_runs_under_crash_drain() {
        // Crash + end-of-run drain both iterate the pending-op maps;
        // replays must stay identical on those paths too (the maps are
        // BTreeMaps precisely so this holds).
        let mut p = base_params(ConsistencyMode::LeaseGuard, 23);
        p.duration_us = 2_000_000;
        p.crash_leader_at_us = 400_000;
        p.interarrival_us = 400.0;
        let a = Cluster::new(p.clone()).run();
        let b = Cluster::new(p).run();
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.history.entries.len(), b.history.entries.len());
        for (ea, eb) in a.history.entries.iter().zip(b.history.entries.iter()) {
            assert_eq!(format!("{ea:?}"), format!("{eb:?}"));
        }
    }

    #[test]
    fn crash_failover_leaseguard_linearizable() {
        let mut p = base_params(ConsistencyMode::LeaseGuard, 11);
        p.duration_us = 2_500_000;
        p.crash_leader_at_us = 500_000;
        p.interarrival_us = 500.0;
        let rep = Cluster::new(p).run();
        assert!(rep.elections >= 2, "expected failover election");
        linearizability::assert_linearizable(&rep.history);
        // Reads succeed during the interregnum-to-lease window thanks to
        // inherited leases: some reads between election and lease expiry.
        let post = rep.series.window_totals(true, 1_000_000, 1_500_000);
        assert!(post.ok > 0, "inherited lease reads should succeed: {post:?}");
    }

    #[test]
    fn stale_precrash_delivery_dropped_after_restart() {
        // Satellite regression: a message queued for delivery before a
        // node's crash must not be delivered after it restarts — the
        // crash-epoch check, not just `is_up`, decides.
        let mut c = Cluster::new(base_params(ConsistencyMode::LeaseGuard, 42));
        while !c.stable_leader_exists() {
            let Some((_, ev)) = c.queue.pop() else { panic!("bootstrap starved") };
            c.handle(ev);
        }
        let victim = 0;
        let old_epoch = c.net.epoch(victim);
        let term_before = c.nodes[victim].term();
        // The poison message was queued before the crash...
        c.net.crash(victim);
        c.net.restart(victim);
        // ...and would arrive after the reboot. `is_up` alone says yes;
        // the epoch check must say no.
        let poison = Message::RequestVote {
            term: 99,
            candidate: 1,
            last_log_index: u64::MAX,
            last_log_term: 99,
        };
        c.handle(Event::Deliver { to: victim, group: 0, msg: poison.clone(), epoch: old_epoch });
        assert_eq!(
            c.nodes[victim].term(),
            term_before,
            "stale pre-crash delivery reached the restarted node"
        );
        // A fresh post-restart send is delivered normally.
        let epoch = c.net.epoch(victim);
        c.handle(Event::Deliver { to: victim, group: 0, msg: poison, epoch });
        assert_eq!(c.nodes[victim].term(), 99);
    }

    #[test]
    fn concurrent_crashes_tracked_independently() {
        // Satellite regression: two nodes down at once; both restarts
        // must be honored (the old `crashed: Option<NodeId>` overwrote
        // the first victim on the second crash).
        let mut p = base_params(ConsistencyMode::LeaseGuard, 19);
        p.nodes = 5;
        p.duration_us = 3_000_000;
        let sched = NemesisSchedule::new()
            .at(600_000, Fault::CrashFollower { restart_after_us: Some(500_000) })
            .at(600_000, Fault::CrashFollower { restart_after_us: Some(500_000) });
        let rep = Cluster::new(p).with_nemesis(sched).run();
        assert_eq!(rep.faults_injected, 2);
        crate::linearizability::assert_linearizable(&rep.history);
        // Quorum held throughout (leader + 2 live of 5): reads keep
        // flowing during the double outage.
        let during = rep.series.window_totals(true, 700_000, 1_000_000);
        assert!(during.ok > 100, "quorum survived the double crash: {during:?}");
    }

    #[test]
    fn compaction_armed_but_never_firing_is_byte_identical() {
        // The determinism guard for the snapshot subsystem: with the
        // threshold unreachable, maybe_take_snapshot is a compare and a
        // return — no RNG draws, no trace, no control-flow change — so
        // the run must replay byte-identically to threshold 0.
        let off = Cluster::new(base_params(ConsistencyMode::LeaseGuard, 7)).run();
        let mut p = base_params(ConsistencyMode::LeaseGuard, 7);
        p.snapshot_threshold = u64::MAX;
        let armed = Cluster::new(p).run();
        assert_eq!(off.events_processed, armed.events_processed);
        assert_eq!(off.t0, armed.t0);
        assert_eq!(off.history.entries.len(), armed.history.entries.len());
        for (ea, eb) in off.history.entries.iter().zip(armed.history.entries.iter()) {
            assert_eq!(format!("{ea:?}"), format!("{eb:?}"));
        }
        assert!(armed.node_stats.iter().all(|s| s.snapshots_taken == 0));
    }

    #[test]
    fn compaction_with_follower_outage_installs_snapshot_and_stays_linearizable() {
        // Aggressive compaction + a follower down long enough that the
        // leader's log base moves past it: catch-up must go through the
        // chunked InstallSnapshot path, and the history must still
        // linearize.
        let mut p = base_params(ConsistencyMode::LeaseGuard, 31);
        p.duration_us = 3_000_000;
        p.interarrival_us = 300.0;
        p.snapshot_threshold = 20;
        let sched = NemesisSchedule::new()
            .at(600_000, Fault::CrashFollower { restart_after_us: Some(800_000) });
        let rep = Cluster::new(p).with_nemesis(sched).run();
        linearizability::assert_linearizable(&rep.history);
        let taken: u64 = rep.node_stats.iter().map(|s| s.snapshots_taken).sum();
        let installed: u64 = rep.node_stats.iter().map(|s| s.snapshots_installed).sum();
        assert!(taken > 0, "threshold 20 must compact under this write load");
        assert!(installed > 0, "restarted follower must catch up via InstallSnapshot");
    }

    #[test]
    fn crash_failover_quorum_linearizable() {
        let mut p = base_params(ConsistencyMode::Quorum, 13);
        p.duration_us = 2_500_000;
        p.crash_leader_at_us = 500_000;
        let rep = Cluster::new(p).run();
        linearizability::assert_linearizable(&rep.history);
    }
}
