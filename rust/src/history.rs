//! Client-operation histories (paper §6.2's `ClientLogEntry`).
//!
//! The simulator and the real client both record one [`HistoryEntry`]
//! per operation, plus the *apply log*: the true time each Put took
//! effect on the replica set (first application anywhere — i.e. on the
//! committing leader). Omniscient execution timestamps are what let the
//! checker avoid the NP-complete general case (§6.2).

use std::collections::BTreeMap;

use crate::raft::types::{FailReason, OpId};
use crate::Micros;

/// What the client asked for and what it got back.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// `write(key, value)` — append `value` to the key's list.
    Append { value: u64 },
    /// `read(key)` — `result` is the observed list (successful reads).
    Read { result: Vec<u64> },
}

/// One operation as the client saw it (§6.2 field-for-field, with
/// `execution_ts` coming from the apply log rather than being trusted).
#[derive(Debug, Clone)]
pub struct HistoryEntry {
    pub op: OpId,
    pub key: u32,
    pub kind: OpKind,
    /// True time the client invoked the operation.
    pub start_ts: Micros,
    /// True time the client received the reply.
    pub end_ts: Micros,
    /// For successful reads: true time the read executed on a server.
    pub execution_ts: Option<Micros>,
    pub success: bool,
    /// Failure classification (None when success).
    pub fail: Option<FailReason>,
}

/// The apply log: for each (key, value), when and in what order the Put
/// first took effect anywhere in the replica set.
#[derive(Debug, Clone, Default)]
pub struct ApplyLog {
    /// (key, value) -> (true time, global apply sequence number).
    /// BTreeMap (lint R2): this map is iterated to build the checker's
    /// ground-truth sequences, so its order must be deterministic.
    first_applied: BTreeMap<(u32, u64), (Micros, u64)>,
    seq: u64,
}

impl ApplyLog {
    pub fn new() -> Self {
        ApplyLog::default()
    }

    /// Record an application event (idempotent: only the first sighting
    /// counts — that is the committing leader's apply).
    pub fn record(&mut self, key: u32, value: u64, at: Micros) {
        self.seq += 1;
        self.first_applied.entry((key, value)).or_insert((at, self.seq));
    }

    pub fn applied_at(&self, key: u32, value: u64) -> Option<Micros> {
        self.first_applied.get(&(key, value)).map(|&(t, _)| t)
    }

    /// Per-key apply sequence, ordered by (time, apply order) — the
    /// ground-truth list every linearizable read must observe a prefix
    /// of. O(total applies); use [`Self::sequences`] when more than one
    /// key is needed.
    pub fn sequence_for(&self, key: u32) -> Vec<(Micros, u64, u64)> {
        let mut v: Vec<(Micros, u64, u64)> = self
            .first_applied
            .iter()
            .filter(|((k, _), _)| *k == key)
            .map(|(&(_, value), &(t, s))| (t, s, value))
            .collect();
        v.sort_unstable();
        v
    }

    /// All per-key apply sequences in one pass (the checker's input —
    /// per-key rescanning was the top profile entry in large runs, see
    /// EXPERIMENTS.md §Perf iteration 6).
    pub fn sequences(&self) -> BTreeMap<u32, Vec<(Micros, u64, u64)>> {
        let mut out: BTreeMap<u32, Vec<(Micros, u64, u64)>> = BTreeMap::new();
        for (&(key, value), &(t, s)) in &self.first_applied {
            out.entry(key).or_default().push((t, s, value));
        }
        for v in out.values_mut() {
            v.sort_unstable();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.first_applied.len()
    }

    pub fn is_empty(&self) -> bool {
        self.first_applied.is_empty()
    }

    /// Split by keyspace partition: apply record for `key` goes to bucket
    /// `part(key)`. Global apply sequence numbers are preserved, so each
    /// bucket's per-key ordering is exactly what it was in the whole log.
    fn partition(&self, buckets: usize, part: impl Fn(u32) -> usize) -> Vec<ApplyLog> {
        let mut out = vec![ApplyLog::new(); buckets];
        for (&(key, value), &(t, s)) in &self.first_applied {
            let b = &mut out[part(key)];
            b.first_applied.insert((key, value), (t, s));
            b.seq = b.seq.max(s);
        }
        out
    }
}

/// A whole run: per-op entries + the apply log.
#[derive(Debug, Clone, Default)]
pub struct History {
    pub entries: Vec<HistoryEntry>,
    pub applies: ApplyLog,
}

impl History {
    pub fn new() -> Self {
        History::default()
    }

    /// Split a multi-group run's history into one history per shard,
    /// routing every entry and apply record by `map.group_of(key)`.
    /// Operations never span keys, so the split is exact: each per-shard
    /// history is a complete, self-contained history of that group and
    /// can be linearizability-checked independently.
    pub fn partition_by_shard(&self, map: &crate::shard::ShardMap) -> Vec<History> {
        let groups = map.groups();
        let applies = self.applies.partition(groups, |k| map.group_of(k) as usize);
        let mut out: Vec<History> = applies
            .into_iter()
            .map(|a| History { entries: Vec::new(), applies: a })
            .collect();
        for e in &self.entries {
            out[map.group_of(e.key) as usize].entries.push(e.clone());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_apply_wins() {
        let mut a = ApplyLog::new();
        a.record(1, 10, 100);
        a.record(1, 10, 200); // follower applying later: ignored
        assert_eq!(a.applied_at(1, 10), Some(100));
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn partition_by_shard_is_exact_and_total() {
        let map = crate::shard::ShardMap::new(4);
        let mut h = History::new();
        for key in 0..32u32 {
            h.applies.record(key, u64::from(key) * 10, 100);
            h.entries.push(HistoryEntry {
                op: u64::from(key) + 1,
                key,
                kind: OpKind::Append { value: u64::from(key) * 10 },
                start_ts: 0,
                end_ts: 50,
                execution_ts: None,
                success: true,
                fail: None,
            });
        }
        let shards = h.partition_by_shard(&map);
        assert_eq!(shards.len(), 4);
        let total_entries: usize = shards.iter().map(|s| s.entries.len()).sum();
        let total_applies: usize = shards.iter().map(|s| s.applies.len()).sum();
        assert_eq!(total_entries, h.entries.len());
        assert_eq!(total_applies, h.applies.len());
        for (g, s) in shards.iter().enumerate() {
            for e in &s.entries {
                assert_eq!(map.group_of(e.key) as usize, g);
                // The entry's apply record travelled with it.
                if let OpKind::Append { value } = e.kind {
                    assert!(s.applies.applied_at(e.key, value).is_some());
                }
            }
        }
    }

    #[test]
    fn sequence_ordered_by_time_then_order() {
        let mut a = ApplyLog::new();
        a.record(1, 10, 100);
        a.record(1, 11, 100); // same instant, applied after 10
        a.record(1, 12, 50);
        a.record(2, 99, 10); // other key
        let seq: Vec<u64> = a.sequence_for(1).into_iter().map(|(_, _, v)| v).collect();
        assert_eq!(seq, vec![12, 10, 11]);
    }
}
