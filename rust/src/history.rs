//! Client-operation histories (paper §6.2's `ClientLogEntry`).
//!
//! The simulator and the real client both record one [`HistoryEntry`]
//! per operation, plus the *apply log*: the true time each Put took
//! effect on the replica set (first application anywhere — i.e. on the
//! committing leader). Omniscient execution timestamps are what let the
//! checker avoid the NP-complete general case (§6.2).

use std::collections::HashMap;

use crate::raft::types::{FailReason, OpId};
use crate::Micros;

/// What the client asked for and what it got back.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// `write(key, value)` — append `value` to the key's list.
    Append { value: u64 },
    /// `read(key)` — `result` is the observed list (successful reads).
    Read { result: Vec<u64> },
}

/// One operation as the client saw it (§6.2 field-for-field, with
/// `execution_ts` coming from the apply log rather than being trusted).
#[derive(Debug, Clone)]
pub struct HistoryEntry {
    pub op: OpId,
    pub key: u32,
    pub kind: OpKind,
    /// True time the client invoked the operation.
    pub start_ts: Micros,
    /// True time the client received the reply.
    pub end_ts: Micros,
    /// For successful reads: true time the read executed on a server.
    pub execution_ts: Option<Micros>,
    pub success: bool,
    /// Failure classification (None when success).
    pub fail: Option<FailReason>,
}

/// The apply log: for each (key, value), when and in what order the Put
/// first took effect anywhere in the replica set.
#[derive(Debug, Clone, Default)]
pub struct ApplyLog {
    /// (key, value) -> (true time, global apply sequence number).
    first_applied: HashMap<(u32, u64), (Micros, u64)>,
    seq: u64,
}

impl ApplyLog {
    pub fn new() -> Self {
        ApplyLog::default()
    }

    /// Record an application event (idempotent: only the first sighting
    /// counts — that is the committing leader's apply).
    pub fn record(&mut self, key: u32, value: u64, at: Micros) {
        self.seq += 1;
        self.first_applied.entry((key, value)).or_insert((at, self.seq));
    }

    pub fn applied_at(&self, key: u32, value: u64) -> Option<Micros> {
        self.first_applied.get(&(key, value)).map(|&(t, _)| t)
    }

    /// Per-key apply sequence, ordered by (time, apply order) — the
    /// ground-truth list every linearizable read must observe a prefix
    /// of. O(total applies); use [`Self::sequences`] when more than one
    /// key is needed.
    pub fn sequence_for(&self, key: u32) -> Vec<(Micros, u64, u64)> {
        let mut v: Vec<(Micros, u64, u64)> = self
            .first_applied
            .iter()
            .filter(|((k, _), _)| *k == key)
            .map(|(&(_, value), &(t, s))| (t, s, value))
            .collect();
        v.sort_unstable();
        v
    }

    /// All per-key apply sequences in one pass (the checker's input —
    /// per-key rescanning was the top profile entry in large runs, see
    /// EXPERIMENTS.md §Perf iteration 6).
    pub fn sequences(&self) -> HashMap<u32, Vec<(Micros, u64, u64)>> {
        let mut out: HashMap<u32, Vec<(Micros, u64, u64)>> = HashMap::new();
        for (&(key, value), &(t, s)) in &self.first_applied {
            out.entry(key).or_default().push((t, s, value));
        }
        for v in out.values_mut() {
            v.sort_unstable();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.first_applied.len()
    }

    pub fn is_empty(&self) -> bool {
        self.first_applied.is_empty()
    }
}

/// A whole run: per-op entries + the apply log.
#[derive(Debug, Clone, Default)]
pub struct History {
    pub entries: Vec<HistoryEntry>,
    pub applies: ApplyLog,
}

impl History {
    pub fn new() -> Self {
        History::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_apply_wins() {
        let mut a = ApplyLog::new();
        a.record(1, 10, 100);
        a.record(1, 10, 200); // follower applying later: ignored
        assert_eq!(a.applied_at(1, 10), Some(100));
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn sequence_ordered_by_time_then_order() {
        let mut a = ApplyLog::new();
        a.record(1, 10, 100);
        a.record(1, 11, 100); // same instant, applied after 10
        a.record(1, 12, 50);
        a.record(2, 99, 10); // other key
        let seq: Vec<u64> = a.sequence_for(1).into_iter().map(|(_, _, v)| v).collect();
        assert_eq!(seq, vec![12, 10, 11]);
    }
}
