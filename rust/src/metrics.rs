//! Metrics: latency histograms, time-bucketed throughput series, and
//! availability counters — everything the paper's figures plot.
//!
//! [`Histogram`] is log-bucketed (HdrHistogram-style, ~2% relative error)
//! so recording is O(1) with no allocation on the hot path; percentile
//! queries interpolate inside the bucket. [`TimeSeries`] buckets
//! successes/failures per interval for the Fig 7/9 availability
//! timelines.

use crate::Micros;

/// Log-bucketed latency histogram over µs values.
///
/// Layout: 40 "decades" (powers of two) of 32 linear sub-buckets each —
/// `BUCKETS` = 1280 counts total. Decade 0 covers 0..32µs exactly;
/// decade d ≥ 1 covers `[32·2^(d-1), 32·2^d)` µs, so the last in-range
/// bucket starts at `(63/32)·2^43` µs and the covered range is
/// 1µs .. ~2^44µs (≈ 200 days) with ≤ ~3% relative error — plenty for
/// p50/p90/p99 over operation latencies. Values past the top bucket
/// clamp into it (`record` never panics, never drops a sample); see
/// `covered_range_and_overflow_clamp`.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: Micros,
    max: Micros,
}

const SUB_BITS: u32 = 5; // 32 sub-buckets per power of two
pub(crate) const SUB: usize = 1 << SUB_BITS;
/// Total bucket count: 40 decades × `SUB` sub-buckets. Shared with the
/// concurrent variant in [`crate::obs::registry::ConcurrentHistogram`]
/// so snapshots merge bucket-for-bucket.
pub(crate) const BUCKETS: usize = SUB * 40;

pub(crate) fn bucket_index(v: Micros) -> usize {
    let v = v.max(0) as u64;
    if v < SUB as u64 {
        return v as usize;
    }
    let top = 63 - v.leading_zeros(); // position of MSB, >= SUB_BITS
    let shift = top - SUB_BITS;
    let sub = ((v >> shift) as usize) & (SUB - 1);
    ((top - SUB_BITS + 1) as usize) * SUB + sub
}

pub(crate) fn bucket_low(idx: usize) -> u64 {
    let decade = idx / SUB;
    let sub = idx % SUB;
    if decade == 0 {
        return sub as u64;
    }
    let shift = (decade - 1) as u32;
    ((SUB + sub) as u64) << shift
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { counts: vec![0; BUCKETS], total: 0, sum: 0, min: Micros::MAX, max: 0 }
    }

    /// Rebuild from raw parts — used by
    /// [`crate::obs::registry::ConcurrentHistogram::snapshot`] to turn
    /// atomically-recorded counts back into a queryable histogram.
    /// `counts.len()` must be `BUCKETS`.
    pub(crate) fn from_parts(counts: Vec<u64>, total: u64, sum: u128, min: Micros, max: Micros) -> Self {
        debug_assert_eq!(counts.len(), BUCKETS);
        Histogram { counts, total, sum, min: if total == 0 { Micros::MAX } else { min }, max }
    }

    #[inline]
    pub fn record(&mut self, v: Micros) {
        let idx = bucket_index(v).min(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v.max(0) as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    pub fn min(&self) -> Micros {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> Micros {
        self.max
    }

    /// Value at quantile q ∈ [0,1] (lower-bound interpolation).
    pub fn quantile(&self, q: f64) -> Micros {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            seen += c;
            if seen >= rank {
                return (bucket_low(i) as Micros).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> Micros {
        self.quantile(0.50)
    }
    pub fn p90(&self) -> Micros {
        self.quantile(0.90)
    }
    pub fn p99(&self) -> Micros {
        self.quantile(0.99)
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// One operation-class bucket in an availability timeline.
#[derive(Debug, Clone, Copy, Default)]
pub struct Bucket {
    pub ok: u64,
    pub failed: u64,
}

/// Time-bucketed success/failure counts for reads and writes — the data
/// behind the paper's availability charts (Figs 7 and 9).
#[derive(Debug, Clone)]
pub struct TimeSeries {
    pub bucket_us: Micros,
    pub reads: Vec<Bucket>,
    pub writes: Vec<Bucket>,
}

impl TimeSeries {
    pub fn new(bucket_us: Micros, duration_us: Micros) -> Self {
        let n = ((duration_us / bucket_us) + 2) as usize;
        TimeSeries { bucket_us, reads: vec![Bucket::default(); n], writes: vec![Bucket::default(); n] }
    }

    fn slot(&mut self, is_read: bool, at: Micros) -> &mut Bucket {
        let i = (at / self.bucket_us).max(0) as usize;
        let v = if is_read { &mut self.reads } else { &mut self.writes };
        if i >= v.len() {
            v.resize(i + 1, Bucket::default());
        }
        &mut v[i]
    }

    pub fn record(&mut self, is_read: bool, at: Micros, ok: bool) {
        let b = self.slot(is_read, at);
        if ok {
            b.ok += 1;
        } else {
            b.failed += 1;
        }
    }

    /// Successful ops/sec in each bucket.
    pub fn ok_rate_per_sec(&self, is_read: bool) -> Vec<f64> {
        let v = if is_read { &self.reads } else { &self.writes };
        let scale = 1_000_000.0 / self.bucket_us as f64;
        v.iter().map(|b| b.ok as f64 * scale).collect()
    }

    /// Totals over a time window [from, to) — used for headline numbers
    /// like "9,930 of 10,000 reads succeed while awaiting a lease".
    pub fn window_totals(&self, is_read: bool, from: Micros, to: Micros) -> Bucket {
        let v = if is_read { &self.reads } else { &self.writes };
        let lo = (from / self.bucket_us).max(0) as usize;
        let hi = (to.saturating_add(self.bucket_us - 1) / self.bucket_us) as usize;
        let mut out = Bucket::default();
        for b in v.iter().take(hi.min(v.len())).skip(lo) {
            out.ok += b.ok;
            out.failed += b.failed;
        }
        out
    }
}

/// Aggregate counters for one experiment run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub reads_ok: u64,
    pub reads_failed: u64,
    pub writes_ok: u64,
    pub writes_failed: u64,
    pub read_latency: Option<Histogram>,
    pub write_latency: Option<Histogram>,
    pub elections: u64,
    pub noop_lease_renewals: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_low_is_monotone_and_consistent() {
        let mut prev = 0;
        for i in 0..SUB * 20 {
            let lo = bucket_low(i);
            assert!(lo >= prev, "i={i}");
            prev = lo;
            // Indexing the low value must land in the same bucket.
            assert_eq!(bucket_index(lo as Micros), i, "i={i} lo={lo}");
        }
    }

    #[test]
    fn quantiles_roughly_correct() {
        let mut h = Histogram::new();
        for v in 1..=10_000 {
            h.record(v);
        }
        assert_eq!(h.count(), 10_000);
        let p50 = h.p50();
        let p90 = h.p90();
        let p99 = h.p99();
        assert!((p50 as f64 - 5000.0).abs() / 5000.0 < 0.05, "p50 {p50}");
        assert!((p90 as f64 - 9000.0).abs() / 9000.0 < 0.05, "p90 {p90}");
        assert!((p99 as f64 - 9900.0).abs() / 9900.0 < 0.05, "p99 {p99}");
        assert!((h.mean() - 5000.5).abs() < 1.0);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 10_000);
    }

    #[test]
    fn quantile_single_value() {
        let mut h = Histogram::new();
        h.record(155);
        assert_eq!(h.p50(), 155);
        assert_eq!(h.p99(), 155);
    }

    #[test]
    fn covered_range_and_overflow_clamp() {
        // Pin the actual layout the module doc promises: 40 decades of
        // 32 sub-buckets. The last in-range bucket is index BUCKETS-1,
        // whose low bound is (SUB + 31) << 38 = (63/32)·2^43 µs, so the
        // covered range tops out around 2^44 µs ≈ 200 days (NOT the
        // "64 decades / ~5 days" an older doc claimed).
        assert_eq!(BUCKETS, 1280);
        let top_low = bucket_low(BUCKETS - 1);
        assert_eq!(top_low, ((SUB as u64) + 31) << 38);
        assert_eq!(bucket_index(top_low as Micros), BUCKETS - 1);
        // ~200 days in µs sits inside the covered range...
        let days200: Micros = 200 * 24 * 3600 * 1_000_000;
        assert!(bucket_index(days200) < BUCKETS, "200 days must be in range");
        // ...while anything larger clamps into the top bucket instead of
        // indexing out of bounds: record() must count it there.
        assert!(bucket_index(Micros::MAX) >= BUCKETS, "i64::MAX naturally overflows the layout");
        let mut h = Histogram::new();
        h.record(Micros::MAX);
        h.record(top_low as Micros);
        assert_eq!(h.counts[BUCKETS - 1], 2, "overflow must clamp into the last bucket");
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), Micros::MAX);
        // Quantiles over a clamped histogram stay within [min, max].
        assert!(h.p99() >= top_low as Micros);
    }

    #[test]
    fn extreme_values_dont_panic() {
        let mut h = Histogram::new();
        h.record(0);
        h.record(Micros::MAX / 2);
        assert!(h.p99() > 0);
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for v in 0..100 {
            a.record(v);
            b.record(v + 1000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert!(a.p99() >= 1000);
    }

    #[test]
    fn timeseries_bucketing() {
        let mut ts = TimeSeries::new(1000, 10_000);
        ts.record(true, 500, true);
        ts.record(true, 999, true);
        ts.record(true, 1000, false);
        ts.record(false, 2500, true);
        assert_eq!(ts.reads[0].ok, 2);
        assert_eq!(ts.reads[1].failed, 1);
        assert_eq!(ts.writes[2].ok, 1);
        let rates = ts.ok_rate_per_sec(true);
        assert!((rates[0] - 2000.0).abs() < 1e-9);
        let w = ts.window_totals(true, 0, 2000);
        assert_eq!((w.ok, w.failed), (2, 1));
    }

    #[test]
    fn timeseries_grows_beyond_duration() {
        let mut ts = TimeSeries::new(1000, 2_000);
        ts.record(false, 50_000, true); // past the declared duration
        assert_eq!(ts.writes[50].ok, 1);
    }
}
