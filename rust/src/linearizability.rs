//! Omniscient linearizability checker (paper §6.2).
//!
//! General linearizability checking is NP-complete [18]; the paper's
//! simulator (and ours) sidesteps it with omniscience: the true time of
//! every execution event is known. Append-only lists make the check
//! exact and linear:
//!
//! * the replica set applies Puts in one global order per key (State
//!   Machine Safety), captured in the [`crate::history::ApplyLog`];
//! * therefore every linearizable read must observe a *prefix* of that
//!   per-key sequence — at least everything applied strictly before the
//!   read executed, at most everything applied up to and including its
//!   instant (ties at the same microsecond may serialize either way,
//!   which subsumes the paper's same-timestamp permutation search);
//! * a client-failed write is ambiguous (§6.2): if it was ever applied
//!   it is treated as a write at its apply time (which is ≥ its
//!   invocation), otherwise it must never be observed.
//!
//! Violations detectable: stale reads (deposed leader), future reads
//! (optimistic limbo execution), lost acknowledged writes, reads
//! observing never-applied values, and execution points outside the
//! invocation window.

use std::collections::BTreeMap;

use crate::history::{History, OpKind};
use crate::Micros;

/// One detected violation, with enough context to debug the protocol.
#[derive(Debug, Clone)]
pub struct Violation {
    pub op: u64,
    pub key: u32,
    pub detail: String,
}

/// Check a run. Returns all violations (empty = linearizable).
pub fn check(history: &History) -> Vec<Violation> {
    let mut violations = Vec::new();

    // Per-key ground-truth apply sequences, built in one pass.
    let mut seqs: BTreeMap<u32, Vec<(Micros, u64, u64)>> = history.applies.sequences();
    for e in &history.entries {
        seqs.entry(e.key).or_default();
    }

    // 1. Acknowledged writes must have been applied, within the window.
    for e in &history.entries {
        if let OpKind::Append { value } = e.kind {
            match history.applies.applied_at(e.key, value) {
                Some(at) => {
                    if e.success && !(e.start_ts <= at && at <= e.end_ts) {
                        violations.push(Violation {
                            op: e.op,
                            key: e.key,
                            detail: format!(
                                "acknowledged write applied at {at} outside [{}, {}]",
                                e.start_ts, e.end_ts
                            ),
                        });
                    }
                    if at < e.start_ts {
                        violations.push(Violation {
                            op: e.op,
                            key: e.key,
                            detail: format!(
                                "write applied at {at} before invocation {}",
                                e.start_ts
                            ),
                        });
                    }
                }
                None if e.success => violations.push(Violation {
                    op: e.op,
                    key: e.key,
                    detail: "acknowledged write never applied (lost update)".into(),
                }),
                None => {} // failed and never applied: fine
            }
        }
    }

    // 2. Every successful read observes a valid prefix.
    for e in &history.entries {
        let OpKind::Read { result } = &e.kind else { continue };
        if !e.success {
            continue;
        }
        let Some(exec) = e.execution_ts else {
            violations.push(Violation {
                op: e.op,
                key: e.key,
                detail: "successful read lacks execution timestamp".into(),
            });
            continue;
        };
        if !(e.start_ts <= exec && exec <= e.end_ts) {
            violations.push(Violation {
                op: e.op,
                key: e.key,
                detail: format!("read executed at {exec} outside [{}, {}]", e.start_ts, e.end_ts),
            });
        }
        let seq = &seqs[&e.key];
        // Prefix bounds: everything applied before exec must be seen;
        // same-instant applies may or may not be.
        let must = seq.partition_point(|&(t, _, _)| t < exec);
        let may = seq.partition_point(|&(t, _, _)| t <= exec);
        if result.len() < must || result.len() > may {
            violations.push(Violation {
                op: e.op,
                key: e.key,
                detail: format!(
                    "read at {exec} observed {} values, expected between {must} and {may} \
                     (stale or future read)",
                    result.len()
                ),
            });
            continue;
        }
        for (i, (&got, &(_, _, want))) in result.iter().zip(seq.iter()).enumerate() {
            if got != want {
                violations.push(Violation {
                    op: e.op,
                    key: e.key,
                    detail: format!(
                        "read observed value {got} at position {i}, apply order says {want}"
                    ),
                });
                break;
            }
        }
    }

    violations
}

/// Convenience: panic with a report if the history is not linearizable
/// (used by examples and integration tests).
pub fn assert_linearizable(history: &History) {
    let v = check(history);
    if !v.is_empty() {
        let mut msg = format!("{} linearizability violation(s):\n", v.len());
        for x in v.iter().take(10) {
            msg.push_str(&format!("  op {} key {}: {}\n", x.op, x.key, x.detail));
        }
        panic!("{msg}");
    }
}

/// Sharded check: split the history per shard under `map` and check each
/// shard independently, returning `(group, violations)` per shard.
///
/// Because the checker is per-key anyway (a key's apply sequence is its
/// own serial order), partitioning cannot hide a violation — this is the
/// same verdict as [`check`] on the whole history, but attributes each
/// violation to the Raft group that served it.
pub fn check_sharded(
    history: &History,
    map: &crate::shard::ShardMap,
) -> Vec<(crate::shard::GroupId, Vec<Violation>)> {
    history
        .partition_by_shard(map)
        .iter()
        .enumerate()
        .map(|(g, h)| (g as crate::shard::GroupId, check(h)))
        .collect()
}

/// Panic with a per-shard report if any shard's history is not
/// linearizable.
pub fn assert_linearizable_sharded(history: &History, map: &crate::shard::ShardMap) {
    let mut msg = String::new();
    let mut total = 0;
    for (g, v) in check_sharded(history, map) {
        total += v.len();
        for x in v.iter().take(10) {
            msg.push_str(&format!("  group {g} op {} key {}: {}\n", x.op, x.key, x.detail));
        }
    }
    if total > 0 {
        panic!("{total} linearizability violation(s) across shards:\n{msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::history::{ApplyLog, HistoryEntry};

    fn write(op: u64, key: u32, value: u64, start: Micros, end: Micros, success: bool) -> HistoryEntry {
        HistoryEntry {
            op,
            key,
            kind: OpKind::Append { value },
            start_ts: start,
            end_ts: end,
            execution_ts: None,
            success,
            fail: None,
        }
    }

    fn read(op: u64, key: u32, result: Vec<u64>, start: Micros, exec: Micros, end: Micros) -> HistoryEntry {
        HistoryEntry {
            op,
            key,
            kind: OpKind::Read { result },
            start_ts: start,
            end_ts: end,
            execution_ts: Some(exec),
            success: true,
            fail: None,
        }
    }

    fn history(entries: Vec<HistoryEntry>, applies: Vec<(u32, u64, Micros)>) -> History {
        let mut a = ApplyLog::new();
        for (k, v, t) in applies {
            a.record(k, v, t);
        }
        History { entries, applies: a }
    }

    #[test]
    fn valid_history_passes() {
        let h = history(
            vec![
                write(1, 1, 10, 0, 120, true),
                read(2, 1, vec![10], 150, 160, 170),
                write(3, 1, 11, 200, 320, true),
                read(4, 1, vec![10, 11], 400, 410, 420),
            ],
            vec![(1, 10, 100), (1, 11, 300)],
        );
        assert!(check(&h).is_empty());
    }

    #[test]
    fn stale_read_detected() {
        // Read at 400 misses value applied at 300 — a deposed leader
        // serving after the new leader committed (the paper's §1 bug).
        let h = history(
            vec![
                write(1, 1, 10, 0, 120, true),
                write(3, 1, 11, 200, 320, true),
                read(4, 1, vec![10], 390, 400, 420),
            ],
            vec![(1, 10, 100), (1, 11, 300)],
        );
        let v = check(&h);
        assert_eq!(v.len(), 1);
        assert!(v[0].detail.contains("stale or future"), "{v:?}");
    }

    #[test]
    fn future_read_detected() {
        // Read observes a value applied after its execution point
        // (optimistic limbo execution, §3.3's second hazard).
        let h = history(
            vec![write(1, 1, 10, 0, 600, true), read(2, 1, vec![10], 100, 200, 250)],
            vec![(1, 10, 500)],
        );
        let v = check(&h);
        assert!(!v.is_empty());
    }

    #[test]
    fn lost_acknowledged_write_detected() {
        let h = history(vec![write(1, 1, 10, 0, 100, true)], vec![]);
        let v = check(&h);
        assert_eq!(v.len(), 1);
        assert!(v[0].detail.contains("never applied"));
    }

    #[test]
    fn ambiguous_failed_write_both_ways() {
        // Failed write never applied: ok.
        let h = history(vec![write(1, 1, 10, 0, 100, false)], vec![]);
        assert!(check(&h).is_empty());
        // Failed write later applied: reads after it must see it.
        let h = history(
            vec![
                write(1, 1, 10, 0, 100, false),
                read(2, 1, vec![10], 400, 450, 500),
            ],
            vec![(1, 10, 300)],
        );
        assert!(check(&h).is_empty());
        // ...and a read that misses it is a violation.
        let h = history(
            vec![
                write(1, 1, 10, 0, 100, false),
                read(2, 1, vec![], 400, 450, 500),
            ],
            vec![(1, 10, 300)],
        );
        assert!(!check(&h).is_empty());
    }

    #[test]
    fn same_instant_tie_accepts_either() {
        let h = |observed: Vec<u64>| {
            history(
                vec![write(1, 1, 10, 0, 300, true), read(2, 1, observed, 100, 200, 250)],
                vec![(1, 10, 200)], // applied exactly at read execution
            )
        };
        assert!(check(&h(vec![])).is_empty());
        assert!(check(&h(vec![10])).is_empty());
    }

    #[test]
    fn wrong_order_detected() {
        let h = history(
            vec![read(3, 1, vec![11, 10], 400, 450, 500)],
            vec![(1, 10, 100), (1, 11, 200)],
        );
        let v = check(&h);
        assert!(!v.is_empty());
        assert!(v[0].detail.contains("apply order"));
    }

    #[test]
    fn read_exec_outside_window_detected() {
        let h = history(vec![read(1, 1, vec![], 100, 700, 200)], vec![]);
        let v = check(&h);
        assert!(v.iter().any(|x| x.detail.contains("outside")));
    }

    #[test]
    fn write_applied_before_invocation_detected() {
        let h = history(vec![write(1, 1, 10, 500, 600, true)], vec![(1, 10, 400)]);
        assert!(!check(&h).is_empty());
    }

    #[test]
    fn keys_are_independent() {
        let h = history(
            vec![
                write(1, 1, 10, 0, 100, true),
                read(2, 2, vec![], 200, 210, 220), // other key, empty: fine
            ],
            vec![(1, 10, 50)],
        );
        assert!(check(&h).is_empty());
    }
}
