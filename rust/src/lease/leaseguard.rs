//! LeaseGuard: the log is the lease (paper §3).
//!
//! Per-leadership state, created when a node wins an election and
//! dropped when it steps down:
//!
//! * **Commit gate** (Fig 2 lines 34-38): the leader of term *t* must
//!   not advance its commitIndex while any entry with term < *t* in its
//!   log is possibly < Δ old — the deposed leader may still hold a lease
//!   and be serving reads. We cache the maximum `written_at.latest` over
//!   prior-term entries at election ([`crate::raft::Log::
//!   max_prior_term_latest`], the paper's `lastEntryInPreviousTermIndex`
//!   constant-time optimization), so the per-commit check is O(1).
//! * **Limbo region** (§3.3): entries in `(commitIndex_at_election,
//!   last_index_at_election]` whose commitment status is unknown to the
//!   new leader. Inherited-lease reads are admitted only for keys
//!   untouched by the region; it disappears on the first own-term
//!   commit.

use crate::clock::TimeInterval;
use crate::raft::log::Log;
use crate::raft::types::{Index, Term};
use crate::Micros;

/// Verdict of the local-read gate (Fig 2 ClientRead lines 18-25).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadGate {
    /// Lease valid and newest committed entry is in the leader's own
    /// term: serve any key.
    Serve,
    /// Lease valid but inherited from a prior term: serve only keys
    /// unaffected by the limbo region (§3.3).
    ServeUnlessLimbo,
    /// No valid lease (newest committed entry may be > Δ old, or nothing
    /// committed at all).
    NoLease,
}

/// Lease diagnostics used by metrics and the XLA admission engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LeaseStatus {
    /// Conservative age (max possible) of the newest committed entry, µs.
    pub commit_age_us: Micros,
    /// Newest committed entry's term equals the current term.
    pub own_term_commit: bool,
    /// Lease (inherited or own) currently valid.
    pub valid: bool,
}

#[derive(Debug, Clone)]
pub struct LeaseGuardState {
    /// Lease duration Δ, µs (cluster-wide constant).
    pub delta_us: Micros,
    /// max over prior-term entries of `written_at.latest`, fixed at
    /// election. `None` = no prior-term entries (fresh cluster).
    max_prior_latest: Option<Micros>,
    /// Last log index at election — upper bound of the limbo region.
    pub limbo_hi: Index,
    /// commitIndex at election — lower bound (exclusive).
    pub limbo_lo: Index,
    /// Set once the leader commits an entry in its own term.
    own_term_committed: bool,
}

impl LeaseGuardState {
    /// Build at election time from the new leader's log (paper §3.3 /
    /// Fig 3). A §5.1 planned handover — the outgoing leader's final
    /// act is committing an end-lease entry — opens the commit gate
    /// immediately: the prior leader has promised to serve no further
    /// reads, so there is no lease to wait out.
    pub fn at_election(log: &Log, term: Term, commit_index: Index, delta_us: Micros) -> Self {
        let relinquished = matches!(
            log.last_prior_term_entry(term),
            Some(e) if e.command == crate::kv::Command::EndLease
        );
        LeaseGuardState {
            delta_us,
            max_prior_latest: if relinquished { None } else { log.max_prior_term_latest(term) },
            limbo_hi: log.last_index(),
            limbo_lo: commit_index,
            own_term_committed: false,
        }
    }

    /// Fig 2 lines 34-38: may the leader advance its commitIndex now?
    /// True when every prior-term entry is *definitely* more than Δ old.
    #[inline]
    pub fn commit_gate_open(&self, now: TimeInterval) -> bool {
        match self.max_prior_latest {
            None => true,
            Some(latest) => latest + self.delta_us < now.earliest,
        }
    }

    /// µs until the gate opens, from the local clock's perspective
    /// (for scheduling a re-check; 0 = open now).
    pub fn gate_retry_after(&self, now: TimeInterval) -> Micros {
        match self.max_prior_latest {
            None => 0,
            Some(latest) => (latest + self.delta_us + 1 - now.earliest).max(0),
        }
    }

    /// Record that an own-term entry committed: the lease is now the
    /// leader's own and the limbo region disappears (§3.3).
    pub fn on_own_term_commit(&mut self) {
        self.own_term_committed = true;
    }

    pub fn own_term_committed(&self) -> bool {
        self.own_term_committed
    }

    /// The limbo region `(limbo_lo, limbo_hi]`, empty once an own-term
    /// entry commits or if nothing was outstanding at election.
    pub fn limbo_range(&self) -> Option<(Index, Index)> {
        if self.own_term_committed || self.limbo_hi <= self.limbo_lo {
            None
        } else {
            Some((self.limbo_lo, self.limbo_hi))
        }
    }

    /// Number of entries in the limbo region (paper Fig 9: "a
    /// significant limbo region of 37 possibly-committed log entries").
    pub fn limbo_len(&self) -> u64 {
        self.limbo_range().map(|(lo, hi)| hi - lo).unwrap_or(0)
    }

    /// The read gate (Fig 2 ClientRead): `inherited` selects whether the
    /// §3.3 optimization is enabled (full LeaseGuard) or prior-term
    /// leases block all reads (LogLease / DeferCommit modes).
    pub fn read_gate(
        &self,
        log: &Log,
        current_term: Term,
        commit_index: Index,
        now: TimeInterval,
        inherited: bool,
    ) -> ReadGate {
        let Some(newest) = log.get(commit_index) else {
            return ReadGate::NoLease; // nothing ever committed
        };
        // Conservative validity: stop serving as soon as the entry
        // *might* be more than Δ old (§4.3).
        if newest.written_at.possibly_older_than(self.delta_us, now) {
            return ReadGate::NoLease;
        }
        if newest.term == current_term {
            ReadGate::Serve
        } else if inherited {
            ReadGate::ServeUnlessLimbo
        } else {
            ReadGate::NoLease
        }
    }

    /// Diagnostics + engine inputs.
    pub fn status(
        &self,
        log: &Log,
        current_term: Term,
        commit_index: Index,
        now: TimeInterval,
    ) -> LeaseStatus {
        match log.get(commit_index) {
            None => LeaseStatus { commit_age_us: Micros::MAX, own_term_commit: false, valid: false },
            Some(e) => {
                let age = e.written_at.max_age(now).max(0);
                LeaseStatus {
                    commit_age_us: age,
                    own_term_commit: e.term == current_term,
                    valid: age <= self.delta_us,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::Command;
    use crate::raft::log::Entry;

    fn entry(term: Term, t: Micros, key: Option<u32>) -> Entry {
        let command = match key {
            Some(k) => Command::Put { key: k, value: 1, payload_bytes: 0 },
            None => Command::Noop,
        };
        Entry { term, command, written_at: TimeInterval::exact(t) }
    }

    fn now(t: Micros) -> TimeInterval {
        TimeInterval::exact(t)
    }

    const DELTA: Micros = 1_000_000;

    fn log_two_terms() -> Log {
        // Term-1 leader wrote through t=500k; new leader elected term 2.
        let mut log = Log::new();
        log.append(entry(1, 100_000, Some(1)));
        log.append(entry(1, 400_000, Some(2)));
        log.append(entry(1, 500_000, Some(3)));
        log
    }

    #[test]
    fn commit_gate_blocks_until_prior_lease_expires() {
        let log = log_two_terms();
        let st = LeaseGuardState::at_election(&log, 2, 1, DELTA);
        // Prior-term newest latest = 500k; gate opens after 1.5s.
        assert!(!st.commit_gate_open(now(1_400_000)));
        assert!(!st.commit_gate_open(now(1_500_000))); // strict
        assert!(st.commit_gate_open(now(1_500_001)));
        assert_eq!(st.gate_retry_after(now(1_400_000)), 100_001);
        assert_eq!(st.gate_retry_after(now(2_000_000)), 0);
    }

    #[test]
    fn gate_open_with_no_prior_entries() {
        let log = Log::new();
        let st = LeaseGuardState::at_election(&log, 1, 0, DELTA);
        assert!(st.commit_gate_open(now(0)));
        assert_eq!(st.limbo_len(), 0);
    }

    #[test]
    fn limbo_region_bounds() {
        let log = log_two_terms();
        // commitIndex at election = 1 → limbo = (1, 3].
        let st = LeaseGuardState::at_election(&log, 2, 1, DELTA);
        assert_eq!(st.limbo_range(), Some((1, 3)));
        assert_eq!(st.limbo_len(), 2);
        let mut st2 = st.clone();
        st2.on_own_term_commit();
        assert_eq!(st2.limbo_range(), None);
    }

    #[test]
    fn read_gate_inherited_vs_not() {
        let log = log_two_terms();
        let st = LeaseGuardState::at_election(&log, 2, 3, DELTA);
        // Newest committed entry (idx 3, term 1, t=500k) still < Δ old.
        let t = now(900_000);
        assert_eq!(st.read_gate(&log, 2, 3, t, true), ReadGate::ServeUnlessLimbo);
        assert_eq!(st.read_gate(&log, 2, 3, t, false), ReadGate::NoLease);
        // After expiry, no lease either way.
        let late = now(1_600_001);
        assert_eq!(st.read_gate(&log, 2, 3, late, true), ReadGate::NoLease);
    }

    #[test]
    fn read_gate_own_term() {
        let mut log = log_two_terms();
        log.append(entry(2, 600_000, None));
        let mut st = LeaseGuardState::at_election(&log, 2, 3, DELTA);
        st.on_own_term_commit();
        // commitIndex advanced to 4 (own-term noop).
        assert_eq!(st.read_gate(&log, 2, 4, now(700_000), false), ReadGate::Serve);
    }

    #[test]
    fn read_gate_nothing_committed() {
        let log = log_two_terms();
        let st = LeaseGuardState::at_election(&log, 2, 0, DELTA);
        assert_eq!(st.read_gate(&log, 2, 0, now(0), true), ReadGate::NoLease);
    }

    #[test]
    fn read_gate_conservative_under_uncertainty() {
        let log = log_two_terms(); // newest committed written at 500k exact
        let st = LeaseGuardState::at_election(&log, 2, 3, DELTA);
        // now could be as late as 1_500_100 → entry might be > Δ old.
        let uncertain = TimeInterval::new(1_400_000, 1_500_100);
        assert_eq!(st.read_gate(&log, 2, 3, uncertain, true), ReadGate::NoLease);
        // With the same midpoint but tight bounds, the lease is valid.
        let tight = TimeInterval::new(1_449_000, 1_451_000);
        assert_eq!(st.read_gate(&log, 2, 3, tight, true), ReadGate::ServeUnlessLimbo);
    }

    #[test]
    fn status_reports_age() {
        let log = log_two_terms();
        let st = LeaseGuardState::at_election(&log, 2, 3, DELTA);
        let s = st.status(&log, 2, 3, now(800_000));
        assert_eq!(s.commit_age_us, 300_000);
        assert!(!s.own_term_commit);
        assert!(s.valid);
    }
}
