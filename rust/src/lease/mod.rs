//! Lease protocols (paper §3, §5, §7.1).
//!
//! * [`leaseguard`] — LeaseGuard proper: "the log is the lease". Tracks
//!   the deposed leader's lease deadline (commit gate, Fig 2 lines
//!   34-38) and the limbo region for inherited-lease reads (§3.3).
//! * [`ongaro`] — the comparison protocol from Ongaro's dissertation
//!   §6.4.1 as the paper reconstructs it (§7.1): heartbeat-acquired
//!   majority lease + follower vote withholding.
//!
//! Which protocol (if any) a node runs is selected by
//! [`crate::config::ConsistencyMode`]; the node consults these types at
//! exactly the three points the paper modifies Raft: read admission,
//! write acknowledgment, and commitIndex advancement.

pub mod leaseguard;
pub mod ongaro;

pub use leaseguard::{LeaseStatus, LeaseGuardState, ReadGate};
pub use ongaro::OngaroState;
