//! The "Ongaro lease" comparison protocol (paper §7.1), reconstructed
//! from Ongaro's dissertation §6.4.1: "the leader tracks the start time
//! of each AppendEntries message it sends to any follower. If the
//! follower replies, the leader updates a local variable s_i ... If a
//! majority of s_i values are less than ET old, then the leader has a
//! lease." The leader's own s_i is the current time.
//!
//! The protocol additionally relies on followers withholding votes while
//! they have heard from a leader recently; the node enforces that in
//! Ongaro mode only (LeaseGuard leaves elections untouched, §3).
//!
//! Times here are local scalar µs (the node's `now.earliest`): Ongaro
//! leases measure *durations on one node*, so interval uncertainty is
//! not involved (the paper notes Ongaro shortens the lease slightly for
//! clock drift and that their implementation, like ours, omits it).

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::collections::BTreeMap;

use crate::{Micros, NodeId};

#[derive(Debug, Clone)]
pub struct OngaroState {
    /// s_i: start time of the last *successful* AppendEntries per peer.
    s: Vec<Micros>,
    /// Outstanding sends per peer: seq -> send start time.
    sent: Vec<BTreeMap<u64, Micros>>,
    me: NodeId,
}

impl OngaroState {
    pub fn new(n: usize, me: NodeId) -> Self {
        OngaroState { s: vec![Micros::MIN; n], sent: vec![BTreeMap::new(); n], me }
    }

    /// Record that an AppendEntries round `seq` was sent to `peer` now.
    pub fn record_send(&mut self, peer: NodeId, seq: u64, now_us: Micros) {
        let m = &mut self.sent[peer];
        m.insert(seq, now_us);
        // Bound memory under loss: drop rounds no reply will ever matter
        // for (anything older than the 1024 most recent).
        if m.len() > 1024 {
            let cutoff = *m.keys().nth(m.len() - 1024).unwrap();
            *m = m.split_off(&cutoff);
        }
    }

    /// Record a successful reply from `peer` for round `seq`.
    pub fn record_ack(&mut self, peer: NodeId, seq: u64) {
        if let Some(sent_at) = self.sent[peer].remove(&seq) {
            self.s[peer] = self.s[peer].max(sent_at);
        }
        // Prune older outstanding rounds: a later ack implies liveness,
        // but NOT receipt of earlier sends — however their s_i would be
        // smaller than this one's, so they can never improve the lease.
        let keep = self.sent[peer].split_off(&(seq + 1));
        self.sent[peer] = keep;
    }

    /// Does the leader hold a lease at `now_us`? True iff a majority of
    /// s_i (counting itself as `now_us`) are younger than `window_us`.
    pub fn has_lease(&self, now_us: Micros, window_us: Micros) -> bool {
        let n = self.s.len();
        let majority = n / 2 + 1;
        let mut fresh = 0usize;
        for (i, &si) in self.s.iter().enumerate() {
            let effective = if i == self.me { now_us } else { si };
            if effective > Micros::MIN && now_us - effective < window_us {
                fresh += 1;
            }
        }
        fresh >= majority
    }

    /// µs until the current lease lapses with no further acks (metrics).
    pub fn remaining(&self, now_us: Micros, window_us: Micros) -> Micros {
        let n = self.s.len();
        let majority = n / 2 + 1;
        let mut ages: Vec<Micros> = (0..n)
            .map(|i| if i == self.me { now_us } else { self.s[i] })
            .collect();
        ages.sort_unstable_by(|a, b| b.cmp(a)); // newest first
        if majority > ages.len() {
            return 0;
        }
        let pivot = ages[majority - 1];
        if pivot == Micros::MIN {
            return 0;
        }
        (pivot + window_us - now_us).max(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const W: Micros = 1_000_000;

    #[test]
    fn no_lease_before_any_ack() {
        let o = OngaroState::new(3, 0);
        assert!(!o.has_lease(0, W));
    }

    #[test]
    fn majority_ack_grants_lease() {
        let mut o = OngaroState::new(3, 0);
        o.record_send(1, 7, 100);
        o.record_send(2, 7, 100);
        o.record_ack(1, 7);
        // self + peer1 = majority of 3.
        assert!(o.has_lease(500, W));
        // Lease measured from the SEND time, not the ack time.
        assert!(o.has_lease(100 + W - 1, W));
        assert!(!o.has_lease(100 + W, W));
    }

    #[test]
    fn ack_without_send_ignored() {
        let mut o = OngaroState::new(3, 0);
        o.record_ack(1, 99);
        assert!(!o.has_lease(0, W));
    }

    #[test]
    fn later_rounds_extend() {
        let mut o = OngaroState::new(3, 0);
        o.record_send(1, 1, 100);
        o.record_ack(1, 1);
        o.record_send(1, 2, 500_000);
        o.record_ack(1, 2);
        assert!(o.has_lease(500_000 + W - 1, W));
    }

    #[test]
    fn stale_ack_cannot_regress() {
        let mut o = OngaroState::new(3, 0);
        o.record_send(1, 1, 100);
        o.record_send(1, 2, 200);
        o.record_ack(1, 2);
        // Round 1's ack arrives late; s_1 stays at 200.
        o.record_ack(1, 1);
        assert!(!o.has_lease(200 + W, W));
        assert!(o.has_lease(200 + W - 1, W));
    }

    #[test]
    fn five_node_majority() {
        let mut o = OngaroState::new(5, 0);
        o.record_send(1, 1, 0);
        o.record_ack(1, 1);
        // self + 1 peer = 2 < 3: no lease.
        assert!(!o.has_lease(10, W));
        o.record_send(2, 2, 5);
        o.record_ack(2, 2);
        assert!(o.has_lease(10, W));
    }

    #[test]
    fn remaining_tracks_pivot() {
        let mut o = OngaroState::new(3, 0);
        o.record_send(1, 1, 100);
        o.record_ack(1, 1);
        assert_eq!(o.remaining(500, W), 100 + W - 500);
        assert_eq!(o.remaining(100 + W + 5, W), 0);
    }
}
