//! Simulated bounded-uncertainty clock (the simulator's model of AWS
//! TimeSync / TrueTime, §2.2). Each node owns one, seeded independently.
//!
//! Model: the node's local oscillator runs at a fixed rate `1 + drift`
//! relative to true time with a phase offset; the clock-bound daemon
//! reports an interval centred near the (noisy) local reading whose
//! half-width is the configured `max_error`. Correct mode *constructs*
//! the interval to contain the true time (as a correct daemon
//! guarantees); `broken` mode deliberately excludes it, reproducing the
//! §4.3 failure ("Inherited lease reads require correct clock bounds!")
//! which the linearizability checker must then catch.

use super::{Clock, TimeInterval};
use crate::prob::Rng;
use crate::Micros;

#[derive(Debug, Clone)]
pub struct SimClockConfig {
    /// Maximum clock-bound error (half-width), µs. Paper testbed: <50µs.
    pub max_error_us: Micros,
    /// Oscillator drift rate, e.g. 1e-5 = 10 ppm.
    pub drift: f64,
    /// If true, intervals deliberately exclude the true time (§4.3).
    pub broken: bool,
}

impl Default for SimClockConfig {
    fn default() -> Self {
        SimClockConfig { max_error_us: 50, drift: 1e-5, broken: false }
    }
}

/// A per-node simulated clock. Unlike [`super::real::RealClock`] it has
/// no time source of its own: the simulator passes the true virtual time
/// to [`SimClock::at`]. (`Clock::interval_now` is not implemented for
/// it; simulation code always knows `true_now`.)
#[derive(Debug, Clone)]
pub struct SimClock {
    cfg: SimClockConfig,
    offset_us: f64,
    rng: Rng,
    /// Monotonicity floor: intervals never regress (TrueTime guarantee).
    last: Option<TimeInterval>,
}

impl SimClock {
    pub fn new(cfg: SimClockConfig, rng: &mut Rng) -> Self {
        let offset_us = if cfg.max_error_us > 0 {
            rng.range_i64(-cfg.max_error_us / 2, cfg.max_error_us / 2) as f64
        } else {
            0.0
        };
        SimClock { cfg, offset_us, rng: rng.fork(), last: None }
    }

    /// A perfect clock (zero error, zero drift) — §4.2 analysis mode.
    pub fn perfect() -> Self {
        SimClock {
            cfg: SimClockConfig { max_error_us: 0, drift: 0.0, broken: false },
            offset_us: 0.0,
            rng: Rng::new(0),
            last: None,
        }
    }

    /// Nemesis hook: inject a skew spike, shifting the local oscillator
    /// phase by `offset_us`. The modelled clock-bound daemon *detects*
    /// the step and widens its reported bound to cover it, so
    /// correct-mode intervals still contain true time (the paper's
    /// guarantee holds; the cost is availability — wider bounds age
    /// leases faster). Pair with `broken = true` to model an undetected
    /// spike instead.
    pub fn inject_skew(&mut self, offset_us: Micros) {
        self.offset_us += offset_us as f64;
        self.cfg.max_error_us += offset_us.abs();
    }

    /// Nemesis hook: change this node's oscillator drift rate mid-run
    /// (per-node drift divergence; the config value seeds all nodes
    /// identically). The local reading is continuous at the switch: the
    /// phase accumulated under the old rate is folded into the offset,
    /// so only *future* time drifts at the new rate — without this, a
    /// rate change would be an instantaneous phase step that the
    /// reported error bound does not cover. Ongoing accumulation under
    /// the new rate is covered by `raw_at`, whose reported half-width
    /// always spans the true error even past the nominal `max_error`.
    pub fn set_drift(&mut self, true_now: Micros, drift: f64) {
        self.offset_us += true_now as f64 * (self.cfg.drift - drift);
        self.cfg.drift = drift;
    }

    /// Read the clock at true (virtual) time `true_now`.
    pub fn at(&mut self, true_now: Micros) -> TimeInterval {
        let iv = self.raw_at(true_now);
        // Enforce monotonic non-regression, as TrueTime does.
        let iv = match self.last {
            Some(prev) => TimeInterval::new(iv.earliest.max(prev.earliest), iv.latest.max(prev.latest)),
            None => iv,
        };
        self.last = Some(iv);
        iv
    }

    fn raw_at(&mut self, true_now: Micros) -> TimeInterval {
        let e = self.cfg.max_error_us;
        if e == 0 && !self.cfg.broken {
            return TimeInterval::exact(true_now);
        }
        // Local (drifting, offset) reading.
        let local = true_now as f64 * (1.0 + self.cfg.drift) + self.offset_us;
        // The daemon's reported error always covers |local - true| in
        // correct mode; we sample the reported half-width in
        // [|local-true|, max_error]. No clamp to max_error: when
        // accumulated drift (or an injected rate change) pushes the
        // true error past the nominal bound, a correct daemon widens
        // its report rather than lie — otherwise long runs would
        // silently violate the containment guarantee §4.3 is about.
        let skew = (local - true_now as f64).abs();
        if self.cfg.broken {
            // Report an interval that confidently excludes the true
            // time, wrong by 2-4x max_error. The direction is a stable
            // per-node coin flip (decided by the node's offset sign):
            // a backward-lying node under-estimates entry ages and keeps
            // serving reads past its lease — the §4.3 violation.
            let sign = if self.offset_us >= 0.0 { 1.0 } else { -1.0 };
            let lie = sign * e as f64 * (2.0 + 2.0 * self.rng.f64());
            let center = true_now as f64 + lie;
            let half = (e / 4).max(1) as f64;
            return TimeInterval::new((center - half) as Micros, (center + half) as Micros);
        }
        let half = skew + self.rng.f64() * (e as f64 - skew).max(0.0);
        let half = half.max(1.0);
        TimeInterval::new((local - half).floor() as Micros, (local + half).ceil() as Micros)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn correct_mode_contains_true_time() {
        let mut rng = Rng::new(99);
        for node in 0..20 {
            let mut c = SimClock::new(
                SimClockConfig { max_error_us: 50, drift: 1e-5, broken: false },
                &mut Rng::new(node),
            );
            let _ = &mut rng;
            for t in (0..1_000_000).step_by(37_123) {
                let iv = c.at(t);
                assert!(
                    iv.earliest <= t && t <= iv.latest,
                    "node {node} t {t} outside {iv:?}"
                );
                assert!(iv.uncertainty() <= 60, "uncertainty too large: {iv:?}");
            }
        }
    }

    #[test]
    fn broken_mode_excludes_true_time() {
        let mut c = SimClock::new(
            SimClockConfig { max_error_us: 50, drift: 0.0, broken: true },
            &mut Rng::new(4),
        );
        let mut excluded = 0;
        for t in (1000..100_000).step_by(997) {
            let iv = c.at(t);
            if t < iv.earliest || t > iv.latest {
                excluded += 1;
            }
        }
        assert!(excluded > 50, "broken clock should usually exclude truth");
    }

    #[test]
    fn monotone_nonregression() {
        let mut c = SimClock::new(SimClockConfig::default(), &mut Rng::new(5));
        let mut prev = c.at(0);
        for t in (0..500_000).step_by(11_003) {
            let iv = c.at(t);
            assert!(iv.earliest >= prev.earliest && iv.latest >= prev.latest);
            prev = iv;
        }
    }

    #[test]
    fn skew_spike_widens_bounds_but_stays_correct() {
        let mut c = SimClock::new(
            SimClockConfig { max_error_us: 50, drift: 0.0, broken: false },
            &mut Rng::new(7),
        );
        let _ = c.at(100_000);
        c.inject_skew(300_000); // 300ms forward spike, detected
        for t in (200_000..2_000_000).step_by(97_003) {
            let iv = c.at(t);
            assert!(
                iv.earliest <= t && t <= iv.latest,
                "detected spike must keep bounds correct: t {t} outside {iv:?}"
            );
        }
        // The reported uncertainty grew to cover the spike.
        let iv = c.at(2_100_000);
        assert!(iv.uncertainty() > 250_000, "bounds should widen: {iv:?}");
    }

    #[test]
    fn drift_change_applies_midrun_without_phase_step() {
        let mut c = SimClock::new(
            SimClockConfig { max_error_us: 5_000, drift: 1e-5, broken: false },
            &mut Rng::new(8),
        );
        // Accumulate phase under the old rate first: a naive rate swap
        // here would step the local reading by t*(new-old) ≈ 2ms and
        // push the "correct" interval off true time.
        let _ = c.at(2_000_000);
        c.set_drift(2_000_000, 1e-3); // 1000 ppm from here on
        for t in (2_000_000..4_000_000).step_by(211_001) {
            let iv = c.at(t);
            assert!(iv.earliest <= t && t <= iv.latest, "t {t} outside {iv:?}");
        }
    }

    #[test]
    fn drift_past_nominal_bound_still_contained() {
        // Accumulated drift beyond the nominal max_error must widen the
        // reported bound, not silently exclude true time.
        let mut c = SimClock::new(
            SimClockConfig { max_error_us: 50, drift: 0.0, broken: false },
            &mut Rng::new(9),
        );
        let _ = c.at(0);
        c.set_drift(0, 1e-3); // accumulates ~2ms over the run, >> 50µs
        for t in (0..2_000_000).step_by(133_001) {
            let iv = c.at(t);
            assert!(iv.earliest <= t && t <= iv.latest, "t {t} outside {iv:?}");
        }
        let iv = c.at(2_100_000);
        assert!(iv.uncertainty() > 2_000, "bound must widen with drift: {iv:?}");
    }

    #[test]
    fn perfect_clock_is_exact() {
        let mut c = SimClock::perfect();
        assert_eq!(c.at(12345), TimeInterval::exact(12345));
    }
}
