//! Real-mode bounded-uncertainty clock: the host monotonic clock wrapped
//! with a configured error bound.
//!
//! Stands in for AWS TimeSync + the clock-bound daemon in the paper's
//! EC2 testbed (§7.1: intervals < 100µs wide, error < 50µs). In our
//! single-host reproduction all server processes/threads share one
//! monotonic clock, so a *zero* bound would be trivially correct; we
//! still apply the configured bound so the protocol exercises the same
//! interval arithmetic as on a multi-host deployment, and so tests can
//! widen the bound to stress the conservative gates.

use std::time::Instant;

use super::{Clock, TimeInterval};
use crate::Micros;

/// Process-wide epoch so that all RealClocks share a timeline.
fn epoch() -> Instant {
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

#[derive(Debug, Clone)]
pub struct RealClock {
    /// Reported half-width, µs (paper testbed: <50).
    pub error_bound_us: Micros,
    /// Extra fixed skew applied to this clock's readings (test hook to
    /// emulate inter-host offsets; must stay within error_bound for the
    /// clock to be "correct").
    pub skew_us: Micros,
}

impl RealClock {
    pub fn new(error_bound_us: Micros) -> Self {
        // Touch the epoch early so concurrent first-readers agree.
        let _ = epoch();
        RealClock { error_bound_us, skew_us: 0 }
    }

    pub fn with_skew(error_bound_us: Micros, skew_us: Micros) -> Self {
        assert!(
            skew_us.abs() <= error_bound_us,
            "skew outside bound would make the clock incorrect"
        );
        let _ = epoch();
        RealClock { error_bound_us, skew_us }
    }

    /// Monotonic µs since the process epoch.
    pub fn monotonic_us() -> Micros {
        epoch().elapsed().as_micros() as Micros
    }
}

impl Clock for RealClock {
    fn interval_now(&mut self) -> TimeInterval {
        let t = Self::monotonic_us() + self.skew_us;
        TimeInterval::new(t - self.error_bound_us, t + self.error_bound_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intervals_advance_and_contain_truth() {
        let mut c = RealClock::new(50);
        let a = c.interval_now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = c.interval_now();
        assert!(b.earliest > a.earliest);
        let now = RealClock::monotonic_us();
        assert!(b.earliest <= now && now - 5_000 <= b.latest);
        assert_eq!(a.uncertainty(), 50);
    }

    #[test]
    fn skewed_clocks_still_overlap_truth() {
        let mut a = RealClock::with_skew(100, 80);
        let mut b = RealClock::with_skew(100, -80);
        let ia = a.interval_now();
        let ib = b.interval_now();
        let now = RealClock::monotonic_us();
        assert!(ia.earliest <= now && ib.earliest <= now);
        assert!(ia.latest + 1000 >= now && ib.latest + 1000 >= now);
    }

    #[test]
    #[should_panic]
    fn skew_beyond_bound_rejected() {
        let _ = RealClock::with_skew(50, 100);
    }
}
