//! Bounded-uncertainty clocks (paper §2.2, §4.3, §5.3).
//!
//! Every node has an `intervalNow()` returning `[earliest, latest]` such
//! that the true time was inside the interval at some moment during the
//! call. LeaseGuard needs exactly two derived judgments:
//!
//! * **definitely older than Δ** — used by the commit gate: a new leader
//!   may commit only when the deposed leader's last entry is *provably*
//!   more than Δ old (`t1.latest + Δ < t2.earliest`, §2.2).
//! * **possibly older than Δ** — used by the read gate: a leaseholder
//!   must stop serving once its newest committed entry *might* be more
//!   than Δ old (conservative age `now.latest − entry.earliest`).
//!
//! The asymmetry is what makes the protocol safe under uncertainty: the
//! reader gives up strictly before the committer proceeds, for any pair
//! of correct clocks. [`sim::SimClock`] models per-node drift + bounded
//! error (and an intentionally *broken* mode used by tests to reproduce
//! the §4.3 violation); [`real::RealClock`] wraps the host monotonic
//! clock with a configured bound, standing in for AWS TimeSync +
//! clock-bound (<50µs error in the paper's testbed).

pub mod real;
pub mod sim;

use crate::Micros;

/// A time interval `[earliest, latest]` guaranteed to have contained the
/// true time at some moment during the `interval_now()` call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimeInterval {
    pub earliest: Micros,
    pub latest: Micros,
}

impl TimeInterval {
    pub fn new(earliest: Micros, latest: Micros) -> Self {
        debug_assert!(earliest <= latest, "inverted interval {earliest}..{latest}");
        TimeInterval { earliest, latest }
    }

    /// An exact (zero-uncertainty) interval — perfect-clock mode (§4.2).
    pub fn exact(t: Micros) -> Self {
        TimeInterval { earliest: t, latest: t }
    }

    /// §2.2: `self` was recorded more than Δ ago, *for certain*, as
    /// observed at `now`. Used by the commit gate (Fig 2 lines 34-38).
    #[inline]
    pub fn definitely_older_than(&self, delta: Micros, now: TimeInterval) -> bool {
        self.latest + delta < now.earliest
    }

    /// `self` *might* be more than Δ old. The read gate serves only while
    /// this is false (Fig 2 line 20, conservatively under uncertainty).
    #[inline]
    pub fn possibly_older_than(&self, delta: Micros, now: TimeInterval) -> bool {
        self.max_age(now) > delta
    }

    /// Maximum possible age of this timestamp at `now` (conservative age
    /// fed to the read-admission engine).
    #[inline]
    pub fn max_age(&self, now: TimeInterval) -> Micros {
        now.latest - self.earliest
    }

    /// Minimum possible age (may be negative across nodes).
    #[inline]
    pub fn min_age(&self, now: TimeInterval) -> Micros {
        now.earliest - self.latest
    }

    /// Half-width of the interval (the clock's error bound at the call).
    #[inline]
    pub fn uncertainty(&self) -> Micros {
        (self.latest - self.earliest) / 2
    }
}

/// A source of bounded-uncertainty time readings.
pub trait Clock {
    /// The paper's `intervalNow()`.
    fn interval_now(&mut self) -> TimeInterval;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_interval_has_zero_uncertainty() {
        let t = TimeInterval::exact(100);
        assert_eq!(t.earliest, 100);
        assert_eq!(t.latest, 100);
        assert_eq!(t.uncertainty(), 0);
    }

    #[test]
    fn definitely_older_strict() {
        let e = TimeInterval::new(0, 10);
        // now.earliest must strictly exceed latest + delta.
        assert!(!e.definitely_older_than(100, TimeInterval::new(110, 120)));
        assert!(e.definitely_older_than(100, TimeInterval::new(111, 120)));
    }

    #[test]
    fn reader_yields_before_committer_proceeds() {
        // The safety asymmetry: for ANY entry interval and any pair of
        // correct readings, once a committer sees definitely_older, a
        // reader at the same or earlier true time already saw
        // possibly_older. Spot-check across a grid.
        let delta = 1000;
        for e_lo in [0i64, 5, 50] {
            for e_w in [0i64, 10, 100] {
                let entry = TimeInterval::new(e_lo, e_lo + e_w);
                for t in (0..3000).step_by(97) {
                    for err in [0i64, 10, 60] {
                        let now = TimeInterval::new(t - err, t + err);
                        if entry.definitely_older_than(delta, now) {
                            // Any correct reading at true time >= now.earliest
                            // must already be possibly_older.
                            let reader_now = TimeInterval::new(t - err, t + err);
                            assert!(entry.possibly_older_than(delta, reader_now));
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn ages_bracket_truth() {
        let e = TimeInterval::new(90, 110);
        let now = TimeInterval::new(190, 210);
        assert_eq!(e.max_age(now), 120);
        assert_eq!(e.min_age(now), 80);
        assert!(e.min_age(now) <= e.max_age(now));
    }
}
