//! Metrics registry: process-wide atomic counters/gauges with
//! per-group labels, plus a concurrent-recording variant of
//! [`crate::metrics::Histogram`].
//!
//! This replaces the ad-hoc `Status` atomics the real server used to
//! carry: instead of a flat struct whose scalar fields silently meant
//! "group 0 only" after the multi-Raft refactor, the registry holds one
//! [`GroupMetrics`] per Raft group — the per-group gauges *are* the
//! source of truth, and the old `leader_groups`/`committed_groups`
//! bitmasks are derived views ([`Registry::leader_groups`]).
//!
//! The paper-specific lease accounting lives here: reads served under
//! the leader's own lease vs. an *inherited* lease vs. a quorum round
//! vs. rejected (and why), and writes accepted vs. blocked during a
//! lease transfer. The per-stage op-latency breakdown
//! (queue → persist → replicate → commit → apply → reply) is recorded
//! into [`ConcurrentHistogram`]s per group by the server's event loop.
//!
//! Everything is lock-free: counters and gauges are relaxed atomics,
//! histogram recording is one relaxed `fetch_add` per bucket plus
//! min/max updates. Writers never block readers; a snapshot taken
//! mid-update is merely slightly stale, never torn in a way that
//! matters (each field is individually atomic).

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};

use crate::metrics::{self, Histogram};
use crate::shard::GroupId;
use crate::Micros;

/// Monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite with an externally-maintained total (used when the
    /// event loop mirrors a node's internal stats into the registry).
    #[inline]
    pub fn set(&self, n: u64) {
        self.0.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time signed value.
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Self {
        Gauge(AtomicI64::new(0))
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Concurrent-recording variant of [`Histogram`]: same bucket layout
/// (shared `bucket_index`), but `record(&self)` works from any thread.
/// Snapshot back into a plain [`Histogram`] for quantile queries.
#[derive(Debug)]
pub struct ConcurrentHistogram {
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum: AtomicU64,
    min: AtomicI64,
    max: AtomicI64,
}

impl ConcurrentHistogram {
    pub fn new() -> Self {
        let mut counts = Vec::with_capacity(metrics::BUCKETS);
        counts.resize_with(metrics::BUCKETS, AtomicU64::default);
        ConcurrentHistogram {
            counts,
            total: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicI64::new(Micros::MAX),
            max: AtomicI64::new(0),
        }
    }

    /// Record one value; lock-free, callable from any thread. Same
    /// clamp-into-top-bucket overflow behavior as `Histogram::record`.
    #[inline]
    pub fn record(&self, v: Micros) {
        let idx = metrics::bucket_index(v).min(self.counts.len() - 1);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v.max(0) as u64, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Materialize a point-in-time [`Histogram`] for quantile queries.
    pub fn snapshot(&self) -> Histogram {
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total = counts.iter().sum(); // consistent with the bucket copy
        Histogram::from_parts(
            counts,
            total,
            self.sum.load(Ordering::Relaxed) as u128,
            self.min.load(Ordering::Relaxed),
            self.max.load(Ordering::Relaxed),
        )
    }
}

impl Default for ConcurrentHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// The op pipeline stages the latency breakdown covers, in pipeline
/// order. Indexes into [`GroupMetrics::stages`].
pub const STAGE_NAMES: [&str; 6] = ["queue", "persist", "replicate", "commit", "apply", "reply"];
pub const STAGE_QUEUE: usize = 0;
pub const STAGE_PERSIST: usize = 1;
pub const STAGE_REPLICATE: usize = 2;
pub const STAGE_COMMIT: usize = 3;
pub const STAGE_APPLY: usize = 4;
pub const STAGE_REPLY: usize = 5;

/// All metrics for one Raft group.
#[derive(Debug, Default)]
pub struct GroupMetrics {
    // -- gauges: current protocol state --
    pub is_leader: AtomicBool,
    pub term: Gauge,
    pub commit_index: Gauge,
    pub limbo_len: Gauge,

    // -- lease accounting (the paper's claims, countable live) --
    /// Reads served locally under the leader's own fresh lease.
    pub reads_lease_local: Counter,
    /// Reads served under an *inherited* lease while awaiting our own
    /// (§3.3 — the headline optimization).
    pub reads_lease_inherited: Counter,
    /// Reads that took a quorum round (ReadIndex-style).
    pub reads_quorum: Counter,
    /// Reads parked awaiting a quorum round's completion.
    pub reads_deferred: Counter,
    /// Reads rejected: no usable lease.
    pub reads_rejected_no_lease: Counter,
    /// Reads rejected: key intersects the limbo region.
    pub reads_rejected_limbo: Counter,
    /// Writes appended to the log.
    pub writes_accepted: Counter,
    /// Commit advances blocked by the gate during a lease transfer
    /// (§3.2) — "writes blocked during transfer".
    pub writes_blocked_transfer: Counter,
    /// Writes rejected outright by the lease gate.
    pub writes_rejected_gate: Counter,
    /// Elections won by this node for this group.
    pub elections_won: Counter,

    // -- snapshots & log compaction --
    /// Snapshots this node took of its own state machine.
    pub snapshots_taken: Counter,
    /// Snapshots installed from a leader's chunked transfer.
    pub snapshots_installed: Counter,
    /// Snapshot installs rejected (corrupt payload, boundary mismatch).
    pub snapshots_rejected: Counter,
    /// Log index the newest local snapshot covers (the log's base);
    /// 0 until the first snapshot exists.
    pub last_snapshot_index: Gauge,

    /// Per-stage op latency: queue → persist → replicate → commit →
    /// apply → reply, indexed by the `STAGE_*` constants.
    pub stages: [ConcurrentHistogram; 6],
}

/// Process-wide registry: one [`GroupMetrics`] per Raft group plus
/// whole-process counters. Shared as `Arc<Registry>` between the
/// server's event loop, its listener/client threads, and test
/// harnesses.
#[derive(Debug)]
pub struct Registry {
    groups: Vec<GroupMetrics>,
    /// Persist-before-route barriers executed (one per event batch that
    /// had dirty state).
    pub wal_barriers: Counter,
    /// Physical fsyncs issued across all barriers (batching across
    /// groups means barriers ≥ syncs is NOT implied; see MultiStorage).
    pub wal_syncs: Counter,
    /// Client reads admitted through batched lease admission.
    pub reads_batched: Counter,
    /// Batched-admission engine invocations.
    pub engine_batches: Counter,
}

impl Registry {
    pub fn new(groups: usize) -> Self {
        let mut v = Vec::with_capacity(groups);
        v.resize_with(groups, GroupMetrics::default);
        Registry {
            groups: v,
            wal_barriers: Counter::new(),
            wal_syncs: Counter::new(),
            reads_batched: Counter::new(),
            engine_batches: Counter::new(),
        }
    }

    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    pub fn group(&self, g: GroupId) -> &GroupMetrics {
        &self.groups[g as usize]
    }

    pub fn groups(&self) -> &[GroupMetrics] {
        &self.groups
    }

    /// Bitmask of groups this process currently leads — derived from
    /// the per-group gauges, so it can never drift from them (the old
    /// `Status` kept scalar group-0 fields *and* a bitmask, a trap).
    pub fn leader_groups(&self) -> u64 {
        let mut mask = 0u64;
        for (g, m) in self.groups.iter().enumerate() {
            if m.is_leader.load(Ordering::Relaxed) {
                mask |= 1 << g;
            }
        }
        mask
    }

    /// Bitmask of groups whose commit index has advanced past zero.
    pub fn committed_groups(&self) -> u64 {
        let mut mask = 0u64;
        for (g, m) in self.groups.iter().enumerate() {
            if m.commit_index.get() > 0 {
                mask |= 1 << g;
            }
        }
        mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        c.set(2);
        assert_eq!(c.get(), 2);
        let g = Gauge::new();
        g.set(-7);
        assert_eq!(g.get(), -7);
    }

    #[test]
    fn concurrent_histogram_matches_sequential() {
        let ch = ConcurrentHistogram::new();
        let mut h = Histogram::new();
        for v in [1, 10, 100, 1000, 10_000, 100_000] {
            ch.record(v);
            h.record(v);
        }
        let snap = ch.snapshot();
        assert_eq!(snap.count(), h.count());
        assert_eq!(snap.p50(), h.p50());
        assert_eq!(snap.p99(), h.p99());
        assert_eq!(snap.min(), h.min());
        assert_eq!(snap.max(), h.max());
        assert!((snap.mean() - h.mean()).abs() < 1e-9);
    }

    #[test]
    fn concurrent_histogram_from_threads() {
        let ch = Arc::new(ConcurrentHistogram::new());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let ch = Arc::clone(&ch);
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    ch.record((t * 1000 + i) as Micros);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = ch.snapshot();
        assert_eq!(snap.count(), 4000);
        assert_eq!(snap.min(), 0);
        assert_eq!(snap.max(), 3999);
    }

    #[test]
    fn empty_concurrent_histogram_snapshot() {
        let snap = ConcurrentHistogram::new().snapshot();
        assert_eq!(snap.count(), 0);
        assert_eq!(snap.min(), 0);
        assert_eq!(snap.p99(), 0);
    }

    #[test]
    fn registry_masks_derive_from_gauges() {
        let r = Registry::new(4);
        r.group(1).is_leader.store(true, Ordering::Relaxed);
        r.group(3).is_leader.store(true, Ordering::Relaxed);
        r.group(0).commit_index.set(5);
        r.group(1).commit_index.set(9);
        assert_eq!(r.leader_groups(), 0b1010);
        assert_eq!(r.committed_groups(), 0b0011);
        assert_eq!(r.num_groups(), 4);
    }

    #[test]
    fn stage_constants_cover_array() {
        assert_eq!(STAGE_NAMES.len(), 6);
        let m = GroupMetrics::default();
        m.stages[STAGE_QUEUE].record(10);
        m.stages[STAGE_REPLY].record(20);
        assert_eq!(m.stages[STAGE_QUEUE].count(), 1);
        assert_eq!(m.stages[STAGE_REPLY].count(), 1);
        assert_eq!(m.stages[STAGE_PERSIST].count() + m.stages[STAGE_REPLICATE].count(), 0);
        assert_eq!(m.stages[STAGE_COMMIT].count() + m.stages[STAGE_APPLY].count(), 0);
    }
}
