//! Flight recorder: a fixed-capacity, allocation-free ring buffer of
//! typed protocol events, one per node (sim and real).
//!
//! Every consequential protocol decision — election started/won, lease
//! acquired/inherited, read admitted/deferred/rejected (with the lease
//! state machine's reason), append fan-out, WAL barrier, commit advance
//! — is recorded as a fixed-size [`FlightEvent`] stamped with the
//! node's time, term, and group. When a linearizability check or a
//! crash-test assertion fails, the window of events around the
//! violation is dumped so the verdict comes with an evidence trail; a
//! live server exposes its tail via the `leaseguard stat` RPC.
//!
//! Determinism contract: the recorder draws no randomness and reads no
//! clock — callers pass the timestamp they already hold — and recording
//! never changes control flow, so a fixed-seed sim run is byte-identical
//! with the recorder on or off (guarded by
//! `determinism_guard_tracing`). With capacity 0 the recorder is
//! disabled and [`FlightRecorder::record`] is a branch and a return: no
//! buffer is allocated, nothing is stored.

use crate::shard::GroupId;
use crate::Micros;

/// What happened. The discriminant is the wire encoding — append only,
/// never renumber.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// Node started an election. a = candidate's new term.
    ElectionStarted = 0,
    /// Node won and became leader. a = limbo length inherited.
    ElectionWon = 1,
    /// Leader stepped down. a = new (higher) observed term.
    SteppedDown = 2,
    /// New leader inherited the predecessor's lease window (§3.3).
    /// a = limbo length, b = limbo region's upper log index.
    LeaseInherited = 3,
    /// Leader's own-term lease became usable (first own-term commit).
    /// a = commit index that activated it.
    LeaseAcquired = 4,
    /// Read served locally under the leader's own fresh lease. a = key.
    ReadServedLocal = 5,
    /// Read served under an *inherited* lease while awaiting our own —
    /// the paper's headline optimization. a = key.
    ReadServedInherited = 6,
    /// Read served via a quorum round (ReadIndex-style). a = key.
    ReadServedQuorum = 7,
    /// Read deferred until the quorum round completes. a = key.
    ReadDeferred = 8,
    /// Read rejected: no usable lease. a = key.
    ReadRejectedNoLease = 9,
    /// Read rejected: key intersects the limbo region. a = key.
    ReadRejectedLimbo = 10,
    /// Write accepted into the log. a = key, b = log index.
    WriteAccepted = 11,
    /// Write rejected by the commit gate during lease transfer (§3.2).
    /// a = key.
    WriteRejectedGate = 12,
    /// Commit advance blocked by the gate. a = blocked index.
    CommitGateBlocked = 13,
    /// AppendEntries fan-out round (one event per round, not per peer).
    /// a = leader's last log index at fan-out, b = round seq.
    AppendFanout = 14,
    /// WAL barrier completed (real path). a = groups flushed, b = syncs.
    WalBarrier = 15,
    /// Commit index advanced. a = new commit index.
    CommitAdvance = 16,
    /// Node snapshotted its own state machine and compacted the log.
    /// a = snapshot boundary (last included index), b = entries dropped.
    SnapshotTaken = 17,
    /// Follower installed a leader's snapshot. a = boundary index,
    /// b = snapshot bytes.
    SnapshotInstalled = 18,
    /// Snapshot install rejected (corrupt payload, wrong group, or
    /// boundary below commit). a = boundary index, b = offset reached.
    SnapshotRejected = 19,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::ElectionStarted => "election_started",
            EventKind::ElectionWon => "election_won",
            EventKind::SteppedDown => "stepped_down",
            EventKind::LeaseInherited => "lease_inherited",
            EventKind::LeaseAcquired => "lease_acquired",
            EventKind::ReadServedLocal => "read_served_local",
            EventKind::ReadServedInherited => "read_served_inherited",
            EventKind::ReadServedQuorum => "read_served_quorum",
            EventKind::ReadDeferred => "read_deferred",
            EventKind::ReadRejectedNoLease => "read_rejected_no_lease",
            EventKind::ReadRejectedLimbo => "read_rejected_limbo",
            EventKind::WriteAccepted => "write_accepted",
            EventKind::WriteRejectedGate => "write_rejected_gate",
            EventKind::CommitGateBlocked => "commit_gate_blocked",
            EventKind::AppendFanout => "append_fanout",
            EventKind::WalBarrier => "wal_barrier",
            EventKind::CommitAdvance => "commit_advance",
            EventKind::SnapshotTaken => "snapshot_taken",
            EventKind::SnapshotInstalled => "snapshot_installed",
            EventKind::SnapshotRejected => "snapshot_rejected",
        }
    }

    /// Wire decoding; `None` for unknown discriminants (future events
    /// from a newer peer are dropped, not misread).
    pub fn from_u8(v: u8) -> Option<EventKind> {
        Some(match v {
            0 => EventKind::ElectionStarted,
            1 => EventKind::ElectionWon,
            2 => EventKind::SteppedDown,
            3 => EventKind::LeaseInherited,
            4 => EventKind::LeaseAcquired,
            5 => EventKind::ReadServedLocal,
            6 => EventKind::ReadServedInherited,
            7 => EventKind::ReadServedQuorum,
            8 => EventKind::ReadDeferred,
            9 => EventKind::ReadRejectedNoLease,
            10 => EventKind::ReadRejectedLimbo,
            11 => EventKind::WriteAccepted,
            12 => EventKind::WriteRejectedGate,
            13 => EventKind::CommitGateBlocked,
            14 => EventKind::AppendFanout,
            15 => EventKind::WalBarrier,
            16 => EventKind::CommitAdvance,
            17 => EventKind::SnapshotTaken,
            18 => EventKind::SnapshotInstalled,
            19 => EventKind::SnapshotRejected,
            _ => return None,
        })
    }
}

/// One recorded event. Fixed-size and `Copy` so the ring never
/// allocates after construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlightEvent {
    /// Node-local time of the event, µs.
    pub at: Micros,
    /// Raft term at the time of the event.
    pub term: u64,
    /// Raft group the node serves.
    pub group: GroupId,
    pub kind: EventKind,
    /// Kind-specific payload (see [`EventKind`] docs).
    pub a: u64,
    /// Second kind-specific payload.
    pub b: u64,
}

impl FlightEvent {
    /// One-line human rendering, used by dumps and `leaseguard stat`.
    pub fn render(&self) -> String {
        format!(
            "{:>12}µs g{} t{:<3} {:<24} a={} b={}",
            self.at,
            self.group,
            self.term,
            self.kind.name(),
            self.a,
            self.b
        )
    }
}

/// Fixed-capacity ring buffer of [`FlightEvent`]s. Capacity 0 disables
/// recording entirely (no buffer, `record` is a branch + return).
#[derive(Debug, Clone)]
pub struct FlightRecorder {
    buf: Vec<FlightEvent>,
    /// Ring capacity. Stored explicitly (not `buf.capacity()`, which the
    /// allocator may round up) so the wrap point is deterministic.
    cap: usize,
    /// Next write position (valid only when capacity > 0).
    next: usize,
    /// Total events ever recorded (≥ retained count; the difference is
    /// how many the ring has overwritten).
    total: u64,
    group: GroupId,
}

impl FlightRecorder {
    pub fn new(capacity: usize, group: GroupId) -> Self {
        FlightRecorder {
            buf: Vec::with_capacity(capacity),
            cap: capacity,
            next: 0,
            total: 0,
            group,
        }
    }

    /// A recorder that stores nothing (the disabled configuration).
    pub fn disabled() -> Self {
        Self::new(0, 0)
    }

    pub fn is_enabled(&self) -> bool {
        self.cap > 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Events currently retained (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total events ever recorded, including overwritten ones.
    pub fn total_recorded(&self) -> u64 {
        self.total
    }

    pub fn group(&self) -> GroupId {
        self.group
    }

    /// Record one event. No allocation (the ring was sized at
    /// construction), no clock reads, no RNG — `at` and `term` come
    /// from the caller.
    #[inline]
    pub fn record(&mut self, at: Micros, term: u64, kind: EventKind, a: u64, b: u64) {
        let cap = self.cap;
        if cap == 0 {
            return;
        }
        let ev = FlightEvent { at, term, group: self.group, kind, a, b };
        if self.buf.len() < cap {
            self.buf.push(ev); // within preallocated capacity: no realloc
        } else {
            self.buf[self.next] = ev;
        }
        self.next = (self.next + 1) % cap;
        self.total += 1;
    }

    /// Iterate retained events oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &FlightEvent> {
        let split = if self.buf.len() < self.cap { 0 } else { self.next };
        self.buf[split..].iter().chain(self.buf[..split].iter())
    }

    /// The most recent `n` events, oldest → newest.
    pub fn tail(&self, n: usize) -> Vec<FlightEvent> {
        let skip = self.len().saturating_sub(n);
        self.iter().skip(skip).copied().collect()
    }

    /// Retained events with `from <= at <= to`, oldest → newest.
    pub fn window(&self, from: Micros, to: Micros) -> Vec<FlightEvent> {
        self.iter().filter(|e| e.at >= from && e.at <= to).copied().collect()
    }
}

/// Render a dump of every recorder's events inside `[from, to]`,
/// labeled per node — the evidence trail attached to a failed
/// linearizability check. `labels[i]` describes `recorders[i]`
/// (e.g. "g0/n2").
pub fn dump_window(
    title: &str,
    labels: &[String],
    recorders: &[&FlightRecorder],
    from: Micros,
    to: Micros,
) -> String {
    let mut out = format!("=== flight recorder dump: {title} (window {from}..{to}µs) ===\n");
    for (label, rec) in labels.iter().zip(recorders.iter()) {
        let events = rec.window(from, to);
        let overwritten = rec.total_recorded() - rec.len() as u64;
        out.push_str(&format!(
            "--- node {label}: {} event(s) in window, {} retained, {} overwritten ---\n",
            events.len(),
            rec.len(),
            overwritten
        ));
        for e in &events {
            out.push_str(&e.render());
            out.push('\n');
        }
    }
    out.push_str("=== end dump ===\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_stores_nothing() {
        let mut r = FlightRecorder::disabled();
        r.record(1, 1, EventKind::ElectionWon, 0, 0);
        assert!(!r.is_enabled());
        assert_eq!(r.len(), 0);
        assert_eq!(r.total_recorded(), 0);
        assert_eq!(r.capacity(), 0);
    }

    #[test]
    fn ring_retains_most_recent_in_order() {
        let mut r = FlightRecorder::new(4, 2);
        for i in 0..10u64 {
            r.record(i as Micros, 1, EventKind::CommitAdvance, i, 0);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.total_recorded(), 10);
        let got: Vec<u64> = r.iter().map(|e| e.a).collect();
        assert_eq!(got, vec![6, 7, 8, 9]);
        assert!(r.iter().all(|e| e.group == 2));
    }

    #[test]
    fn ring_does_not_reallocate_past_capacity() {
        let mut r = FlightRecorder::new(8, 0);
        let cap_before = r.buf.capacity();
        let ptr_before = r.buf.as_ptr();
        for i in 0..1000 {
            r.record(i, 1, EventKind::WriteAccepted, 0, 0);
        }
        assert_eq!(r.buf.capacity(), cap_before);
        assert_eq!(r.buf.as_ptr(), ptr_before);
    }

    #[test]
    fn tail_and_window() {
        let mut r = FlightRecorder::new(16, 0);
        for i in 0..8 {
            r.record(i * 100, 1, EventKind::ReadServedLocal, i as u64, 0);
        }
        let t = r.tail(3);
        assert_eq!(t.iter().map(|e| e.a).collect::<Vec<_>>(), vec![5, 6, 7]);
        let w = r.window(250, 550);
        assert_eq!(w.iter().map(|e| e.at).collect::<Vec<_>>(), vec![300, 400, 500]);
        // tail(n > len) returns everything.
        assert_eq!(r.tail(100).len(), 8);
    }

    #[test]
    fn partially_filled_iterates_in_insert_order() {
        let mut r = FlightRecorder::new(64, 0);
        r.record(5, 1, EventKind::ElectionStarted, 0, 0);
        r.record(9, 1, EventKind::ElectionWon, 0, 0);
        let kinds: Vec<EventKind> = r.iter().map(|e| e.kind).collect();
        assert_eq!(kinds, vec![EventKind::ElectionStarted, EventKind::ElectionWon]);
    }

    #[test]
    fn kind_roundtrips_through_u8() {
        for raw in 0u8..=40 {
            if let Some(k) = EventKind::from_u8(raw) {
                assert_eq!(k as u8, raw);
                assert!(!k.name().is_empty());
            } else {
                assert!(raw > 19, "kind {raw} should decode");
            }
        }
    }

    #[test]
    fn dump_window_renders_labels_and_events() {
        let mut a = FlightRecorder::new(8, 0);
        let mut b = FlightRecorder::new(8, 1);
        a.record(100, 3, EventKind::ReadServedInherited, 42, 0);
        b.record(900, 3, EventKind::ReadRejectedLimbo, 7, 0);
        let labels = vec!["g0/n0".to_string(), "g1/n1".to_string()];
        let dump = dump_window("test", &labels, &[&a, &b], 0, 500);
        assert!(dump.contains("g0/n0"), "{dump}");
        assert!(dump.contains("read_served_inherited"), "{dump}");
        // b's event at 900µs is outside the window.
        assert!(!dump.contains("read_rejected_limbo"), "{dump}");
        assert!(dump.contains("g1/n1: 0 event(s)"), "{dump}");
    }
}
