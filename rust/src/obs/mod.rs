//! Observability: flight recorder, metrics registry, and live
//! introspection snapshots.
//!
//! Three pillars (see DESIGN.md § Observability):
//!
//! * [`recorder`] — per-node fixed-capacity ring buffer of typed
//!   protocol events, dumped when a check fails and tailed live;
//! * [`registry`] — process-wide atomic counters/gauges with per-group
//!   labels plus concurrent histograms for the per-stage op latency
//!   breakdown, replacing the real server's old ad-hoc `Status` struct;
//! * [`StatusSnapshot`] — the typed point-in-time view of both, served
//!   over the wire by `StatusRequest`/`StatusReply` and rendered as
//!   JSON by `leaseguard stat`.

pub mod recorder;
pub mod registry;

pub use recorder::{dump_window, EventKind, FlightEvent, FlightRecorder};
pub use registry::{ConcurrentHistogram, Counter, Gauge, GroupMetrics, Registry};

use crate::metrics::Histogram;
use crate::shard::GroupId;
use crate::Micros;

/// Compact summary of one stage's latency histogram — what travels on
/// the wire instead of 1280 raw buckets.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageSummary {
    pub count: u64,
    pub sum_us: u64,
    pub min_us: Micros,
    pub p50_us: Micros,
    pub p90_us: Micros,
    pub p99_us: Micros,
    pub max_us: Micros,
}

impl StageSummary {
    pub fn of(h: &Histogram) -> Self {
        StageSummary {
            count: h.count(),
            sum_us: (h.mean() * h.count() as f64).round() as u64,
            min_us: h.min(),
            p50_us: h.p50(),
            p90_us: h.p90(),
            p99_us: h.p99(),
            max_us: h.max(),
        }
    }
}

/// Point-in-time view of one Raft group: protocol gauges, lease
/// accounting, per-stage latency, and the flight-recorder tail.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GroupSnapshot {
    pub group: GroupId,
    pub is_leader: bool,
    pub term: u64,
    pub commit_index: u64,
    pub limbo_len: u64,
    pub reads_lease_local: u64,
    pub reads_lease_inherited: u64,
    pub reads_quorum: u64,
    pub reads_deferred: u64,
    pub reads_rejected_no_lease: u64,
    pub reads_rejected_limbo: u64,
    pub writes_accepted: u64,
    pub writes_blocked_transfer: u64,
    pub writes_rejected_gate: u64,
    pub elections_won: u64,
    pub snapshots_taken: u64,
    pub snapshots_installed: u64,
    pub snapshots_rejected: u64,
    /// Boundary index of the newest local snapshot (0 = none yet).
    pub last_snapshot_index: u64,
    /// Indexed by `registry::STAGE_*`; names in `registry::STAGE_NAMES`.
    pub stages: [StageSummary; 6],
    /// Most recent flight-recorder events, oldest → newest.
    pub events: Vec<FlightEvent>,
}

/// Point-in-time view of a whole server process, per group.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StatusSnapshot {
    pub groups: Vec<GroupSnapshot>,
    pub wal_barriers: u64,
    pub wal_syncs: u64,
    pub reads_batched: u64,
    pub engine_batches: u64,
}

impl Registry {
    /// Snapshot one group's registry state (recorder tail not included —
    /// the caller owns the node and splices in `events`).
    pub fn group_snapshot(&self, g: GroupId) -> GroupSnapshot {
        use std::sync::atomic::Ordering;
        let m = self.group(g);
        let mut stages = [StageSummary::default(); 6];
        for (out, ch) in stages.iter_mut().zip(m.stages.iter()) {
            *out = StageSummary::of(&ch.snapshot());
        }
        GroupSnapshot {
            group: g,
            is_leader: m.is_leader.load(Ordering::Relaxed),
            term: m.term.get().max(0) as u64,
            commit_index: m.commit_index.get().max(0) as u64,
            limbo_len: m.limbo_len.get().max(0) as u64,
            reads_lease_local: m.reads_lease_local.get(),
            reads_lease_inherited: m.reads_lease_inherited.get(),
            reads_quorum: m.reads_quorum.get(),
            reads_deferred: m.reads_deferred.get(),
            reads_rejected_no_lease: m.reads_rejected_no_lease.get(),
            reads_rejected_limbo: m.reads_rejected_limbo.get(),
            writes_accepted: m.writes_accepted.get(),
            writes_blocked_transfer: m.writes_blocked_transfer.get(),
            writes_rejected_gate: m.writes_rejected_gate.get(),
            elections_won: m.elections_won.get(),
            snapshots_taken: m.snapshots_taken.get(),
            snapshots_installed: m.snapshots_installed.get(),
            snapshots_rejected: m.snapshots_rejected.get(),
            last_snapshot_index: m.last_snapshot_index.get().max(0) as u64,
            stages,
            events: Vec::new(),
        }
    }

    /// Snapshot the whole registry (all groups, no recorder tails).
    pub fn snapshot(&self) -> StatusSnapshot {
        StatusSnapshot {
            groups: (0..self.num_groups() as GroupId).map(|g| self.group_snapshot(g)).collect(),
            wal_barriers: self.wal_barriers.get(),
            wal_syncs: self.wal_syncs.get(),
            reads_batched: self.reads_batched.get(),
            engine_batches: self.engine_batches.get(),
        }
    }
}

impl StatusSnapshot {
    /// Render as JSON (hand-rolled: no serde offline; every value is a
    /// number, bool, or fixed key, so no string escaping is needed).
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(4096);
        s.push_str("{\n  \"groups\": [");
        for (i, g) in self.groups.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str("\n    {");
            s.push_str(&format!("\"group\": {}, ", g.group));
            s.push_str(&format!("\"is_leader\": {}, ", g.is_leader));
            s.push_str(&format!("\"term\": {}, ", g.term));
            s.push_str(&format!("\"commit_index\": {}, ", g.commit_index));
            s.push_str(&format!("\"limbo_len\": {},\n     ", g.limbo_len));
            s.push_str(&format!("\"reads_lease_local\": {}, ", g.reads_lease_local));
            s.push_str(&format!("\"reads_lease_inherited\": {}, ", g.reads_lease_inherited));
            s.push_str(&format!("\"reads_quorum\": {}, ", g.reads_quorum));
            s.push_str(&format!("\"reads_deferred\": {}, ", g.reads_deferred));
            s.push_str(&format!("\"reads_rejected_no_lease\": {}, ", g.reads_rejected_no_lease));
            s.push_str(&format!("\"reads_rejected_limbo\": {},\n     ", g.reads_rejected_limbo));
            s.push_str(&format!("\"writes_accepted\": {}, ", g.writes_accepted));
            s.push_str(&format!("\"writes_blocked_transfer\": {}, ", g.writes_blocked_transfer));
            s.push_str(&format!("\"writes_rejected_gate\": {}, ", g.writes_rejected_gate));
            s.push_str(&format!("\"elections_won\": {},\n     ", g.elections_won));
            s.push_str(&format!("\"snapshots_taken\": {}, ", g.snapshots_taken));
            s.push_str(&format!("\"snapshots_installed\": {}, ", g.snapshots_installed));
            s.push_str(&format!("\"snapshots_rejected\": {}, ", g.snapshots_rejected));
            s.push_str(&format!("\"last_snapshot_index\": {},\n     ", g.last_snapshot_index));
            s.push_str("\"stages\": {");
            for (j, (name, st)) in registry::STAGE_NAMES.iter().zip(g.stages.iter()).enumerate() {
                if j > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!(
                    "\"{name}\": {{\"count\": {}, \"sum_us\": {}, \"min_us\": {}, \
                     \"p50_us\": {}, \"p90_us\": {}, \"p99_us\": {}, \"max_us\": {}}}",
                    st.count, st.sum_us, st.min_us, st.p50_us, st.p90_us, st.p99_us, st.max_us
                ));
            }
            s.push_str("},\n     \"events\": [");
            for (j, e) in g.events.iter().enumerate() {
                if j > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!(
                    "{{\"at_us\": {}, \"term\": {}, \"kind\": \"{}\", \"a\": {}, \"b\": {}}}",
                    e.at,
                    e.term,
                    e.kind.name(),
                    e.a,
                    e.b
                ));
            }
            s.push_str("]}");
        }
        s.push_str("\n  ],\n");
        s.push_str(&format!("  \"wal_barriers\": {},\n", self.wal_barriers));
        s.push_str(&format!("  \"wal_syncs\": {},\n", self.wal_syncs));
        s.push_str(&format!("  \"reads_batched\": {},\n", self.reads_batched));
        s.push_str(&format!("  \"engine_batches\": {}\n", self.engine_batches));
        s.push('}');
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_snapshot_carries_lease_accounting_and_stages() {
        let r = Registry::new(2);
        r.group(1).reads_lease_inherited.add(42);
        r.group(1).reads_rejected_limbo.add(3);
        r.group(0).writes_accepted.add(7);
        r.group(1).stages[registry::STAGE_QUEUE].record(150);
        r.group(1).stages[registry::STAGE_REPLY].record(80);
        r.group(0).snapshots_taken.add(2);
        r.group(0).last_snapshot_index.set(42);
        r.wal_barriers.add(5);
        let snap = r.snapshot();
        assert_eq!(snap.groups.len(), 2);
        assert_eq!(snap.groups[0].snapshots_taken, 2);
        assert_eq!(snap.groups[0].last_snapshot_index, 42);
        assert_eq!(snap.groups[1].reads_lease_inherited, 42);
        assert_eq!(snap.groups[1].reads_rejected_limbo, 3);
        assert_eq!(snap.groups[0].writes_accepted, 7);
        assert_eq!(snap.groups[1].stages[registry::STAGE_QUEUE].count, 1);
        assert_eq!(snap.groups[1].stages[registry::STAGE_QUEUE].p50_us, 150);
        assert_eq!(snap.wal_barriers, 5);
    }

    #[test]
    fn json_rendering_contains_all_keys() {
        let r = Registry::new(1);
        r.group(0).reads_lease_inherited.inc();
        let mut snap = r.snapshot();
        snap.groups[0].events.push(FlightEvent {
            at: 123,
            term: 2,
            group: 0,
            kind: EventKind::ReadServedInherited,
            a: 9,
            b: 0,
        });
        let json = snap.to_json();
        for key in [
            "\"groups\"",
            "\"reads_lease_inherited\": 1",
            "\"reads_deferred\"",
            "\"reads_rejected_no_lease\"",
            "\"writes_blocked_transfer\"",
            "\"snapshots_taken\"",
            "\"snapshots_installed\"",
            "\"snapshots_rejected\"",
            "\"last_snapshot_index\"",
            "\"stages\"",
            "\"queue\"",
            "\"persist\"",
            "\"replicate\"",
            "\"commit\"",
            "\"apply\"",
            "\"reply\"",
            "\"p99_us\"",
            "\"events\"",
            "\"kind\": \"read_served_inherited\"",
            "\"wal_barriers\"",
        ] {
            assert!(json.contains(key), "missing {key} in:\n{json}");
        }
        // Balanced braces/brackets — cheap well-formedness check.
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }
}
