//! Microbenchmark suite for the hot paths, shared by the harness=false
//! bench target (`cargo bench --bench micro`) and the CLI
//! (`leaseguard bench`). Both can emit the machine-readable
//! `BENCH_micro.json` trajectory at the repo root so every perf PR
//! records before/after numbers (see `scripts/bench.sh`).
//!
//! Covered: event-loop throughput, a full sim availability run, read
//! admission (scalar and, when artifacts exist, the XLA engine),
//! zero-copy replication fan-out vs. the per-peer deep-copy baseline,
//! wire encode with and without buffer reuse, loopback frame transport,
//! the kv read path (Arc snapshot vs. per-read deep copy), multi-group
//! sim throughput, histogram recording, and the client-frame codec.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::time::Instant;

use crate::clock::TimeInterval;
use crate::cluster::Cluster;
use crate::config::{ConsistencyMode, Params};
use crate::figures::fig8::limbo_leader;
use crate::kv::Command;
use crate::metrics::Histogram;
use crate::prob::Rng;
use crate::raft::log::Entry;
use crate::raft::{Message, Node, NodeConfig, Output, TimerKind};
use crate::report;
use crate::runtime::{hash_key, scalar_admission, AdmissionEngine, AdmissionInputs};
use crate::server::transport::{write_frame, FrameReader};
use crate::server::wire::{self, ClientReq, Enc, Frame};
use crate::sim::EventQueue;

/// One measured microbench: best-of-3 throughput after a warmup rep.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub ops_per_sec: f64,
    pub ops_per_rep: u64,
}

fn bench<F: FnMut() -> u64>(out: &mut Vec<BenchResult>, name: &str, mut f: F) {
    f(); // warmup
    let mut best = 0.0f64;
    let mut last_ops = 0;
    for _ in 0..3 {
        // lint:allow(R1): bench timing measures real elapsed wall time by definition
        let t0 = Instant::now();
        let ops = f();
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        best = best.max(ops as f64 / dt);
        last_ops = ops;
    }
    println!("{name:<56} {best:>14.0} ops/s  ({last_ops} ops/rep)");
    out.push(BenchResult { name: name.to_string(), ops_per_sec: best, ops_per_rep: last_ops });
}

/// A 5-node leader with `entries` log entries and all followers unacked
/// (`next_index` still at 1), so every heartbeat fan-out re-sends the
/// full batch to each of the 4 peers — the replication hot path under a
/// catch-up-shaped load.
fn fanout_leader(entries: u64) -> (Node, TimeInterval) {
    let cfg = NodeConfig {
        id: 0,
        n: 5,
        mode: ConsistencyMode::Inconsistent,
        election_timeout_us: 500_000,
        election_jitter_us: 0,
        lease_duration_us: 1_000_000,
        heartbeat_us: 75_000,
        lease_renew_fraction: 0.0,
        max_entries_per_append: 1024,
        snapshot_threshold: 0,
        group: 0,
        recorder_capacity: 0, // bench the hot path without tracing
    };
    let (mut node, _) = Node::new(cfg, 1, TimeInterval::exact(0));
    let now = TimeInterval::exact(500_000);
    node.on_timer(now, TimerKind::Election);
    let term = node.term();
    node.on_message(now, Message::VoteReply { term, voter: 1, granted: true });
    node.on_message(now, Message::VoteReply { term, voter: 2, granted: true });
    assert!(node.is_leader(), "bench setup: election failed");
    // Term-start noop is entry 1; add the rest via the write path.
    for i in 1..entries {
        node.client_write(now, i, (i % 64) as u32, i, 0);
    }
    assert_eq!(node.log().last_index(), entries);
    (node, now)
}

/// Run the whole suite, printing a line per bench, returning the data.
pub fn run_suite() -> Vec<BenchResult> {
    let mut out = Vec::new();

    bench(&mut out, "event_loop: schedule+pop", || {
        let mut q = EventQueue::new();
        let n = 1_000_000u64;
        for i in 0..n {
            q.schedule(i as i64, i);
        }
        let mut popped = 0;
        while q.pop().is_some() {
            popped += 1;
        }
        popped
    });

    bench(&mut out, "sim: full availability run (events)", || {
        let mut p = Params::default();
        p.consistency = ConsistencyMode::LeaseGuard;
        p.duration_us = 1_000_000;
        p.interarrival_us = 100.0;
        p.crash_leader_at_us = 300_000;
        let rep = Cluster::new(p).run();
        rep.events_processed
    });

    // ---- replication fan-out: the tentpole measurement --------------
    // 5-node replicate-all with 64-entry batches. The zero-copy path
    // materializes the batch into one Arc and fans out views; the
    // baseline re-enacts what `send_append` did before this PR — one
    // deep copy of the 64-entry segment per follower.
    bench(&mut out, "replication: fan-out 5n x 64 entries (zero-copy)", || {
        let (mut node, now) = fanout_leader(64);
        let reps = 4000u64;
        let mut entries_sent = 0u64;
        for _ in 0..reps {
            let outs = node.on_timer(now, TimerKind::Heartbeat);
            for o in &outs {
                if let Output::Send { msg: Message::AppendEntries { entries, .. }, .. } = o {
                    entries_sent += entries.len() as u64;
                }
            }
        }
        assert_eq!(entries_sent, reps * 4 * 64);
        entries_sent
    });

    bench(&mut out, "replication: fan-out 5n x 64 entries (deep-copy baseline)", || {
        // Re-enacts the pre-refactor send path shape-for-shape: per
        // round, build one AppendEntries per peer into an output vec —
        // but materialize the batch once per PEER (the old
        // `slice(..).to_vec()` behavior) instead of once per round.
        // Only the materialization strategy differs from the zero-copy
        // row above.
        let (node, _) = fanout_leader(64);
        let term = node.term();
        let commit = node.commit_index();
        let reps = 4000u64;
        let mut entries_sent = 0u64;
        for _ in 0..reps {
            let mut outs: Vec<Output> = Vec::new();
            for peer in 1..5usize {
                let batch: crate::raft::EntryBatch = node.log().slice(0, 64).into();
                entries_sent += batch.len() as u64;
                outs.push(Output::Send {
                    to: peer,
                    msg: Message::AppendEntries {
                        term,
                        leader: 0,
                        prev_index: 0,
                        prev_term: 0,
                        entries: batch,
                        leader_commit: commit,
                        seq: 1,
                    },
                });
            }
            std::hint::black_box(&outs);
        }
        entries_sent
    });

    // ---- wire / frame throughput ------------------------------------
    let batch_msg = || Message::AppendEntries {
        term: 3,
        leader: 0,
        prev_index: 0,
        prev_term: 0,
        entries: (0..64u32)
            .map(|i| Entry {
                term: 3,
                command: Command::Put { key: i, value: i as u64, payload_bytes: 256 },
                written_at: TimeInterval::exact(i as i64),
            })
            .collect::<Vec<_>>()
            .into(),
        leader_commit: 0,
        seq: 9,
    };

    bench(&mut out, "wire: encode 64-entry AppendEntries (reused buffer)", || {
        let msg = batch_msg();
        let mut enc = Enc::new();
        let n = 100_000u64;
        for _ in 0..n {
            enc.reset();
            wire::encode_raft_into(0, 0, &msg, &mut enc);
            std::hint::black_box(&enc.buf);
        }
        n
    });

    bench(&mut out, "wire: encode 64-entry AppendEntries (fresh alloc)", || {
        let msg = batch_msg();
        let n = 100_000u64;
        for _ in 0..n {
            let body = wire::encode(&Frame::Raft { from: 0, group: 0, msg: msg.clone() });
            std::hint::black_box(&body);
        }
        n
    });

    bench(&mut out, "transport: loopback 1KiB frames (vectored+buffered)", || {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let mut tx = TcpStream::connect(addr).expect("connect");
        tx.set_nodelay(true).ok();
        let (rx, _) = listener.accept().expect("accept");
        rx.set_nodelay(true).ok();
        let mut frames = FrameReader::new(rx);
        let body = vec![0xA5u8; 1024];
        let n = 20_000u64;
        let burst = 32u64;
        let mut seen = 0u64;
        while seen < n {
            for _ in 0..burst {
                write_frame(&mut tx, &body).expect("write");
            }
            for _ in 0..burst {
                let got = frames.next_frame().expect("read").expect("frame");
                debug_assert_eq!(got.len(), body.len());
                seen += 1;
            }
        }
        n
    });

    // ---- read admission ---------------------------------------------
    bench(&mut out, "admission: scalar 256q x 64 limbo", || {
        let inp = AdmissionInputs {
            query_hashes: (0..256).map(hash_key).collect(),
            limbo_hashes: (0..64).map(hash_key).collect(),
            commit_age_us: 10,
            delta_us: 1_000_000,
            own_term_commit: false,
        };
        let mut total = 0u64;
        for _ in 0..2000 {
            total += scalar_admission(&inp).iter().filter(|&&b| b).count() as u64;
        }
        std::hint::black_box(total);
        2000 * 256
    });

    if Path::new("artifacts/manifest.json").exists() {
        match AdmissionEngine::load(Path::new("artifacts")) {
            Ok(engine) => {
                for (nq, nl) in [(64usize, 64usize), (256, 128), (1024, 256)] {
                    bench(&mut out, &format!("admission: XLA engine {nq}q x {nl} limbo"), || {
                        let inp = AdmissionInputs {
                            query_hashes: (0..nq as u32).map(hash_key).collect(),
                            limbo_hashes: (0..nl as u32).map(hash_key).collect(),
                            commit_age_us: 10,
                            delta_us: 1_000_000,
                            own_term_commit: false,
                        };
                        let reps = 200;
                        for _ in 0..reps {
                            let _ = engine.admit(&inp).unwrap();
                        }
                        (reps * nq) as u64
                    });
                }
            }
            Err(e) => println!("(XLA engine benches skipped: {e:#})"),
        }
    } else {
        println!("(XLA engine benches skipped: run `make artifacts`)");
    }

    bench(&mut out, "node: batched read admission path (limbo)", || {
        let p = Params::default();
        let mut node = limbo_leader(&p, 100, 0.5, 3);
        let ops: Vec<(u64, u32)> = (0..1024u64).map(|i| (i, (i % 1000) as u32)).collect();
        let now = TimeInterval::exact(1_200_000);
        let reps = 200;
        for _ in 0..reps {
            let _ = node.client_read_batch(now, &ops, |i| scalar_admission(i));
        }
        reps * ops.len() as u64
    });

    // ---- kv store read path -----------------------------------------
    // `Store::read` returns an Arc snapshot: a read clones a pointer,
    // never the value list. The baseline re-enacts the pre-refactor
    // behavior — one `Vec` deep copy per read — on the same store shape
    // (hot key holding a 1k-value list). The gap is the allocation.
    bench(&mut out, "kv: read 1k-value hot key (Arc snapshot)", || {
        let mut s = crate::kv::Store::new();
        for v in 0..1000u64 {
            s.apply(&Command::Put { key: 1, value: v, payload_bytes: 0 });
        }
        let n = 1_000_000u64;
        let mut total = 0u64;
        for _ in 0..n {
            let snap = s.read(1);
            total += snap.len() as u64;
        }
        assert_eq!(total, n * 1000);
        n
    });

    bench(&mut out, "kv: read 1k-value hot key (deep-copy baseline)", || {
        let mut s = crate::kv::Store::new();
        for v in 0..1000u64 {
            s.apply(&Command::Put { key: 1, value: v, payload_bytes: 0 });
        }
        let n = 100_000u64;
        let mut total = 0u64;
        for _ in 0..n {
            let copy: Vec<u64> = (*s.read(1)).clone();
            total += copy.len() as u64;
            std::hint::black_box(&copy);
        }
        assert_eq!(total, n * 1000);
        n
    });

    // ---- multi-Raft sharding ----------------------------------------
    // One process-set hosting 1 vs 8 groups over the same simulated
    // workload: aggregate events processed per wall-second. The per-op
    // work is unchanged; more groups spread the log/lease serialization.
    for groups in [1usize, 8] {
        bench(&mut out, &format!("sim: full availability run, {groups} group(s)"), || {
            let mut p = Params::default();
            p.consistency = ConsistencyMode::LeaseGuard;
            p.groups = groups;
            p.duration_us = 1_000_000;
            p.interarrival_us = 100.0;
            let rep = Cluster::new(p).run();
            rep.events_processed
        });
    }

    // ---- snapshots & recovery ---------------------------------------
    // The tentpole claim for log compaction: recovery cost tracks the
    // WAL *suffix*, not history length. Same 10k-entry history both
    // ways; the snapshot row replays 10x fewer records.
    {
        use crate::raft::log::Log;
        use crate::storage::{FsyncPolicy, Storage};

        let entry_at = |i: u64| Entry {
            term: 1,
            command: Command::Put { key: (i % 64) as u32, value: i, payload_bytes: 0 },
            written_at: TimeInterval::exact(i as i64),
        };
        // Dir A: 10k entries, no snapshot — recovery replays everything.
        let full = crate::testkit::TempDir::new("bench-recover-full");
        {
            let (mut s, _) = Storage::open(full.path(), FsyncPolicy::Never).expect("open");
            for i in 1..=10_000u64 {
                s.append(i, &entry_at(i)).expect("append");
            }
            s.sync().expect("sync");
        }
        // Dir B: same history, snapshot at 9000 — recovery replays 1k.
        let compacted = crate::testkit::TempDir::new("bench-recover-snap");
        {
            let (mut s, _) = Storage::open(compacted.path(), FsyncPolicy::Never).expect("open");
            let mut log = Log::default();
            let mut store = crate::kv::Store::new();
            for i in 1..=10_000u64 {
                let e = entry_at(i);
                log.append(e);
                s.append(i, &e).expect("append");
                if i <= 9_000 {
                    store.apply(&e.command);
                }
            }
            s.sync().expect("sync");
            log.compact_to(9_000);
            let snap = crate::snap::encode(
                &store,
                crate::snap::SnapMeta {
                    group: 0,
                    last_index: log.base(),
                    last_term: log.base_term(),
                    last_written_at: log.base_written_at(),
                    applied: store.applied(),
                },
            );
            s.install_snapshot(&snap, &log).expect("rotate");
        }
        bench(&mut out, "recovery: 10k-entry WAL, no snapshot (full replay)", || {
            let reps = 20u64;
            for _ in 0..reps {
                let (_, ds) = Storage::open(full.path(), FsyncPolicy::Never).expect("recover");
                assert_eq!(ds.log.last_index(), 10_000);
                assert_eq!(ds.log.base(), 0);
            }
            reps * 10_000
        });
        bench(&mut out, "recovery: 10k entries, snapshot@9000 + 1k suffix", || {
            let reps = 20u64;
            for _ in 0..reps {
                let (_, ds) = Storage::open(compacted.path(), FsyncPolicy::Never).expect("recover");
                assert_eq!(ds.log.last_index(), 10_000);
                assert_eq!(ds.log.base(), 9_000);
            }
            reps * 10_000
        });
    }

    bench(&mut out, "snap: encode 10k-key store", || {
        let mut store = crate::kv::Store::new();
        for k in 0..10_000u32 {
            store.apply(&Command::Put { key: k, value: k as u64, payload_bytes: 0 });
        }
        let meta = crate::snap::SnapMeta {
            group: 0,
            last_index: 10_000,
            last_term: 1,
            last_written_at: TimeInterval::exact(10_000),
            applied: 10_000,
        };
        let reps = 100u64;
        let mut bytes = 0u64;
        for _ in 0..reps {
            let snap = crate::snap::encode(&store, meta);
            bytes += snap.size() as u64;
        }
        std::hint::black_box(bytes);
        reps * 10_000 // keys encoded
    });

    bench(&mut out, "metrics: histogram record+p99", || {
        let mut h = Histogram::new();
        let mut r = Rng::new(1);
        let n = 2_000_000u64;
        for _ in 0..n {
            h.record(r.below(1_000_000) as i64);
        }
        assert!(h.p99() > 0);
        n
    });

    bench(&mut out, "wire: encode+decode 1KiB write req", || {
        let req = Frame::ClientReq(ClientReq {
            op: 1,
            key: 7,
            write_value: Some(9),
            payload: vec![0xA5; 1024],
        });
        let n = 100_000u64;
        for _ in 0..n {
            let enc = wire::encode(&req);
            let dec = wire::decode(&enc).unwrap();
            assert!(matches!(dec, Frame::ClientReq(_)));
        }
        n
    });

    out
}

/// Write results as `BENCH_micro.json` (or any path).
pub fn write_json(path: &Path, results: &[BenchResult]) -> std::io::Result<()> {
    let rows: Vec<(String, f64, u64)> =
        results.iter().map(|r| (r.name.clone(), r.ops_per_sec, r.ops_per_rep)).collect();
    report::write_bench_json(path, &rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fanout_leader_is_ready_for_measurement() {
        let (mut node, now) = fanout_leader(8);
        let outs = node.on_timer(now, TimerKind::Heartbeat);
        let batches: Vec<_> = outs
            .iter()
            .filter_map(|o| match o {
                Output::Send { msg: Message::AppendEntries { entries, .. }, .. } => Some(entries),
                _ => None,
            })
            .collect();
        assert_eq!(batches.len(), 4, "5-node fan-out sends to 4 peers");
        assert!(batches.iter().all(|b| b.len() == 8), "full batch to every peer");
        // Zero per-peer deep copies: all views share one allocation.
        assert!(batches.windows(2).all(|w| w[0].shares_buffer(w[1])));
    }
}
