//! The Nemesis scenario matrix: a standing catalog of fault scenarios,
//! each run against every consistency mode the matrix covers and
//! linearizability-checked (`leaseguard scenarios --json`).
//!
//! This is the machinery behind the ROADMAP's "as many scenarios as you
//! can imagine": a scenario is just a name + a [`NemesisSchedule`] + a
//! parameter tweak, so adding one is a three-line edit to [`catalog`]
//! (see DESIGN.md "Nemesis" for the recipe). Every scenario runs under
//! {LeaseGuard, Quorum, Inconsistent}: the first two *promise*
//! linearizability and the matrix fails loudly if a history violates
//! it; Inconsistent is the control — the checker reports whatever the
//! fault shape manages to expose.
//!
//! Determinism: a scenario run is a pure function of
//! `(scenario, mode, seed)` — same inputs, byte-identical history
//! (guarded by `nemesis_determinism_*` in `rust/tests/integration_sim.rs`).

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use crate::cluster::{Cluster, RunReport};
use crate::config::{ConsistencyMode, Params};
use crate::linearizability;
use crate::sim::nemesis::{Fault, NemesisSchedule};

/// One catalog entry: a named fault schedule plus parameter overrides.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub description: &'static str,
    pub schedule: NemesisSchedule,
    /// Overrides applied on top of the matrix base parameters.
    pub tune: fn(&mut Params),
}

/// Consistency modes every scenario runs under. The first two promise
/// linearizability under any fault schedule; Inconsistent does not.
pub const MATRIX_MODES: [ConsistencyMode; 3] = [
    ConsistencyMode::LeaseGuard,
    ConsistencyMode::Quorum,
    ConsistencyMode::Inconsistent,
];

fn no_tune(_: &mut Params) {}

/// The standing scenario catalog. Keep entries deterministic and short
/// enough that the whole matrix stays test-suite friendly.
pub fn catalog() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "leader-crash-restart",
            description: "leader crashes at 500ms, reboots 400ms later",
            schedule: NemesisSchedule::new()
                .at(500_000, Fault::CrashLeader { restart_after_us: Some(400_000) }),
            tune: no_tune,
        },
        Scenario {
            name: "repeated-leader-crash",
            description: "two leader crashes in one run; each victim reboots",
            schedule: NemesisSchedule::new()
                .at(400_000, Fault::CrashLeader { restart_after_us: Some(700_000) })
                .at(1_800_000, Fault::CrashLeader { restart_after_us: Some(700_000) }),
            tune: |p| p.duration_us = 3_200_000,
        },
        Scenario {
            name: "crash-quick-restart",
            description: "leader reboots faster than the election that replaces it",
            schedule: NemesisSchedule::new()
                .at(500_000, Fault::CrashLeader { restart_after_us: Some(150_000) }),
            tune: no_tune,
        },
        Scenario {
            name: "double-follower-crash",
            description: "5 nodes; two followers crash at the same instant",
            schedule: NemesisSchedule::new()
                .at(600_000, Fault::CrashFollower { restart_after_us: Some(700_000) })
                .at(600_000, Fault::CrashFollower { restart_after_us: Some(700_000) }),
            tune: |p| {
                p.nodes = 5;
                p.duration_us = 3_000_000;
            },
        },
        Scenario {
            name: "leader-partition-heal",
            description: "leader isolated from peers (clients still reach it), heals at 1.8s",
            schedule: NemesisSchedule::new()
                .at(500_000, Fault::PartitionLeader)
                .at(1_800_000, Fault::Heal),
            tune: |p| {
                p.client_stray_prob = 0.1;
                p.op_timeout_us = 300_000;
                p.duration_us = 3_000_000;
            },
        },
        Scenario {
            name: "minority-follower-partition",
            description: "node 2 partitioned away; rejoins with an inflated term at 1.6s",
            schedule: NemesisSchedule::new()
                .at(400_000, Fault::PartitionNodes(vec![2]))
                .at(1_600_000, Fault::Heal),
            tune: |p| p.duration_us = 3_000_000,
        },
        Scenario {
            name: "asymmetric-inbound-cut",
            description: "peers can hear the leader but the leader hears no acks",
            schedule: NemesisSchedule::new()
                .at(500_000, Fault::CutLeaderInbound)
                .at(1_500_000, Fault::Heal),
            tune: |p| p.op_timeout_us = 400_000,
        },
        Scenario {
            name: "dup-reorder-storm",
            description: "15% duplication + up-to-10ms reorder jitter for 1.5s",
            schedule: NemesisSchedule::new()
                .at(300_000, Fault::SetDuplicate(0.15))
                .window(300_000, 1_800_000, Fault::SetReorder(10_000)),
            tune: no_tune,
        },
        Scenario {
            name: "burst-loss",
            description: "30% message loss window during steady state",
            schedule: NemesisSchedule::new().window(500_000, 1_500_000, Fault::SetLoss(0.3)),
            tune: no_tune,
        },
        Scenario {
            name: "leader-clock-skew-spike",
            description: "leader's clock jumps +300ms (detected: bounds widen, stay correct)",
            schedule: NemesisSchedule::new().at(600_000, Fault::LeaderClockSkew(300_000)),
            tune: no_tune,
        },
        Scenario {
            name: "snapshot-catchup-leader-crash",
            description: "aggressive compaction; a lagging follower rejoins and the leader crashes during snapshot catch-up",
            schedule: NemesisSchedule::new()
                // The follower misses ~700ms of writes while the leader
                // compacts past its log, so its rejoin must go through
                // the chunked InstallSnapshot path...
                .at(500_000, Fault::CrashFollower { restart_after_us: Some(700_000) })
                // ...and the leader dies inside that catch-up window.
                // The new leader restarts the transfer from offset 0;
                // the partially-installed follower state must never
                // become visible.
                .at(1_260_000, Fault::CrashLeader { restart_after_us: Some(500_000) }),
            tune: |p| {
                p.snapshot_threshold = 16;
                p.interarrival_us = 300.0;
                p.duration_us = 3_000_000;
            },
        },
        Scenario {
            name: "planned-handover",
            description: "§5.1 drain: leader commits end-lease and steps down",
            schedule: NemesisSchedule::new().at(800_000, Fault::PlannedHandover),
            tune: no_tune,
        },
    ]
}

/// Matrix base parameters: start from the caller's `Params` (CLI
/// `--param` overrides included), then apply the matrix's standard
/// workload shape — short but failure-rich runs — to any knob the
/// caller left at its global default. Scenario `tune` functions apply
/// on top of this and always win.
fn matrix_base(user: &Params, mode: ConsistencyMode) -> Params {
    let d = Params::default();
    let mut p = user.clone();
    p.consistency = mode;
    if p.duration_us == d.duration_us {
        p.duration_us = 2_500_000;
    }
    if p.interarrival_us == d.interarrival_us {
        p.interarrival_us = 500.0;
    }
    if p.op_timeout_us == d.op_timeout_us {
        p.op_timeout_us = 1_000_000;
    }
    p
}

/// Effective parameters for one (scenario, mode) run.
fn scenario_params(sc: &Scenario, user: &Params, mode: ConsistencyMode) -> Params {
    let mut p = matrix_base(user, mode);
    (sc.tune)(&mut p);
    p
}

/// Per-(scenario, mode) results — availability, latency, and the
/// checker's verdict against what the mode promises.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    pub scenario: String,
    pub mode: ConsistencyMode,
    pub expect_linearizable: bool,
    pub violations: usize,
    pub reads_ok: u64,
    pub reads_failed: u64,
    pub writes_ok: u64,
    pub writes_failed: u64,
    pub read_p50_us: i64,
    pub read_p99_us: i64,
    pub write_p50_us: i64,
    pub write_p99_us: i64,
    pub elections: u64,
    pub faults_injected: u64,
    pub events_processed: u64,
}

impl ScenarioOutcome {
    /// Did this run honor its mode's promise? (Inconsistent promises
    /// nothing, so it can never fail the matrix.)
    pub fn ok(&self) -> bool {
        !self.expect_linearizable || self.violations == 0
    }
}

/// Run one scenario under one mode and return the full report (the
/// determinism guards compare raw histories across repeat runs).
pub fn run_report(sc: &Scenario, mode: ConsistencyMode, seed: u64) -> RunReport {
    let mut user = Params::default();
    user.seed = seed;
    run_report_from(sc, &user, mode)
}

/// As [`run_report`], honoring caller parameter overrides.
pub fn run_report_from(sc: &Scenario, user: &Params, mode: ConsistencyMode) -> RunReport {
    let p = scenario_params(sc, user, mode);
    Cluster::new(p).with_nemesis(sc.schedule.clone()).run()
}

/// Run one scenario under one mode, linearizability-checked.
pub fn run_one(sc: &Scenario, mode: ConsistencyMode, seed: u64) -> ScenarioOutcome {
    let mut user = Params::default();
    user.seed = seed;
    run_one_from(sc, &user, mode)
}

/// As [`run_one`], honoring caller parameter overrides.
///
/// When a mode that *promises* linearizability fails the check, the
/// flight-recorder window around the first violation is dumped to
/// stderr automatically — the matrix verdict arrives with its evidence
/// trail instead of a bare op id.
pub fn run_one_from(sc: &Scenario, user: &Params, mode: ConsistencyMode) -> ScenarioOutcome {
    let rep = run_report_from(sc, user, mode);
    let viol = linearizability::check(&rep.history);
    if mode != ConsistencyMode::Inconsistent {
        if let Some(v) = viol.first() {
            eprintln!("{}", dump_first_violation(sc.name, mode, &rep, v));
        }
    }
    let violations = viol.len();
    let reads = rep.series.window_totals(true, 0, i64::MAX);
    let writes = rep.series.window_totals(false, 0, i64::MAX);
    ScenarioOutcome {
        scenario: sc.name.to_string(),
        mode,
        expect_linearizable: mode != ConsistencyMode::Inconsistent,
        violations,
        reads_ok: reads.ok,
        reads_failed: reads.failed,
        writes_ok: writes.ok,
        writes_failed: writes.failed,
        read_p50_us: rep.read_latency.p50(),
        read_p99_us: rep.read_latency.p99(),
        write_p50_us: rep.write_latency.p50(),
        write_p99_us: rep.write_latency.p99(),
        elections: rep.elections,
        faults_injected: rep.faults_injected,
        events_processed: rep.events_processed,
    }
}

/// Padding around a violating op's `[start_ts, end_ts]` when slicing
/// flight-recorder dumps: wide enough to cover the election /
/// lease-handoff activity that produced the stale read.
const DUMP_PAD_US: i64 = 250_000;

/// Render the flight-recorder evidence for the first violation of a
/// run that promised linearizability (public so driver binaries and
/// integration tests can reuse the exact dump the matrix emits).
pub fn dump_first_violation(
    scenario: &str,
    mode: ConsistencyMode,
    rep: &RunReport,
    v: &linearizability::Violation,
) -> String {
    // The violation carries the op id; its history entry has the
    // real-time window the op occupied.
    let (from, to) = rep
        .history
        .entries
        .iter()
        .find(|e| e.op == v.op)
        .map(|e| (e.start_ts - DUMP_PAD_US, e.end_ts + DUMP_PAD_US))
        .unwrap_or((0, i64::MAX));
    let title =
        format!("{scenario}/{mode}: op {} key {} — {}", v.op, v.key, v.detail);
    rep.dump_flight_window(&title, from, to)
}

/// The full matrix: every catalog scenario × every matrix mode, in
/// deterministic order.
pub fn run_matrix(seed: u64) -> Vec<ScenarioOutcome> {
    let mut user = Params::default();
    user.seed = seed;
    run_matrix_from(&user)
}

/// As [`run_matrix`], honoring caller parameter overrides (the CLI's
/// `--param k=v` flags flow through here).
pub fn run_matrix_from(user: &Params) -> Vec<ScenarioOutcome> {
    let mut out = Vec::new();
    for sc in &catalog() {
        for &mode in MATRIX_MODES.iter() {
            out.push(run_one_from(sc, user, mode));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn violation_dump_covers_the_op_window() {
        // Exercise the exact dump path the matrix runs on failure: take
        // a real report, point a (fabricated) violation at one of its
        // ops, and require the evidence trail to cover that op's window.
        let sc = &catalog()[0];
        let rep = run_report(sc, ConsistencyMode::LeaseGuard, 42);
        let e = rep.history.entries.iter().find(|e| e.success).expect("a successful op");
        let v = linearizability::Violation {
            op: e.op,
            key: e.key,
            detail: "fabricated for dump test".to_string(),
        };
        let dump = dump_first_violation(sc.name, ConsistencyMode::LeaseGuard, &rep, &v);
        assert!(dump.contains("flight recorder dump"), "{dump}");
        assert!(dump.contains(&format!("op {}", e.op)), "{dump}");
        // Every node appears, labeled g<group>/n<process>.
        for n in 0..3 {
            assert!(dump.contains(&format!("g0/n{n}")), "missing node {n}:\n{dump}");
        }
        // The recorder was on (default params), so the crash+failover
        // window is not empty: some protocol event made it in.
        assert!(
            rep.recorders.iter().any(|r| r.total_recorded() > 0),
            "default-on recorder captured nothing"
        );
    }

    #[test]
    fn catalog_names_unique_and_plentiful() {
        let cat = catalog();
        assert!(cat.len() >= 8, "matrix needs >= 8 distinct fault scenarios");
        let mut names: Vec<&str> = cat.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), cat.len(), "scenario names must be unique");
        for sc in &cat {
            assert!(!sc.schedule.is_empty(), "{}: empty schedule", sc.name);
            scenario_params(sc, &Params::default(), ConsistencyMode::LeaseGuard)
                .validate()
                .unwrap_or_else(|e| panic!("{}: bad tuned params: {e}", sc.name));
        }
    }
}
