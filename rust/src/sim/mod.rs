//! Deterministic discrete-event simulation substrate (the paper's
//! `simulate.py`, §6.1).
//!
//! [`event_loop::EventQueue`] is a virtual-time priority queue with a
//! stable tie-break, so a run is a pure function of (parameters, seed) —
//! the reproducibility the paper "carefully engineered ... to ease
//! debugging and analysis". [`network::SimNetwork`] samples per-message
//! lognormal delays and models partitions, message loss, and node
//! crashes. The replica-set harness that drives Raft nodes over this
//! substrate lives in [`crate::cluster`].

pub mod event_loop;
pub mod network;

pub use event_loop::EventQueue;
pub use network::SimNetwork;
