//! Deterministic discrete-event simulation substrate (the paper's
//! `simulate.py`, §6.1).
//!
//! [`event_loop::EventQueue`] is a virtual-time priority queue with a
//! stable tie-break, so a run is a pure function of (parameters, seed) —
//! the reproducibility the paper "carefully engineered ... to ease
//! debugging and analysis". [`network::SimNetwork`] samples per-message
//! lognormal delays and models partitions (symmetric and asymmetric),
//! message loss/duplication/reordering, and node crashes with restart
//! epochs. [`nemesis::NemesisSchedule`] composes those faults into
//! deterministic timed schedules, and [`scenario`] keeps the standing
//! catalog the `leaseguard scenarios` matrix runs. The replica-set
//! harness that drives Raft nodes over this substrate lives in
//! [`crate::cluster`].

pub mod event_loop;
pub mod nemesis;
pub mod network;
pub mod scenario;

pub use event_loop::EventQueue;
pub use nemesis::{Fault, NemesisSchedule, TimedFault};
pub use network::SimNetwork;
