//! Nemesis: a deterministic, composable fault-injection schedule.
//!
//! Named for Jepsen's fault-injecting process of the same role, but
//! fully deterministic: a [`NemesisSchedule`] is a plain list of
//! [`TimedFault`]s relative to the experiment origin `t0` (the first
//! committed no-op). The cluster harness turns each into a timed event
//! on the same virtual-time [`crate::sim::EventQueue`] that drives the
//! protocol, so a run with a schedule is still a pure function of
//! `(params, schedule, seed)` — byte-identical on replay.
//!
//! Faults that name a *role* rather than a node id (`CrashLeader`,
//! `PartitionLeader`, `CutLeaderInbound`, …) resolve against live
//! cluster state at fire time — the leader at 1.5 s may not be the
//! leader that existed when the schedule was written, and repeated
//! leader-targeting faults chase whoever currently leads.
//!
//! The taxonomy (see DESIGN.md "Nemesis" for the full table):
//!
//! * **process** — crash + optional restart, of the leader or a
//!   follower; planned §5.1 handover;
//! * **network** — symmetric partitions, *asymmetric* directed link
//!   cuts, and chaos windows (duplication, burst loss, reorder jitter)
//!   applied/cleared as paired events;
//! * **clock** — per-node skew spikes and drift changes via
//!   [`crate::clock::sim::SimClock`].

use crate::{Micros, NodeId};

/// One injectable fault. Role-relative variants resolve at fire time.
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// Crash the current (highest-term live) leader; optionally restart
    /// it after a delay. No-op if no live leader exists at fire time.
    CrashLeader { restart_after_us: Option<Micros> },
    /// Crash the lowest-id live non-leader; optional restart. Two such
    /// faults at the same instant crash two distinct followers.
    CrashFollower { restart_after_us: Option<Micros> },
    /// Crash a specific node (no-op if already down); optional restart.
    CrashNode { node: NodeId, restart_after_us: Option<Micros> },
    /// Symmetric partition: isolate the current leader from all peers.
    /// Clients still reach it — the §1 deposed-leader scenario.
    PartitionLeader,
    /// Symmetric partition: isolate this node set from the rest.
    PartitionNodes(Vec<NodeId>),
    /// Asymmetric partition: cut every peer→leader link, leaving
    /// leader→peer delivery intact. The leader keeps replicating (so no
    /// election fires) but can never observe an ack.
    CutLeaderInbound,
    /// Heal all partitions and directed link cuts.
    Heal,
    /// Begin a message-duplication window (probability per message).
    SetDuplicate(f64),
    /// Begin a burst-loss window (extra drop probability per message).
    SetLoss(f64),
    /// Begin a reorder window: uniform extra delay in `[0, extra_us]`
    /// per message, enough to reorder back-to-back sends on a link.
    SetReorder(Micros),
    /// End every chaos window (duplication, loss, reorder).
    ClearChaos,
    /// Skew-spike the current leader's clock by `offset_us` (detected
    /// by the clock daemon: bounds widen, stay correct — §4.3's
    /// *undetected* variant is `Params::clock_broken`).
    LeaderClockSkew(Micros),
    /// Skew-spike a specific node's clock.
    NodeClockSkew { node: NodeId, offset_us: Micros },
    /// Change a specific node's oscillator drift rate (per-node drift
    /// divergence).
    SetNodeDrift { node: NodeId, drift: f64 },
    /// §5.1 planned handover: the current leader commits an end-lease
    /// entry and steps down once it commits.
    PlannedHandover,
}

/// A fault at an instant relative to the experiment origin `t0`.
#[derive(Debug, Clone, PartialEq)]
pub struct TimedFault {
    pub at: Micros,
    pub fault: Fault,
}

/// An ordered fault schedule (builder-style construction).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NemesisSchedule {
    pub events: Vec<TimedFault>,
}

impl NemesisSchedule {
    pub fn new() -> Self {
        NemesisSchedule::default()
    }

    /// Append `fault` at `at` (µs after t0).
    pub fn at(mut self, at: Micros, fault: Fault) -> Self {
        self.events.push(TimedFault { at, fault });
        self
    }

    /// Chaos-window helper: apply `fault` at `from`, clear every chaos
    /// knob at `to`.
    pub fn window(self, from: Micros, to: Micros, fault: Fault) -> Self {
        self.at(from, fault).at(to, Fault::ClearChaos)
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_orders_and_windows() {
        let s = NemesisSchedule::new()
            .at(500_000, Fault::CrashLeader { restart_after_us: Some(400_000) })
            .window(300_000, 1_800_000, Fault::SetLoss(0.3));
        assert_eq!(s.len(), 3);
        assert_eq!(s.events[0].at, 500_000);
        assert_eq!(s.events[1].fault, Fault::SetLoss(0.3));
        assert_eq!(s.events[2], TimedFault { at: 1_800_000, fault: Fault::ClearChaos });
    }

    #[test]
    fn schedules_compare_structurally() {
        let a = NemesisSchedule::new().at(1, Fault::Heal);
        let b = NemesisSchedule::new().at(1, Fault::Heal);
        assert_eq!(a, b);
        assert!(NemesisSchedule::new().is_empty());
    }
}
