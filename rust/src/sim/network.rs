//! Simulated network: per-message lognormal delay, partitions, loss, and
//! node liveness (paper §6.1: "Nodes communicate via message-passing,
//! with random network delays").
//!
//! The network itself is policy-only — it decides *whether* and *when* a
//! message arrives; the cluster harness owns the event queue and actually
//! schedules the delivery. Keeping the two separate makes the policy unit
//! -testable without running a simulation. It also keeps this layer
//! payload-agnostic: message bodies (including Arc-backed shared entry
//! batches) move through the event queue untouched, so delivery cost is
//! independent of batch size.

use crate::prob::{LogNormal, Rng};
use crate::{Micros, NodeId};

#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Mean one-way delay, µs. Paper Fig 6 sweeps 1–10 ms; Fig 7 uses the
    /// AWS same-subnet fit (mean 191 µs, variance 391 µs²).
    pub one_way_mean_us: f64,
    /// Variance of the one-way delay, µs².
    pub one_way_variance_us2: f64,
    /// Propagation floor, µs (no message arrives faster than this).
    pub min_delay_us: Micros,
    /// Probability a message is silently dropped (outside partitions).
    pub loss: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        // AWS same-subnet latency fit from §6.5 / [23].
        NetConfig {
            one_way_mean_us: 191.0,
            one_way_variance_us2: 391.0,
            min_delay_us: 20,
            loss: 0.0,
        }
    }
}

impl NetConfig {
    /// Paper Fig 6 parameterization: lognormal with variance = mean,
    /// mean given in milliseconds.
    pub fn wan_ms(mean_ms: f64) -> Self {
        let mean_us = mean_ms * 1000.0;
        NetConfig {
            one_way_mean_us: mean_us,
            // "variance equal to the mean" (in ms²) → scale to µs².
            one_way_variance_us2: mean_ms * 1_000_000.0,
            min_delay_us: 50,
            loss: 0.0,
        }
    }
}

/// Verdict for one message send.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Delivery {
    /// Deliver after this one-way delay (µs).
    After(Micros),
    /// Silently dropped (partition, crash, or random loss).
    Dropped,
}

#[derive(Debug)]
pub struct SimNetwork {
    cfg: NetConfig,
    dist: LogNormal,
    rng: Rng,
    /// Partition group id per node; messages cross groups only if healed.
    group: Vec<u8>,
    /// Node liveness — a crashed node neither sends nor receives.
    up: Vec<bool>,
}

impl SimNetwork {
    pub fn new(n: usize, cfg: NetConfig, rng: &mut Rng) -> Self {
        let dist = LogNormal::from_mean_variance(
            cfg.one_way_mean_us.max(1.0),
            cfg.one_way_variance_us2.max(0.0),
        );
        SimNetwork { cfg, dist, rng: rng.fork(), group: vec![0; n], up: vec![true; n] }
    }

    /// Decide the fate of one message from `from` to `to`.
    pub fn send(&mut self, from: NodeId, to: NodeId) -> Delivery {
        if !self.up[from] || !self.up[to] {
            return Delivery::Dropped;
        }
        if self.group[from] != self.group[to] {
            return Delivery::Dropped;
        }
        if self.cfg.loss > 0.0 && self.rng.chance(self.cfg.loss) {
            return Delivery::Dropped;
        }
        let d = self.dist.sample(&mut self.rng) as Micros;
        Delivery::After(d.max(self.cfg.min_delay_us))
    }

    /// Partition the cluster: nodes in `minority` lose contact with the
    /// rest (e.g. an old leader on the wrong side of a partition, §1).
    pub fn partition(&mut self, minority: &[NodeId]) {
        for &n in minority {
            self.group[n] = 1;
        }
    }

    /// Heal all partitions.
    pub fn heal(&mut self) {
        for g in self.group.iter_mut() {
            *g = 0;
        }
    }

    pub fn crash(&mut self, node: NodeId) {
        self.up[node] = false;
    }

    pub fn restart(&mut self, node: NodeId) {
        self.up[node] = true;
    }

    pub fn is_up(&self, node: NodeId) -> bool {
        self.up[node]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(cfg: NetConfig) -> SimNetwork {
        SimNetwork::new(3, cfg, &mut Rng::new(1))
    }

    #[test]
    fn delays_positive_and_near_mean() {
        let mut n = net(NetConfig::default());
        let mut sum = 0i64;
        let k = 20_000;
        for _ in 0..k {
            match n.send(0, 1) {
                Delivery::After(d) => {
                    assert!(d >= 20);
                    sum += d;
                }
                Delivery::Dropped => panic!("no loss configured"),
            }
        }
        let mean = sum as f64 / k as f64;
        assert!((mean - 191.0).abs() < 10.0, "mean {mean}");
    }

    #[test]
    fn partition_blocks_cross_group_only() {
        let mut n = net(NetConfig::default());
        n.partition(&[2]);
        assert_eq!(n.send(0, 2), Delivery::Dropped);
        assert_eq!(n.send(2, 1), Delivery::Dropped);
        assert!(matches!(n.send(0, 1), Delivery::After(_)));
        n.heal();
        assert!(matches!(n.send(0, 2), Delivery::After(_)));
    }

    #[test]
    fn crashed_node_isolated() {
        let mut n = net(NetConfig::default());
        n.crash(1);
        assert_eq!(n.send(0, 1), Delivery::Dropped);
        assert_eq!(n.send(1, 0), Delivery::Dropped);
        n.restart(1);
        assert!(matches!(n.send(0, 1), Delivery::After(_)));
    }

    #[test]
    fn loss_rate_respected() {
        let mut cfg = NetConfig::default();
        cfg.loss = 0.25;
        let mut n = net(cfg);
        let mut dropped = 0;
        let k = 40_000;
        for _ in 0..k {
            if n.send(0, 1) == Delivery::Dropped {
                dropped += 1;
            }
        }
        let rate = dropped as f64 / k as f64;
        assert!((rate - 0.25).abs() < 0.02, "loss rate {rate}");
    }

    #[test]
    fn wan_parameterization() {
        let mut n = net(NetConfig::wan_ms(5.0));
        let mut sum = 0i64;
        let k = 20_000;
        for _ in 0..k {
            if let Delivery::After(d) = n.send(0, 1) {
                sum += d;
            }
        }
        let mean_ms = sum as f64 / k as f64 / 1000.0;
        assert!((mean_ms - 5.0).abs() < 0.3, "mean {mean_ms}ms");
    }
}
