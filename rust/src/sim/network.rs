//! Simulated network: per-message lognormal delay, partitions, loss, and
//! node liveness (paper §6.1: "Nodes communicate via message-passing,
//! with random network delays").
//!
//! The network itself is policy-only — it decides *whether* and *when* a
//! message arrives; the cluster harness owns the event queue and actually
//! schedules the delivery. Keeping the two separate makes the policy unit
//! -testable without running a simulation. It also keeps this layer
//! payload-agnostic: message bodies (including Arc-backed shared entry
//! batches) move through the event queue untouched, so delivery cost is
//! independent of batch size.
//!
//! Fault surface (driven by [`crate::sim::nemesis`]):
//!
//! * **Directed link cuts** — a per-link `blocked` matrix, so partitions
//!   can be *asymmetric* (A reaches B while B cannot reach A), the fault
//!   shape symmetric group-based models can never produce.
//! * **Chaos windows** — extra burst loss, message duplication, and
//!   reorder jitter, each toggled independently.
//! * **Crash epochs** — every crash bumps the node's epoch. The harness
//!   stamps each queued delivery with the destination's epoch at send
//!   time and drops stale ones at delivery time, so a message queued
//!   before a crash can never arrive after the restart.

use crate::prob::{LogNormal, Rng};
use crate::{Micros, NodeId};

#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Mean one-way delay, µs. Paper Fig 6 sweeps 1–10 ms; Fig 7 uses the
    /// AWS same-subnet fit (mean 191 µs, variance 391 µs²).
    pub one_way_mean_us: f64,
    /// Variance of the one-way delay, µs².
    pub one_way_variance_us2: f64,
    /// Propagation floor, µs (no message arrives faster than this).
    pub min_delay_us: Micros,
    /// Probability a message is silently dropped (outside partitions).
    pub loss: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        // AWS same-subnet latency fit from §6.5 / [23].
        NetConfig {
            one_way_mean_us: 191.0,
            one_way_variance_us2: 391.0,
            min_delay_us: 20,
            loss: 0.0,
        }
    }
}

impl NetConfig {
    /// Paper Fig 6 parameterization: lognormal with variance = mean,
    /// mean given in milliseconds.
    pub fn wan_ms(mean_ms: f64) -> Self {
        let mean_us = mean_ms * 1000.0;
        NetConfig {
            one_way_mean_us: mean_us,
            // "variance equal to the mean" (in ms²) → scale to µs².
            one_way_variance_us2: mean_ms * 1_000_000.0,
            min_delay_us: 50,
            loss: 0.0,
        }
    }
}

/// Wire throughput for sized sends (≈1 Gbit/s): a sized message adds
/// `bytes / BYTES_PER_US` µs of serialization delay on top of the
/// sampled propagation delay. Only snapshot-chunk transfers are sized;
/// ordinary RPCs stay payload-agnostic (see module docs).
pub const BYTES_PER_US: usize = 125;

/// Verdict for one message send.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Delivery {
    /// Deliver after this one-way delay (µs).
    After(Micros),
    /// Duplication window: deliver two copies, one per delay.
    Twice(Micros, Micros),
    /// Silently dropped (partition, crash, or random loss).
    Dropped,
}

#[derive(Debug)]
pub struct SimNetwork {
    cfg: NetConfig,
    dist: LogNormal,
    rng: Rng,
    n: usize,
    /// Directed link cuts: `blocked[from * n + to]` drops from→to only.
    blocked: Vec<bool>,
    /// Node liveness — a crashed node neither sends nor receives.
    up: Vec<bool>,
    /// Crash epoch per node, bumped on every crash. Deliveries queued
    /// under an older epoch are stale and must be dropped.
    epoch: Vec<u64>,
    /// Chaos windows (all zero outside a Nemesis window; when zero the
    /// RNG draw sequence is identical to the pre-chaos implementation,
    /// preserving byte-for-byte determinism of existing seeds).
    dup_prob: f64,
    extra_loss: f64,
    reorder_extra_us: Micros,
}

impl SimNetwork {
    pub fn new(n: usize, cfg: NetConfig, rng: &mut Rng) -> Self {
        let dist = LogNormal::from_mean_variance(
            cfg.one_way_mean_us.max(1.0),
            cfg.one_way_variance_us2.max(0.0),
        );
        SimNetwork {
            cfg,
            dist,
            rng: rng.fork(),
            n,
            blocked: vec![false; n * n],
            up: vec![true; n],
            epoch: vec![0; n],
            dup_prob: 0.0,
            extra_loss: 0.0,
            reorder_extra_us: 0,
        }
    }

    /// Decide the fate of one *sized* message (snapshot chunks): same
    /// policy as [`Self::send`] plus a serialization delay of
    /// `bytes / BYTES_PER_US` µs on each copy, so a multi-chunk
    /// transfer occupies simulated time proportional to its size and a
    /// Nemesis fault can land mid-transfer. Draws exactly the RNG
    /// sequence [`Self::send`] draws — the extra delay is arithmetic —
    /// so runs that never send sized messages (compaction off) replay
    /// byte-identically.
    pub fn send_sized(&mut self, from: NodeId, to: NodeId, bytes: usize) -> Delivery {
        let ser = (bytes / BYTES_PER_US) as Micros;
        match self.send(from, to) {
            Delivery::After(d) => Delivery::After(d + ser),
            Delivery::Twice(a, b) => Delivery::Twice(a + ser, b + ser),
            Delivery::Dropped => Delivery::Dropped,
        }
    }

    /// Decide the fate of one message from `from` to `to`.
    pub fn send(&mut self, from: NodeId, to: NodeId) -> Delivery {
        if !self.up[from] || !self.up[to] {
            return Delivery::Dropped;
        }
        if self.blocked[from * self.n + to] {
            return Delivery::Dropped;
        }
        if self.cfg.loss > 0.0 && self.rng.chance(self.cfg.loss) {
            return Delivery::Dropped;
        }
        if self.extra_loss > 0.0 && self.rng.chance(self.extra_loss) {
            return Delivery::Dropped;
        }
        let d = self.sample_delay();
        if self.dup_prob > 0.0 && self.rng.chance(self.dup_prob) {
            return Delivery::Twice(d, self.sample_delay());
        }
        Delivery::After(d)
    }

    fn sample_delay(&mut self) -> Micros {
        let mut d = (self.dist.sample(&mut self.rng) as Micros).max(self.cfg.min_delay_us);
        if self.reorder_extra_us > 0 {
            // Uniform extra jitter ≥ the base spread ⇒ frequent pairwise
            // reordering of back-to-back messages on the same link.
            d += self.rng.below(self.reorder_extra_us as u64 + 1) as Micros;
        }
        d
    }

    /// Partition the cluster: nodes in `minority` lose contact with the
    /// rest, both directions (e.g. an old leader on the wrong side of a
    /// partition, §1).
    pub fn partition(&mut self, minority: &[NodeId]) {
        for a in 0..self.n {
            for b in 0..self.n {
                if minority.contains(&a) != minority.contains(&b) {
                    self.blocked[a * self.n + b] = true;
                }
            }
        }
    }

    /// Cut one directed link: messages from→to are dropped; to→from is
    /// untouched (asymmetric/partial partition).
    pub fn cut_link(&mut self, from: NodeId, to: NodeId) {
        self.blocked[from * self.n + to] = true;
    }

    /// Heal all partitions and directed link cuts.
    pub fn heal(&mut self) {
        for b in self.blocked.iter_mut() {
            *b = false;
        }
    }

    /// Chaos-window knobs (Nemesis).
    pub fn set_duplicate(&mut self, prob: f64) {
        self.dup_prob = prob;
    }
    pub fn set_loss(&mut self, prob: f64) {
        self.extra_loss = prob;
    }
    pub fn set_reorder(&mut self, extra_us: Micros) {
        self.reorder_extra_us = extra_us.max(0);
    }
    /// End every chaos window (duplication, burst loss, reordering).
    pub fn clear_chaos(&mut self) {
        self.dup_prob = 0.0;
        self.extra_loss = 0.0;
        self.reorder_extra_us = 0;
    }

    pub fn crash(&mut self, node: NodeId) {
        self.up[node] = false;
        self.epoch[node] += 1;
    }

    pub fn restart(&mut self, node: NodeId) {
        self.up[node] = true;
    }

    pub fn is_up(&self, node: NodeId) -> bool {
        self.up[node]
    }

    /// Current crash epoch of `node` (see module docs). A delivery
    /// stamped with an older epoch was queued before the node's latest
    /// crash and must not be delivered.
    pub fn epoch(&self, node: NodeId) -> u64 {
        self.epoch[node]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(cfg: NetConfig) -> SimNetwork {
        SimNetwork::new(3, cfg, &mut Rng::new(1))
    }

    #[test]
    fn delays_positive_and_near_mean() {
        let mut n = net(NetConfig::default());
        let mut sum = 0i64;
        let k = 20_000;
        for _ in 0..k {
            match n.send(0, 1) {
                Delivery::After(d) => {
                    assert!(d >= 20);
                    sum += d;
                }
                _ => panic!("no loss or duplication configured"),
            }
        }
        let mean = sum as f64 / k as f64;
        assert!((mean - 191.0).abs() < 10.0, "mean {mean}");
    }

    #[test]
    fn sized_send_adds_serialization_delay_only() {
        // Two networks, same seed: a sized send must land exactly
        // bytes/BYTES_PER_US later than the unsized send would have,
        // consuming the identical RNG stream.
        let mut a = net(NetConfig::default());
        let mut b = net(NetConfig::default());
        for i in 0..1000usize {
            let bytes = (i % 3) * crate::snap::SNAP_CHUNK_BYTES;
            let plain = a.send(0, 1);
            let sized = b.send_sized(0, 1, bytes);
            match (plain, sized) {
                (Delivery::After(d), Delivery::After(s)) => {
                    assert_eq!(s, d + (bytes / BYTES_PER_US) as Micros);
                }
                (p, s) => panic!("verdicts diverged: {p:?} vs {s:?}"),
            }
        }
        // A full 16 KiB chunk costs ~131 µs of line time.
        assert_eq!(crate::snap::SNAP_CHUNK_BYTES / BYTES_PER_US, 131);
    }

    #[test]
    fn partition_blocks_cross_group_only() {
        let mut n = net(NetConfig::default());
        n.partition(&[2]);
        assert_eq!(n.send(0, 2), Delivery::Dropped);
        assert_eq!(n.send(2, 1), Delivery::Dropped);
        assert!(matches!(n.send(0, 1), Delivery::After(_)));
        n.heal();
        assert!(matches!(n.send(0, 2), Delivery::After(_)));
    }

    #[test]
    fn asymmetric_cut_is_one_directional() {
        let mut n = net(NetConfig::default());
        n.cut_link(0, 1);
        assert_eq!(n.send(0, 1), Delivery::Dropped);
        assert!(matches!(n.send(1, 0), Delivery::After(_)), "reverse direction open");
        assert!(matches!(n.send(0, 2), Delivery::After(_)), "other links open");
        n.heal();
        assert!(matches!(n.send(0, 1), Delivery::After(_)));
    }

    #[test]
    fn crashed_node_isolated() {
        let mut n = net(NetConfig::default());
        n.crash(1);
        assert_eq!(n.send(0, 1), Delivery::Dropped);
        assert_eq!(n.send(1, 0), Delivery::Dropped);
        n.restart(1);
        assert!(matches!(n.send(0, 1), Delivery::After(_)));
    }

    #[test]
    fn crash_bumps_epoch_restart_does_not() {
        let mut n = net(NetConfig::default());
        assert_eq!(n.epoch(1), 0);
        n.crash(1);
        assert_eq!(n.epoch(1), 1);
        n.restart(1);
        assert_eq!(n.epoch(1), 1, "epoch identifies the incarnation's crash count");
        n.crash(1);
        assert_eq!(n.epoch(1), 2);
        assert_eq!(n.epoch(0), 0, "other nodes unaffected");
    }

    #[test]
    fn loss_rate_respected() {
        let mut cfg = NetConfig::default();
        cfg.loss = 0.25;
        let mut n = net(cfg);
        let mut dropped = 0;
        let k = 40_000;
        for _ in 0..k {
            if n.send(0, 1) == Delivery::Dropped {
                dropped += 1;
            }
        }
        let rate = dropped as f64 / k as f64;
        assert!((rate - 0.25).abs() < 0.02, "loss rate {rate}");
    }

    #[test]
    fn burst_loss_window_compounds_and_clears() {
        let mut n = net(NetConfig::default());
        n.set_loss(0.5);
        let k = 20_000;
        let dropped = (0..k).filter(|_| n.send(0, 1) == Delivery::Dropped).count();
        let rate = dropped as f64 / k as f64;
        assert!((rate - 0.5).abs() < 0.03, "window loss rate {rate}");
        n.clear_chaos();
        for _ in 0..2000 {
            assert!(matches!(n.send(0, 1), Delivery::After(_)));
        }
    }

    #[test]
    fn duplication_window_emits_two_copies() {
        let mut n = net(NetConfig::default());
        n.set_duplicate(0.3);
        let k = 20_000;
        let mut dups = 0;
        for _ in 0..k {
            match n.send(0, 1) {
                Delivery::Twice(a, b) => {
                    assert!(a >= 20 && b >= 20);
                    dups += 1;
                }
                Delivery::After(_) => {}
                Delivery::Dropped => panic!("no loss configured"),
            }
        }
        let rate = dups as f64 / k as f64;
        assert!((rate - 0.3).abs() < 0.02, "dup rate {rate}");
        n.clear_chaos();
        assert!(matches!(n.send(0, 1), Delivery::After(_)));
    }

    #[test]
    fn reorder_window_adds_bounded_jitter() {
        let mut base = net(NetConfig::default());
        let mut jittered = net(NetConfig::default());
        jittered.set_reorder(10_000);
        let k = 10_000;
        let sum = |n: &mut SimNetwork| -> i64 {
            (0..k)
                .map(|_| match n.send(0, 1) {
                    Delivery::After(d) => d,
                    _ => 0,
                })
                .sum()
        };
        let mean_base = sum(&mut base) as f64 / k as f64;
        let mean_jit = sum(&mut jittered) as f64 / k as f64;
        // Uniform [0, 10ms] adds ~5ms on average.
        assert!(
            (mean_jit - mean_base - 5_000.0).abs() < 500.0,
            "base {mean_base} jittered {mean_jit}"
        );
    }

    #[test]
    fn wan_parameterization() {
        let mut n = net(NetConfig::wan_ms(5.0));
        let mut sum = 0i64;
        let k = 20_000;
        for _ in 0..k {
            if let Delivery::After(d) = n.send(0, 1) {
                sum += d;
            }
        }
        let mean_ms = sum as f64 / k as f64 / 1000.0;
        assert!((mean_ms - 5.0).abs() < 0.3, "mean {mean_ms}ms");
    }
}
