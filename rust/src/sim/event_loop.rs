//! Virtual-time event queue: the core of the deterministic simulator.
//!
//! The paper's event loop "finds the callback with the earliest deadline,
//! advances the simulated clock to exactly that deadline, executes the
//! callback (which may schedule more callbacks), then repeats" (§6.1).
//! Rust has no need for coroutines here: domain code (the cluster
//! harness) pops typed events and dispatches them, which keeps the whole
//! simulation single-threaded, allocation-light, and bit-reproducible.
//!
//! Ties are broken by insertion sequence number, so two events scheduled
//! for the same virtual instant always execute in the order they were
//! scheduled — the property that makes runs a pure function of the seed.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::Micros;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct Key {
    at: Micros,
    seq: u64,
}

#[derive(Debug)]
struct Scheduled<E> {
    key: Key,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

/// A deterministic virtual-time event queue.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Scheduled<E>>>,
    now: Micros,
    seq: u64,
    processed: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0, seq: 0, processed: 0 }
    }

    /// Current virtual time (µs). Advances only inside [`Self::pop`].
    #[inline]
    pub fn now(&self) -> Micros {
        self.now
    }

    /// Total events executed so far (perf counter).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `event` at absolute virtual time `at`. Scheduling in the
    /// past is clamped to `now` (it will run next, in schedule order).
    pub fn schedule(&mut self, at: Micros, event: E) {
        let at = at.max(self.now);
        let key = Key { at, seq: self.seq };
        self.seq += 1;
        self.heap.push(Reverse(Scheduled { key, event }));
    }

    /// Schedule `event` after a relative delay.
    pub fn schedule_in(&mut self, delay: Micros, event: E) {
        self.schedule(self.now + delay.max(0), event);
    }

    /// Pop the earliest event, advancing virtual time to its deadline.
    pub fn pop(&mut self) -> Option<(Micros, E)> {
        let Reverse(s) = self.heap.pop()?;
        debug_assert!(s.key.at >= self.now, "time went backwards");
        self.now = s.key.at;
        self.processed += 1;
        Some((s.key.at, s.event))
    }

    /// Deadline of the earliest pending event.
    pub fn peek_deadline(&self) -> Option<Micros> {
        self.heap.peek().map(|Reverse(s)| s.key.at)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30, "c");
        q.schedule(10, "a");
        q.schedule(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.now(), 10);
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_by_schedule_order() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule(5, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((5, i)));
        }
    }

    #[test]
    fn past_scheduling_clamped_to_now() {
        let mut q = EventQueue::new();
        q.schedule(100, 1);
        q.pop();
        q.schedule(50, 2); // in the past
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (100, 2));
        assert_eq!(q.now(), 100);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(1000, 0);
        q.pop();
        q.schedule_in(500, 1);
        assert_eq!(q.pop(), Some((1500, 1)));
    }

    #[test]
    fn interleaved_scheduling_deterministic() {
        // Two runs with identical operations produce identical sequences.
        let run = || {
            let mut q = EventQueue::new();
            let mut out = Vec::new();
            q.schedule(0, 0u64);
            let mut next = 1u64;
            while let Some((t, e)) = q.pop() {
                out.push((t, e));
                if out.len() > 1000 {
                    break;
                }
                // Each event spawns two more, same deadline + offsets.
                if next < 500 {
                    q.schedule(t + 7, next);
                    next += 1;
                    q.schedule(t + 7, next);
                    next += 1;
                }
            }
            out
        };
        assert_eq!(run(), run());
    }
}
