// Lint fixture (never compiled): the waivered twin of r4_snap_bad.rs.
// (Real snap/mod.rs has zero waivers: the codec is fully panic-free
// via the Dec cursor. The waiver form exists for hypothetical
// fixed-size trusted prefixes.)

pub fn decode_magic(b: &[u8; 4]) -> u8 {
    // lint:allow(R4): fixed-size array ref, bound checked by the type, fixture only
    let first = b[0];
    first
}
