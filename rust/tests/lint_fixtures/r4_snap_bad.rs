// Lint fixture (never compiled): R4 must flag panic paths in the
// snapshot codec too — InstallSnapshot chunks are untrusted wire
// bytes, exactly like server/wire.rs frames. Linted under
// `snap/mod.rs`.

pub fn decode_snapshot_header(b: &[u8]) -> (u32, u64) {
    let magic = u32::from_le_bytes(b[0..4].try_into().unwrap());
    if magic != 0x4E53_474C {
        panic!("bad snapshot magic {magic:#x}");
    }
    let last_index = b.get(5..13).expect("boundary index");
    let _ = last_index;
    (magic, 0)
}
