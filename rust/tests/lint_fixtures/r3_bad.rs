// Lint fixture (never compiled): R3 must flag ambient RNG anywhere in
// the tree. Linted under `metrics.rs`.

pub fn jitter() -> u64 {
    let mut rng = thread_rng();
    let x: u64 = rand::random();
    let s = std::collections::hash_map::RandomState::new();
    let _ = (&mut rng, s);
    x
}
