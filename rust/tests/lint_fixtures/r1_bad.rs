// Lint fixture (never compiled): R1 must flag a wall-clock read in a
// protocol path. Linted under the logical path `raft/tick.rs`.

pub fn election_deadline_us(timeout_us: i64) -> i64 {
    let t0 = std::time::Instant::now();
    let _ = t0;
    let wall = std::time::SystemTime::now();
    let _ = wall;
    timeout_us
}
