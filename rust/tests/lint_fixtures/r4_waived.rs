// Lint fixture (never compiled): the waivered twin of r4_bad.rs.
// (Real wire.rs has zero waivers: decode is fully panic-free. The
// waiver form exists for hypothetical trusted-prefix fast paths.)

pub fn decode_header(b: &[u8; 8]) -> u8 {
    // lint:allow(R4): fixed-size array ref, bound checked by the type, fixture only
    let magic = b[0];
    magic
}
