// Lint fixture (never compiled): R4 must flag panic paths in the
// untrusted-byte decoder. Linted under `server/wire.rs`.

pub fn decode_header(b: &[u8]) -> (u8, u32) {
    let magic = b[0];
    let len = u32::from_le_bytes(b[1..5].try_into().unwrap());
    if magic != 0xA7 {
        panic!("bad magic {magic}");
    }
    let tail = b.get(5).copied().expect("tail byte");
    let _ = tail;
    (magic, len)
}
