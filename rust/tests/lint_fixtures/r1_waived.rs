// Lint fixture (never compiled): the waivered twin of r1_bad.rs —
// every wall-clock read carries a reasoned waiver, so the file passes.

pub fn election_deadline_us(timeout_us: i64) -> i64 {
    // lint:allow(R1): fixture demonstrating a documented wall-clock exception
    let t0 = std::time::Instant::now();
    let _ = t0;
    let wall = std::time::SystemTime::now(); // lint:allow(R1): trailing-comment waiver form
    let _ = wall;
    timeout_us
}
