// Lint fixture (never compiled): the waivered twin of r2_bad.rs.
// (In real code the fix is BTreeMap or collect-and-sort; the waiver
// form exists for sites where order provably cannot leak.)

use std::collections::HashMap;

pub struct Tally {
    counts: HashMap<u32, u64>,
}

impl Tally {
    pub fn sum(&self) -> u64 {
        let mut acc = 0;
        // lint:allow(R2): summation is order-insensitive; nothing ordered escapes
        for (_k, v) in self.counts.iter() {
            acc += v;
        }
        acc
    }
}
