// Lint fixture (never compiled): the waivered twin of r5_bad.rs —
// persist-before-route alternation is clean, and the one direct
// write_frame carries a reasoned waiver.

fn main_loop(router: &mut Router, shards: &mut Shards) {
    loop {
        let mut pending = collect_outputs(shards);
        persist_all(shards);
        router.handle(&mut pending);
        // lint:allow(R5): read-only introspection reply, nothing to persist first
        write_frame(stream, &bytes);
    }
}
