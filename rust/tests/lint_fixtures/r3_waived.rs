// Lint fixture (never compiled): the waivered twin of r3_bad.rs.

pub fn jitter() -> u64 {
    // lint:allow(R3): fixture-only; real code draws from the seeded prob::Rng
    let mut rng = thread_rng();
    let _ = &mut rng;
    7
}
