// Lint fixture (never compiled): R5 must flag a route with no persist
// since the previous route, and a direct write_frame in main_loop.
// Linted under `server/server.rs`.

fn main_loop(router: &mut Router, shards: &mut Shards) {
    loop {
        let mut pending = collect_outputs(shards);
        persist_all(shards);
        router.handle(&mut pending);
        let mut more = collect_outputs(shards);
        router.handle(&mut more); // no persist_all since last route
        write_frame(stream, &bytes); // bypasses the pending buffer
    }
}
