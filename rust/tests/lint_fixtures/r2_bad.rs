// Lint fixture (never compiled): R2 must flag HashMap/HashSet
// iteration in a protocol path. Linted under `sim/tally.rs`.

use std::collections::HashMap;

pub struct Tally {
    counts: HashMap<u32, u64>,
}

impl Tally {
    pub fn dump(&self) {
        for (k, v) in self.counts.iter() {
            println!("{k} {v}");
        }
        let fresh = HashMap::new();
        for k in fresh {
            drop(k);
        }
    }
}
