//! Checker-vs-protocol cross-validation: generate valid histories from
//! real simulated runs, mutate them adversarially, and require the
//! checker to (a) accept the originals and (b) flag the mutations.
//! "Always be suspicious of success" (§5.4) — a checker that can't see
//! injected bugs proves nothing.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use leaseguard::cluster::Cluster;
use leaseguard::config::{ConsistencyMode, Params};
use leaseguard::history::{History, OpKind};
use leaseguard::linearizability::check;
use leaseguard::prob::Rng;

fn run_history(seed: u64) -> History {
    let mut p = Params::default();
    p.consistency = ConsistencyMode::LeaseGuard;
    p.seed = seed;
    p.duration_us = 1_500_000;
    p.interarrival_us = 500.0;
    p.crash_leader_at_us = 400_000;
    Cluster::new(p).run().history
}

#[test]
fn real_histories_accepted() {
    for seed in [1u64, 5, 9] {
        let h = run_history(seed);
        assert!(h.entries.len() > 500, "history too small: {}", h.entries.len());
        let v = check(&h);
        assert!(v.is_empty(), "seed {seed}: {:?}", v.first());
    }
}

#[test]
fn mutation_dropping_read_value_detected() {
    // Removing the last observed value from a non-empty successful read
    // makes it stale; the checker must notice at least one such
    // mutation (some reads may legally drop a same-instant value).
    let h = run_history(2);
    let mut rng = Rng::new(1);
    let mut detected = 0;
    let mut tried = 0;
    for _ in 0..50 {
        let mut m = h.clone();
        let candidates: Vec<usize> = m
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| {
                e.success && matches!(&e.kind, OpKind::Read { result } if result.len() >= 2)
            })
            .map(|(i, _)| i)
            .collect();
        if candidates.is_empty() {
            break;
        }
        let i = *rng.choice(&candidates);
        if let OpKind::Read { result } = &mut m.entries[i].kind {
            result.pop();
        }
        tried += 1;
        if !check(&m).is_empty() {
            detected += 1;
        }
    }
    assert!(tried > 10, "not enough mutable reads ({tried})");
    assert!(
        detected * 2 > tried,
        "checker blind to stale reads: {detected}/{tried} detected"
    );
}

#[test]
fn mutation_reordering_read_values_detected() {
    let h = run_history(3);
    let mut rng = Rng::new(2);
    let mut detected = 0;
    let mut tried = 0;
    for _ in 0..50 {
        let mut m = h.clone();
        let candidates: Vec<usize> = m
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| {
                e.success && matches!(&e.kind, OpKind::Read { result } if result.len() >= 2)
            })
            .map(|(i, _)| i)
            .collect();
        if candidates.is_empty() {
            break;
        }
        let i = *rng.choice(&candidates);
        if let OpKind::Read { result } = &mut m.entries[i].kind {
            result.swap(0, 1);
        }
        tried += 1;
        if !check(&m).is_empty() {
            detected += 1;
        }
    }
    assert!(tried > 10);
    assert_eq!(detected, tried, "reorders must always be caught");
}

#[test]
fn mutation_forged_future_value_detected() {
    // A read claiming to observe a value that was never applied.
    let h = run_history(4);
    let mut m = h.clone();
    let i = m
        .entries
        .iter()
        .position(|e| e.success && matches!(e.kind, OpKind::Read { .. }))
        .expect("a successful read");
    if let OpKind::Read { result } = &mut m.entries[i].kind {
        result.push(0xDEAD_BEEF);
    }
    assert!(!check(&m).is_empty());
}

#[test]
fn mutation_unacked_write_observed_is_fine_but_lost_write_is_not() {
    let h = run_history(6);
    // Forge: mark a successful write as failed — checker must still
    // accept (ambiguity rule §6.2).
    let mut m = h.clone();
    if let Some(e) = m
        .entries
        .iter_mut()
        .find(|e| e.success && matches!(e.kind, OpKind::Append { .. }))
    {
        e.success = false;
    }
    assert!(check(&m).is_empty(), "failed-but-applied writes are legal");
    // Forge: a successful write whose apply record is erased = lost
    // update. Rebuild the apply log without one written value.
    let mut m2 = h.clone();
    let victim = m2
        .entries
        .iter()
        .find_map(|e| match (&e.kind, e.success) {
            (OpKind::Append { value }, true) => Some((e.key, *value)),
            _ => None,
        })
        .expect("a successful write");
    let mut fresh = leaseguard::history::ApplyLog::new();
    for e in &h.entries {
        if let (OpKind::Append { value }, true) = (&e.kind, e.success) {
            if (e.key, *value) != victim {
                if let Some(at) = h.applies.applied_at(e.key, *value) {
                    fresh.record(e.key, *value, at);
                }
            }
        }
    }
    // Also strip reads that observed the victim so only the lost-update
    // rule fires.
    m2.entries.retain(|e| match &e.kind {
        OpKind::Read { result } => !(e.key == victim.0 && result.contains(&victim.1)),
        _ => true,
    });
    m2.applies = fresh;
    let v = check(&m2);
    assert!(
        v.iter().any(|x| x.detail.contains("never applied")),
        "lost acknowledged write must be flagged: {v:?}"
    );
}
