//! Tier-1 gate for the self-hosted linter (`leaseguard lint`).
//!
//! Two halves:
//! 1. **Fixtures** — every rule R1–R5 has a known-bad snippet under
//!    `rust/tests/lint_fixtures/` that MUST fire, and a waivered twin
//!    that MUST pass clean (waiver consumed, no W1). This pins the
//!    rules themselves: a lexer or matcher regression that stops a rule
//!    from firing fails here, not silently in production lint runs.
//! 2. **Self-hosting** — the linter runs over the crate's own
//!    `rust/src/` and the tree must have zero unwaived findings, with
//!    every waiver in effect carrying a non-empty reason.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::path::PathBuf;

use leaseguard::lint::{lint_source, lint_tree, Finding};

fn repo() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Load a fixture and lint it under the logical in-tree path that puts
/// it in the rule's scope (rules are path-scoped; the fixture's real
/// location under tests/ would exempt most of them).
fn lint_fixture(fixture: &str, logical_path: &str) -> Vec<Finding> {
    let p = repo().join("rust/tests/lint_fixtures").join(fixture);
    let src = std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("fixture {} unreadable: {e}", p.display()));
    lint_source(logical_path, &src)
}

/// (bad fixture, waived twin, logical path, rule) — one row per rule,
/// plus one per extra path a rule is scoped to (R4 covers both
/// untrusted-byte decoders).
const MATRIX: [(&str, &str, &str, &str); 6] = [
    ("r1_bad.rs", "r1_waived.rs", "raft/tick.rs", "R1"),
    ("r2_bad.rs", "r2_waived.rs", "sim/tally.rs", "R2"),
    ("r3_bad.rs", "r3_waived.rs", "metrics.rs", "R3"),
    ("r4_bad.rs", "r4_waived.rs", "server/wire.rs", "R4"),
    ("r4_snap_bad.rs", "r4_snap_waived.rs", "snap/mod.rs", "R4"),
    ("r5_bad.rs", "r5_waived.rs", "server/server.rs", "R5"),
];

#[test]
fn every_rule_fires_on_its_bad_fixture() {
    for (bad, _, logical, rule) in MATRIX {
        let findings = lint_fixture(bad, logical);
        let hits: Vec<&Finding> =
            findings.iter().filter(|f| f.rule == rule && f.waived.is_none()).collect();
        assert!(!hits.is_empty(), "{bad} under {logical}: {rule} did not fire: {findings:?}");
    }
}

#[test]
fn every_waived_twin_passes_clean() {
    for (_, waived, logical, rule) in MATRIX {
        let findings = lint_fixture(waived, logical);
        let unwaived: Vec<&Finding> = findings.iter().filter(|f| f.waived.is_none()).collect();
        assert!(
            unwaived.is_empty(),
            "{waived} under {logical}: expected clean, got {unwaived:?}"
        );
        // The waiver must actually be exercised (else it is testing
        // nothing) and must carry its reason through to the report.
        let consumed = findings
            .iter()
            .filter(|f| f.rule == rule)
            .filter_map(|f| f.waived.as_deref())
            .collect::<Vec<_>>();
        assert!(!consumed.is_empty(), "{waived}: no waived {rule} finding recorded");
        assert!(consumed.iter().all(|r| !r.is_empty()));
    }
}

#[test]
fn bad_fixtures_are_scope_sensitive() {
    // The same bad code OUTSIDE the rule's path scope must pass for the
    // path-scoped rules (R1/R2/R4/R5) — proving the scoping logic, not
    // just the matchers. (R3 is global by design.) R1 is scoped by an
    // exemption list, so its out-of-scope path is `server/`; the others
    // use a path their scope lists don't cover.
    for (bad, _, _, rule) in MATRIX {
        let out_of_scope = match rule {
            "R1" => "server/transport.rs",
            "R3" => continue,
            _ => "obs/registry.rs",
        };
        let findings = lint_fixture(bad, out_of_scope);
        assert!(
            findings.iter().all(|f| f.rule != rule),
            "{bad} fired {rule} outside its scope: {findings:?}"
        );
    }
}

#[test]
fn self_host_own_tree_is_clean() {
    let root = repo().join("rust/src");
    assert!(root.is_dir(), "missing {}", root.display());
    let report = lint_tree(&root).expect("lint walk");
    assert!(report.files_scanned > 30, "suspiciously few files: {}", report.files_scanned);
    let unwaived: Vec<&Finding> = report.unwaived().collect();
    assert!(
        unwaived.is_empty(),
        "unwaived lint findings in rust/src:\n{}",
        report.render_text()
    );
    // Every waiver in effect must carry a non-empty reason (W0 would
    // have caught a missing one; this checks the recorded pairing too).
    for f in &report.findings {
        if let Some(reason) = &f.waived {
            assert!(!reason.is_empty(), "reasonless waiver on {}:{}", f.file, f.line);
        }
    }
    // JSON view agrees with the report.
    let json = report.to_json();
    assert!(json.contains("\"unwaived\": 0"), "{json}");
}

#[test]
fn self_host_report_is_deterministic() {
    let root = repo().join("rust/src");
    let a = lint_tree(&root).expect("lint walk a");
    let b = lint_tree(&root).expect("lint walk b");
    assert_eq!(a.to_json(), b.to_json(), "lint report must be byte-stable across runs");
}

#[test]
fn known_in_tree_waivers_are_present() {
    // The cleanup sweep left exactly these documented exception sites;
    // pin them so a future edit that silently deletes the waiver (or
    // the code it covers) shows up here.
    let root = repo().join("rust/src");
    let report = lint_tree(&root).expect("lint walk");
    let waived: Vec<(&str, &str)> = report
        .findings
        .iter()
        .filter(|f| f.waived.is_some())
        .map(|f| (f.rule, f.file.as_str()))
        .collect();
    assert!(
        waived.contains(&("R1", "bench.rs")),
        "bench.rs timing waiver missing: {waived:?}"
    );
    assert!(
        waived.contains(&("R5", "server/server.rs")),
        "server.rs status-reply waiver missing: {waived:?}"
    );
}

#[test]
fn fixture_dir_and_src_do_not_overlap() {
    // lint_tree(rust/src) must never pick up the deliberately-bad
    // fixtures; they live under rust/tests/.
    let fixtures = repo().join("rust/tests/lint_fixtures");
    assert!(fixtures.is_dir());
    assert!(!fixtures.starts_with(repo().join("rust/src")));
}
