//! Crash/restart harness for the real TCP cluster.
//!
//! Two drills, both with hard-kill semantics (no graceful flush):
//!
//! 1. `no_double_vote_across_restart` — the classic Raft durability
//!    failure, reproduced at the process level: a server that votes in
//!    term T, crashes, and forgets `voted_for` will happily vote for a
//!    second candidate in the same term, electing two leaders. The test
//!    speaks the wire protocol directly (fake candidates on real
//!    sockets) so nothing between disk and TCP is mocked.
//!
//! 2. `durable_cluster_survives_leader_crash_and_restart` — a 3-node
//!    cluster under open-loop client load: kill the leader mid-run,
//!    respawn it from its data dir on the same port (exercising
//!    SO_REUSEADDR rebind and the peers' redial path), and require a
//!    linearizable history plus recovered read throughput. The lease
//!    must be re-derived from the recovered log, never resurrected —
//!    a resurrected lease shows up here as a stale read the checker
//!    flags.
//!
//! `CRASHTEST_SEED` varies the workload seed (used by
//! scripts/crashtest.sh to run many distinct schedules).

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::thread;
use std::time::{Duration, Instant};

use leaseguard::client::run_open_loop;
use leaseguard::config::{ConsistencyMode, Params};
use leaseguard::figures::realcluster::RealCluster;
use leaseguard::linearizability;
use leaseguard::raft::Message;
use leaseguard::server::server::{Server, ServerConfig};
use leaseguard::server::transport::{read_frame, write_frame};
use leaseguard::server::wire::{self, Frame};
use leaseguard::storage::FsyncPolicy;
use leaseguard::testkit::TempDir;

fn accept_within(l: &TcpListener, timeout: Duration, what: &str) -> TcpStream {
    l.set_nonblocking(true).unwrap();
    let deadline = Instant::now() + timeout;
    loop {
        match l.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false).unwrap();
                s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
                return s;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                assert!(Instant::now() < deadline, "timed out accepting {what}");
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) => panic!("accept {what}: {e}"),
        }
    }
}

/// The first frame on every outgoing peer link is the dialer's hello.
fn expect_hello(conn: &mut TcpStream, from: usize, what: &str) {
    let buf = read_frame(conn).unwrap().unwrap_or_else(|| panic!("{what}: closed before hello"));
    match wire::decode(&buf).unwrap() {
        Frame::HelloPeer { from: f } => assert_eq!(f, from, "{what}"),
        other => panic!("{what}: expected HelloPeer, got {other:?}"),
    }
}

fn send_vote_request(conn: &mut TcpStream, candidate: usize, term: u64) {
    let f = Frame::Raft {
        from: candidate,
        group: 0,
        msg: Message::RequestVote { term, candidate, last_log_index: 0, last_log_term: 0 },
    };
    write_frame(conn, &wire::encode(&f)).unwrap();
}

fn await_vote_reply(conn: &mut TcpStream, what: &str) -> (u64, bool) {
    loop {
        let buf = read_frame(conn).unwrap().unwrap_or_else(|| panic!("{what}: link closed"));
        match wire::decode(&buf).unwrap() {
            Frame::Raft { msg: Message::VoteReply { term, granted, .. }, .. } => {
                return (term, granted)
            }
            _ => continue,
        }
    }
}

#[test]
fn no_double_vote_across_restart() {
    // Fake peers 1 and 2: plain listeners the server-under-test dials.
    let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
    let l2 = TcpListener::bind("127.0.0.1:0").unwrap();
    let dir = TempDir::new("no-double-vote");
    let mut p = Params::default();
    p.consistency = ConsistencyMode::LeaseGuard;
    p.nodes = 3;
    // Freeze self-election so the only term/vote activity is ours.
    p.election_timeout_us = 60_000_000;
    p.election_jitter_us = 1_000;
    let cfg = ServerConfig {
        id: 0,
        peer_addrs: vec![
            "auto".into(),
            l1.local_addr().unwrap().to_string(),
            l2.local_addr().unwrap().to_string(),
        ],
        params: p,
        one_way_delay: Duration::ZERO,
        engine: None,
        applies: None,
        data_dir: Some(dir.path().to_path_buf()),
        fsync: FsyncPolicy::Group,
    };

    // First life: grant candidate 1 a vote in term 7.
    let h = Server::spawn(cfg.clone()).expect("spawn");
    let mut from1 = accept_within(&l1, Duration::from_secs(5), "peer-1 link");
    let mut from2 = accept_within(&l2, Duration::from_secs(5), "peer-2 link");
    expect_hello(&mut from1, 0, "peer-1 link");
    expect_hello(&mut from2, 0, "peer-2 link");
    thread::sleep(Duration::from_millis(50)); // let PeerUp land before the vote needs it
    let mut inbound = TcpStream::connect(&h.addr).unwrap();
    send_vote_request(&mut inbound, 1, 7);
    let (term, granted) = await_vote_reply(&mut from1, "first vote");
    assert_eq!(term, 7);
    assert!(granted, "an empty follower must grant the first vote in a new term");

    // Crash: no graceful flush, connections drop.
    h.kill();
    drop((from1, from2, inbound));

    // Second life: reboot from the same data dir. The dialer re-dials the
    // fake peers; we accept the fresh links.
    let h = Server::spawn(cfg.clone()).expect("respawn");
    let mut from1 = accept_within(&l1, Duration::from_secs(5), "peer-1 relink");
    let mut from2 = accept_within(&l2, Duration::from_secs(5), "peer-2 relink");
    expect_hello(&mut from1, 0, "peer-1 relink");
    expect_hello(&mut from2, 0, "peer-2 relink");
    assert!(
        !h.status.group(0).is_leader.load(Ordering::Relaxed),
        "a restart must never resurrect leadership (the lease is re-derived, not reloaded)"
    );
    thread::sleep(Duration::from_millis(50));
    let mut inbound = TcpStream::connect(&h.addr).unwrap();

    // A stale term proves current_term survived the crash...
    send_vote_request(&mut inbound, 1, 6);
    let (term, granted) = await_vote_reply(&mut from1, "stale-term vote");
    assert_eq!(term, 7, "restart must not lose the current term");
    assert!(!granted, "a vote request from a stale term must be denied");

    // ...and a different candidate in the SAME term proves voted_for did:
    // granting here is exactly the double vote that elects two leaders.
    send_vote_request(&mut inbound, 2, 7);
    let (term, granted) = await_vote_reply(&mut from2, "double-vote attempt");
    assert_eq!(term, 7);
    assert!(!granted, "DOUBLE VOTE: restart forgot voted_for in term 7");

    // The original candidate may ask again — vote re-grant is idempotent —
    // which proves the denial above came from the persisted vote, not
    // from a server that stopped granting votes altogether.
    send_vote_request(&mut inbound, 1, 7);
    let (term, granted) = await_vote_reply(&mut from1, "re-granted vote");
    assert_eq!(term, 7);
    assert!(granted, "re-granting the persisted vote to the same candidate is legal");
    h.kill();
}

#[test]
fn durable_cluster_survives_leader_crash_and_restart() {
    let seed: u64 = std::env::var("CRASHTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(42);
    let mut p = Params::default();
    p.consistency = ConsistencyMode::LeaseGuard;
    p.nodes = 3;
    p.election_timeout_us = 200_000;
    p.election_jitter_us = 150_000;
    p.heartbeat_us = 50_000;
    p.lease_duration_us = 400_000;
    p.duration_us = 1_800_000;
    p.interarrival_us = 1000.0;
    p.value_bytes = 256;
    p.seed = seed;

    let dirs: Vec<TempDir> =
        (0..p.nodes).map(|i| TempDir::new(&format!("crash-restart-{seed}-{i}"))).collect();
    let paths: Vec<PathBuf> = dirs.iter().map(|d| d.path().to_path_buf()).collect();
    let mut cluster =
        RealCluster::spawn_durable(&p, Duration::ZERO, None, &paths, FsyncPolicy::Group)
            .expect("spawn");
    let leader = cluster.wait_for_leader(Duration::from_secs(10)).expect("leader");
    let pre_term = cluster.handles[leader].as_ref().unwrap().status.group(0).term.get();

    let addrs = cluster.addrs.clone();
    let applies = cluster.applies.clone();
    let pc = p.clone();
    let client = thread::spawn(move || run_open_loop(&addrs, &pc, Some(applies)));

    thread::sleep(Duration::from_millis(400));
    cluster.kill(leader);
    thread::sleep(Duration::from_millis(300));
    // Same id, same port (SO_REUSEADDR vs TIME_WAIT), same data dir.
    cluster.respawn(leader).expect("respawn");

    let rep = client.join().unwrap().expect("client");
    let post_term = cluster.handles[leader].as_ref().unwrap().status.group(0).term.get();
    cluster.shutdown();

    // The respawned node booted from its recovered term and then caught
    // up with the new leader — terms never move backwards.
    assert!(post_term >= pre_term, "recovered term went backwards: {pre_term} -> {post_term}");
    // One linearizable history across crash, election, AND restart. A
    // resurrected lease (stale read served by the rebooted ex-leader)
    // would surface here.
    let viol = linearizability::check(&rep.history);
    assert!(viol.is_empty(), "history with crash+restart not linearizable: {:?}", viol.first());
    // Reads recover after the failover + rejoin.
    let tail = rep.series.window_totals(true, 1_400_000, 1_800_000);
    assert!(tail.ok > 20, "reads should recover after failover + restart: {tail:?}");
}
