//! Real-mode integration: TCP servers + open-loop client, in process.
//! These are slower than the simulator tests, so workloads are modest;
//! the heavy versions live in the fig9-11 benches.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::time::Duration;

use leaseguard::client::run_open_loop;
use leaseguard::config::{ConsistencyMode, Params};
use leaseguard::figures::realcluster::RealCluster;
use leaseguard::linearizability;
use leaseguard::runtime::EngineHandle;

fn base(mode: ConsistencyMode) -> Params {
    let mut p = Params::default();
    p.consistency = mode;
    p.nodes = 3;
    p.election_timeout_us = 200_000;
    p.election_jitter_us = 150_000;
    p.heartbeat_us = 50_000;
    p.lease_duration_us = 400_000;
    p.duration_us = 900_000;
    p.interarrival_us = 1000.0;
    p.value_bytes = 256;
    p.seed = 42;
    p
}

fn steady_state(mode: ConsistencyMode) {
    let p = base(mode);
    let cluster = RealCluster::spawn(&p, Duration::ZERO, None).expect("spawn");
    cluster.wait_for_leader(Duration::from_secs(10)).expect("leader");
    let rep = run_open_loop(&cluster.addrs, &p, Some(cluster.applies.clone())).expect("client");
    cluster.shutdown();
    let ok = rep.read_latency.count() + rep.write_latency.count();
    assert!(ok > 300, "{mode}: only {ok} successful ops");
    let viol = linearizability::check(&rep.history);
    assert!(viol.is_empty(), "{mode}: {:?}", viol.first());
}

#[test]
fn steady_state_leaseguard() {
    steady_state(ConsistencyMode::LeaseGuard);
}

#[test]
fn steady_state_quorum() {
    steady_state(ConsistencyMode::Quorum);
}

#[test]
fn steady_state_ongaro() {
    steady_state(ConsistencyMode::OngaroLease);
}

#[test]
fn steady_state_inconsistent() {
    steady_state(ConsistencyMode::Inconsistent);
}

#[test]
fn crash_failover_leaseguard_real() {
    let mut p = base(ConsistencyMode::LeaseGuard);
    p.duration_us = 1_800_000;
    let mut cluster = RealCluster::spawn(&p, Duration::ZERO, None).expect("spawn");
    let leader = cluster.wait_for_leader(Duration::from_secs(10)).expect("leader");
    let addrs = cluster.addrs.clone();
    let applies = cluster.applies.clone();
    let pc = p.clone();
    let client = std::thread::spawn(move || run_open_loop(&addrs, &pc, Some(applies)));
    std::thread::sleep(Duration::from_millis(400));
    cluster.kill(leader);
    let rep = client.join().unwrap().expect("client");
    cluster.shutdown();
    let viol = linearizability::check(&rep.history);
    assert!(viol.is_empty(), "{:?}", viol.first());
    // Reads recover after failover.
    let tail = rep.series.window_totals(true, 1_400_000, 1_800_000);
    assert!(tail.ok > 20, "reads should recover post-failover: {tail:?}");
}

#[test]
fn xla_engine_serves_batched_reads() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = EngineHandle::spawn(std::path::Path::new("artifacts")).expect("engine");
    let mut p = base(ConsistencyMode::LeaseGuard);
    p.use_xla_admission = true;
    let cluster = RealCluster::spawn(&p, Duration::ZERO, Some(engine)).expect("spawn");
    cluster.wait_for_leader(Duration::from_secs(10)).expect("leader");
    let rep = run_open_loop(&cluster.addrs, &p, Some(cluster.applies.clone())).expect("client");
    let batched: u64 = cluster
        .handles
        .iter()
        .flatten()
        .map(|h| h.status.reads_batched.get())
        .sum();
    cluster.shutdown();
    assert!(batched > 100, "reads should flow through the batch path: {batched}");
    assert!(rep.read_latency.count() > 200);
    linearizability::assert_linearizable(&rep.history);
}

#[test]
fn live_stat_reports_per_group_lease_accounting_and_stages() {
    // The introspection acceptance path end to end: a 2-group cluster
    // under real load, then the same StatusRequest RPC `leaseguard
    // stat` issues, against every server. The snapshots must carry
    // per-group lease accounting, a per-stage latency breakdown, and a
    // decodable flight-recorder tail.
    use leaseguard::obs::registry::{
        STAGE_PERSIST, STAGE_QUEUE, STAGE_REPLICATE, STAGE_REPLY,
    };
    let mut p = base(ConsistencyMode::LeaseGuard);
    p.groups = 2;
    let cluster = RealCluster::spawn(&p, Duration::ZERO, None).expect("spawn");
    cluster.wait_for_all_leaders(2, Duration::from_secs(10)).expect("all groups elect");
    let rep = run_open_loop(&cluster.addrs, &p, Some(cluster.applies.clone())).expect("client");
    assert!(rep.read_latency.count() + rep.write_latency.count() > 300);

    let snaps: Vec<leaseguard::obs::StatusSnapshot> = cluster
        .addrs
        .iter()
        .map(|a| leaseguard::client::fetch_status(a, 64).expect("stat RPC"))
        .collect();
    cluster.shutdown();

    for (i, s) in snaps.iter().enumerate() {
        assert_eq!(s.groups.len(), 2, "server {i} must report every group");
    }
    for g in 0..2usize {
        // Lease accounting: the workload's reads and writes show up in
        // this group's counters on whichever server(s) led it.
        let reads: u64 = snaps
            .iter()
            .map(|s| {
                let gs = &s.groups[g];
                gs.reads_lease_local + gs.reads_lease_inherited + gs.reads_quorum
            })
            .sum();
        let writes: u64 = snaps.iter().map(|s| s.groups[g].writes_accepted).sum();
        assert!(reads > 0, "group {g}: no reads in the lease accounting");
        assert!(writes > 0, "group {g}: no writes accounted");
        // Per-stage latency: ops traversed queue -> persist ->
        // replicate -> reply on the group's leader.
        for (stage, name) in
            [(STAGE_QUEUE, "queue"), (STAGE_PERSIST, "persist"), (STAGE_REPLICATE, "replicate"), (STAGE_REPLY, "reply")]
        {
            assert!(
                snaps.iter().any(|s| s.groups[g].stages[stage].count > 0),
                "group {g}: no {name}-stage samples on any server"
            );
        }
        // Flight-recorder tail decoded over the wire: protocol events
        // with sane stamps, tagged with this group.
        let events: usize = snaps.iter().map(|s| s.groups[g].events.len()).sum();
        assert!(events > 0, "group {g}: empty flight-recorder tail everywhere");
        for s in &snaps {
            for e in &s.groups[g].events {
                assert_eq!(e.group as usize, g, "event routed to the wrong group: {e:?}");
            }
        }
        assert!(
            snaps.iter().any(|s| s.groups[g].events.iter().any(|e| e.term >= 1)),
            "group {g}: no post-election events in any tail"
        );
    }
    // JSON rendering of a live snapshot stays well-formed.
    let json = snaps[0].to_json();
    assert!(json.contains("\"reads_lease_local\""), "{json}");
    assert_eq!(json.matches('{').count(), json.matches('}').count());
}

#[test]
fn injected_delay_slows_writes_not_lease_reads() {
    let mut p = base(ConsistencyMode::LeaseGuard);
    p.election_timeout_us = 600_000;
    p.lease_duration_us = 1_000_000;
    p.duration_us = 900_000;
    let cluster = RealCluster::spawn(&p, Duration::from_millis(5), None).expect("spawn");
    cluster.wait_for_leader(Duration::from_secs(10)).expect("leader");
    let rep = run_open_loop(&cluster.addrs, &p, Some(cluster.applies.clone())).expect("client");
    cluster.shutdown();
    assert!(
        rep.write_latency.p50() >= 5_000,
        "writes must pay the injected one-way delay: p50={}",
        rep.write_latency.p50()
    );
    assert!(
        rep.read_latency.p50() < 5_000,
        "lease reads must not: p50={}",
        rep.read_latency.p50()
    );
    linearizability::assert_linearizable(&rep.history);
}
