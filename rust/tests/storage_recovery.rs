//! WAL + hard-state recovery under corruption: every torn tail,
//! truncated file, or flipped byte must recover the longest valid prefix
//! of the log — never panic, never resurrect anything past the damage,
//! and never block subsequent appends.
//!
//! Deterministic edge cases live next to the implementation
//! (`rust/src/storage/`); this suite drives the public API and adds a
//! randomized corruption property via `testkit`.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use leaseguard::clock::TimeInterval;
use leaseguard::kv::Command;
use leaseguard::raft::Entry;
use leaseguard::storage::wal::{Wal, WalRecord};
use leaseguard::storage::{hardstate, FsyncPolicy, Storage};
use leaseguard::testkit::{assert_prop, PropConfig, TempDir};

fn entry(term: u64, ts: i64) -> Entry {
    Entry {
        term,
        command: Command::Put { key: (ts % 7) as u32, value: ts as u64, payload_bytes: 0 },
        written_at: TimeInterval::exact(ts),
    }
}

/// Write `entries` as a fresh WAL and return the file's bytes.
fn write_wal(path: &std::path::Path, entries: &[(u64, i64)]) -> Vec<u8> {
    let (mut w, log) = Wal::open(path, FsyncPolicy::Never).unwrap();
    assert_eq!(log.last_index(), 0, "fresh dir");
    for (i, &(term, ts)) in entries.iter().enumerate() {
        w.append(&WalRecord::Append { index: i as u64 + 1, entry: entry(term, ts) }).unwrap();
    }
    w.sync().unwrap();
    drop(w);
    std::fs::read(path).unwrap()
}

#[test]
fn empty_file_recovers_empty_state() {
    let d = TempDir::new("rec-empty");
    std::fs::write(d.path().join("wal"), b"").unwrap();
    let (_, ds) = Storage::open(d.path(), FsyncPolicy::Group).unwrap();
    assert_eq!(ds.current_term, 0);
    assert_eq!(ds.voted_for, None);
    assert!(ds.log.is_empty());
}

#[test]
fn torn_tail_every_cut_point() {
    // Cut the file at EVERY byte offset: recovery must always yield a
    // prefix and must never panic. Exhaustive, not sampled — the file is
    // only a few hundred bytes.
    let d = TempDir::new("rec-cuts");
    let p = d.path().join("wal");
    let entries: Vec<(u64, i64)> = (0..8).map(|i| (1 + i / 3, 10 * i as i64)).collect();
    let full = write_wal(&p, &entries);
    for cut in 0..=full.len() {
        std::fs::write(&p, &full[..cut]).unwrap();
        let (_, log) = Wal::open(&p, FsyncPolicy::Never).unwrap();
        let n = log.last_index() as usize;
        assert!(n <= entries.len(), "cut {cut}: recovered more than written");
        for i in 1..=n {
            let (term, ts) = entries[i - 1];
            assert_eq!(log.get(i as u64).unwrap(), &entry(term, ts), "cut {cut} index {i}");
        }
        if cut == full.len() {
            assert_eq!(n, entries.len(), "uncorrupted file must recover fully");
        }
    }
}

#[test]
fn corrupted_crc_mid_file_keeps_prefix() {
    let d = TempDir::new("rec-crc");
    let p = d.path().join("wal");
    let entries: Vec<(u64, i64)> = (0..6).map(|i| (1, i as i64)).collect();
    let full = write_wal(&p, &entries);
    let record = full.len() / entries.len();
    // Corrupt a byte inside the fourth record's payload.
    let mut bad = full.clone();
    bad[3 * record + 9] ^= 0x01;
    std::fs::write(&p, &bad).unwrap();
    let (_, log) = Wal::open(&p, FsyncPolicy::Never).unwrap();
    assert_eq!(log.last_index(), 3, "prefix before the corrupt record survives");
    for i in 1..=3u64 {
        assert_eq!(log.get(i).unwrap(), &entry(1, i as i64 - 1));
    }
}

#[test]
fn half_written_hard_state_defaults_conservatively() {
    let d = TempDir::new("rec-hs");
    {
        let (mut s, _) = Storage::open(d.path(), FsyncPolicy::Group).unwrap();
        s.persist_hard_state(4, Some(2)).unwrap();
        s.append(1, &entry(3, 1)).unwrap();
        s.sync().unwrap();
    }
    // Tear the hard-state file in half (a torn direct write — the
    // tmp+rename path can't produce this, but recovery must not trust
    // that).
    let hs_path = d.path().join(hardstate::FILE);
    let hs = std::fs::read(&hs_path).unwrap();
    std::fs::write(&hs_path, &hs[..hs.len() / 2]).unwrap();
    let (_, ds) = Storage::open(d.path(), FsyncPolicy::Group).unwrap();
    // voted_for falls back to the safe default; the term is still
    // floored by what the log proves.
    assert_eq!(ds.voted_for, None);
    assert_eq!(ds.current_term, 3, "term re-derived from the recovered log");
    assert_eq!(ds.log.last_index(), 1, "WAL untouched by hard-state damage");
}

// ------------------------------------------------------- randomized fuzz

#[derive(Clone, Debug)]
enum Corrupt {
    /// Truncate the file to `pos % (len + 1)` bytes (torn tail).
    Truncate(usize),
    /// XOR the byte at `pos % len` with a non-zero mask (bit rot).
    Flip(usize, u8),
}

#[derive(Clone, Debug)]
struct Case {
    entries: Vec<(u64, i64)>,
    corrupt: Corrupt,
}

#[test]
fn fuzz_corrupted_wal_recovers_longest_valid_prefix() {
    assert_prop(
        PropConfig { cases: 200, seed: 0x5709A6E, ..Default::default() },
        |rng| {
            let n = rng.below(24) as usize;
            let mut term = 1u64;
            let entries: Vec<(u64, i64)> = (0..n)
                .map(|i| {
                    term += rng.below(2); // non-decreasing terms
                    (term, i as i64)
                })
                .collect();
            let corrupt = if rng.chance(0.5) {
                Corrupt::Truncate(rng.below(1 << 14) as usize)
            } else {
                Corrupt::Flip(rng.below(1 << 14) as usize, rng.below(255) as u8 + 1)
            };
            Case { entries, corrupt }
        },
        |case| {
            leaseguard::testkit::shrink_vec(&case.entries)
                .into_iter()
                .map(|entries| Case { entries, corrupt: case.corrupt.clone() })
                .collect()
        },
        |case| {
            let d = TempDir::new("rec-fuzz");
            let p = d.path().join("wal");
            let full = write_wal(&p, &case.entries);
            match case.corrupt {
                Corrupt::Truncate(pos) => {
                    let cut = pos % (full.len() + 1);
                    std::fs::write(&p, &full[..cut]).unwrap();
                }
                Corrupt::Flip(pos, mask) => {
                    if full.is_empty() {
                        return Ok(()); // nothing to flip
                    }
                    let mut bad = full.clone();
                    bad[pos % bad.len()] ^= mask;
                    std::fs::write(&p, &bad).unwrap();
                }
            }
            // Recovery: longest valid prefix, entry-for-entry.
            let (_, log) = Wal::open(&p, FsyncPolicy::Never).unwrap();
            let n = log.last_index() as usize;
            if n > case.entries.len() {
                return Err(format!("recovered {n} > written {}", case.entries.len()));
            }
            for i in 1..=n {
                let (term, ts) = case.entries[i - 1];
                if log.get(i as u64) != Some(&entry(term, ts)) {
                    return Err(format!("index {i} differs after recovery"));
                }
            }
            // The file was truncated back to the valid prefix: appending
            // and re-recovering must extend cleanly.
            let (mut w, _) = Wal::open(&p, FsyncPolicy::Never).unwrap();
            w.append(&WalRecord::Append { index: n as u64 + 1, entry: entry(99, 99) }).unwrap();
            w.sync().unwrap();
            drop(w);
            let (_, log2) = Wal::open(&p, FsyncPolicy::Never).unwrap();
            if log2.last_index() as usize != n + 1 {
                return Err(format!(
                    "append after recovery: expected {} entries, got {}",
                    n + 1,
                    log2.last_index()
                ));
            }
            if log2.get(n as u64 + 1) != Some(&entry(99, 99)) {
                return Err("appended entry lost after recovery".into());
            }
            Ok(())
        },
    );
}

#[test]
fn fuzz_corrupted_hard_state_never_panics() {
    assert_prop(
        PropConfig { cases: 150, seed: 0x45F022, ..Default::default() },
        |rng| {
            let term = rng.below(1 << 20);
            let vote = if rng.chance(0.5) { Some(rng.below(5) as usize) } else { None };
            let corrupt = if rng.chance(0.5) {
                Corrupt::Truncate(rng.below(64) as usize)
            } else {
                Corrupt::Flip(rng.below(64) as usize, rng.below(255) as u8 + 1)
            };
            (term, vote, corrupt)
        },
        |_| vec![],
        |(term, vote, corrupt)| {
            let d = TempDir::new("hs-fuzz");
            hardstate::write(d.path(), *term, *vote, FsyncPolicy::Never)
                .map_err(|e| e.to_string())?;
            let path = d.path().join(hardstate::FILE);
            let full = std::fs::read(&path).map_err(|e| e.to_string())?;
            match corrupt {
                Corrupt::Truncate(pos) => {
                    let cut = pos % (full.len() + 1);
                    std::fs::write(&path, &full[..cut]).map_err(|e| e.to_string())?;
                    let got = hardstate::read(d.path());
                    // Either intact (cut == len) or the safe default.
                    if cut == full.len() {
                        if got != (*term, *vote) {
                            return Err(format!("uncorrupted read lost state: {got:?}"));
                        }
                    } else if got != (0, None) {
                        return Err(format!("torn hard-state not defaulted: {got:?}"));
                    }
                }
                Corrupt::Flip(pos, mask) => {
                    let mut bad = full.clone();
                    bad[pos % bad.len()] ^= mask;
                    std::fs::write(&path, &bad).map_err(|e| e.to_string())?;
                    if hardstate::read(d.path()) != (0, None) {
                        return Err("bit-rotted hard-state not defaulted".into());
                    }
                }
            }
            Ok(())
        },
    );
}
