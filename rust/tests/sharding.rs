//! Multi-Raft sharding suite: the keyspace partition, per-group routing,
//! and the whole-stack behavior when a process hosts many lease-guarded
//! groups (tentpole PR).
//!
//! Covered:
//! * `ShardMap` wire agreement — the client routes with a map decoded
//!   from the same bytes the servers serialize; they must agree on every
//!   key forever (a disagreement silently splits a key across groups).
//! * Simulated multi-group runs: per-shard linearizability in steady
//!   state and under a nemesis that crashes one group's leader process
//!   while other groups keep serving reads.
//! * Fixed-seed determinism with many groups (byte-identical histories).
//! * Real TCP cluster: 3 servers hosting 4 groups, kill the process
//!   leading group 0 mid-run, require every shard's history linearizable
//!   and read throughput to recover — the paper's crash drill, per shard.
//! * Durable multi-group recovery: kill + respawn from per-group WALs.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::time::Duration;

use leaseguard::client::run_open_loop;
use leaseguard::cluster::Cluster;
use leaseguard::config::{ConsistencyMode, Params};
use leaseguard::figures::realcluster::RealCluster;
use leaseguard::linearizability;
use leaseguard::shard::{group_seed, ShardMap, MAX_GROUPS};
use leaseguard::sim::{Fault, NemesisSchedule};
use leaseguard::storage::FsyncPolicy;
use leaseguard::testkit::TempDir;

// ------------------------------------------------------------ shard map

#[test]
fn shardmap_serialized_agreement() {
    // Client and servers must agree on the partition after a
    // serialize/deserialize trip — for every key, not just samples.
    let map = ShardMap::new(16);
    let decoded = ShardMap::from_bytes(&map.to_bytes()).expect("decode");
    assert_eq!(decoded.groups(), 16);
    for key in 0..100_000u32 {
        assert_eq!(map.group_of(key), decoded.group_of(key), "key {key}");
    }
}

#[test]
fn shardmap_rejects_foreign_bytes() {
    let mut bytes = ShardMap::new(8).to_bytes();
    bytes[4] = 99; // future version
    assert!(ShardMap::from_bytes(&bytes).is_err());
    let mut bytes = ShardMap::new(8).to_bytes();
    bytes[0] ^= 0xFF; // corrupt magic
    assert!(ShardMap::from_bytes(&bytes).is_err());
    assert!(ShardMap::from_bytes(&[]).is_err());
}

#[test]
fn group_seeds_are_distinct_and_preserve_group_zero() {
    let seed = 0xDEAD_BEEF_u64;
    assert_eq!(group_seed(seed, 0), seed, "group 0 must replay single-group runs");
    let mut seen: Vec<u64> = (0..MAX_GROUPS as u32).map(|g| group_seed(seed, g)).collect();
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), MAX_GROUPS, "per-group seeds must not collide");
}

// ------------------------------------------------------------ simulator

fn sim_params(groups: usize, seed: u64) -> Params {
    let mut p = Params::default();
    p.consistency = ConsistencyMode::LeaseGuard;
    p.seed = seed;
    p.groups = groups;
    p.duration_us = 1_500_000;
    p.interarrival_us = 500.0;
    p
}

#[test]
fn multi_group_sim_steady_state_every_shard_linearizable() {
    let p = sim_params(4, 42);
    let rep = Cluster::new(p).run();
    let map = ShardMap::new(4);
    linearizability::assert_linearizable_sharded(&rep.history, &map);
    // Uniform keys: every group must actually have served traffic.
    let shards = rep.history.partition_by_shard(&map);
    for (g, s) in shards.iter().enumerate() {
        assert!(!s.entries.is_empty(), "group {g} served no operations");
    }
}

#[test]
fn crash_one_group_leader_while_other_shards_serve() {
    // Crash the process leading group 0 (process crashes take down every
    // group it hosts); groups led elsewhere keep serving reads through
    // the outage, and every shard's history stays linearizable.
    let mut p = sim_params(8, 11);
    p.duration_us = 2_500_000;
    let sched = NemesisSchedule::new()
        .at(500_000, Fault::CrashLeader { restart_after_us: Some(600_000) });
    let rep = Cluster::new(p).with_nemesis(sched).run();
    assert_eq!(rep.faults_injected, 1);
    let map = ShardMap::new(8);
    linearizability::assert_linearizable_sharded(&rep.history, &map);
    // During the outage (crash at 500ms, restart at 1.1s) the shards
    // whose leader survived — plus inherited-lease reads elsewhere —
    // keep the read path alive.
    let during = rep.series.window_totals(true, 600_000, 1_000_000);
    assert!(during.ok > 0, "no reads served during the single-process outage: {during:?}");
}

#[test]
fn multi_group_runs_are_deterministic() {
    // Fixed seed, many groups: two runs must be byte-identical — same
    // event count, same origin, same history, op for op.
    let a = Cluster::new(sim_params(8, 7)).run();
    let b = Cluster::new(sim_params(8, 7)).run();
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.t0, b.t0);
    assert_eq!(a.elections, b.elections);
    assert_eq!(
        format!("{:?}", a.history.entries),
        format!("{:?}", b.history.entries),
        "histories diverged under a fixed seed"
    );
}

#[test]
fn single_group_mode_unchanged_by_sharding() {
    // groups=1 must behave exactly like the pre-sharding cluster: the
    // map sends every key to group 0 and the sharded check degenerates
    // to the whole-history check.
    let map = ShardMap::new(1);
    for key in 0..1000u32 {
        assert_eq!(map.group_of(key), 0);
    }
    let rep = Cluster::new(sim_params(1, 42)).run();
    let whole = linearizability::check(&rep.history);
    let sharded = linearizability::check_sharded(&rep.history, &map);
    assert_eq!(sharded.len(), 1);
    assert_eq!(whole.len(), sharded[0].1.len());
    assert!(whole.is_empty());
}

// ------------------------------------------------------------ real cluster

fn real_params(groups: usize) -> Params {
    let mut p = Params::default();
    p.consistency = ConsistencyMode::LeaseGuard;
    p.nodes = 3;
    p.groups = groups;
    p.election_timeout_us = 200_000;
    p.election_jitter_us = 150_000;
    p.heartbeat_us = 50_000;
    p.lease_duration_us = 400_000;
    p.duration_us = 1_800_000;
    p.interarrival_us = 1000.0;
    p.value_bytes = 256;
    p.seed = 42;
    p
}

#[test]
fn real_cluster_kill_group_leader_per_shard_linearizable() {
    // The acceptance drill: 3 servers hosting 4 groups over one TCP
    // transport each; kill the process leading group 0 mid-run; every
    // shard's history must stay linearizable and reads must recover.
    let p = real_params(4);
    let mut cluster = RealCluster::spawn(&p, Duration::ZERO, None).expect("spawn");
    let leaders =
        cluster.wait_for_all_leaders(4, Duration::from_secs(10)).expect("all groups elect");
    let addrs = cluster.addrs.clone();
    let applies = cluster.applies.clone();
    let pc = p.clone();
    let client = std::thread::spawn(move || run_open_loop(&addrs, &pc, Some(applies)));
    std::thread::sleep(Duration::from_millis(400));
    cluster.kill(leaders[0]);
    let rep = client.join().unwrap().expect("client");
    cluster.shutdown();
    let map = ShardMap::new(4);
    linearizability::assert_linearizable_sharded(&rep.history, &map);
    // Reads recover after the failover elections.
    let tail = rep.series.window_totals(true, 1_400_000, 1_800_000);
    assert!(tail.ok > 20, "reads should recover post-failover: {tail:?}");
}

#[test]
fn durable_multi_group_kill_respawn_recovers_every_group() {
    // Per-group WAL namespacing end to end: a killed server reboots from
    // `data-dir/g<id>/…` for each of its 4 groups and rejoins them all.
    let mut p = real_params(4);
    p.duration_us = 900_000;
    let dirs: Vec<_> =
        (0..3).map(|i| TempDir::new(&format!("shard-respawn-{i}"))).collect();
    let paths: Vec<std::path::PathBuf> = dirs.iter().map(|d| d.path().to_path_buf()).collect();
    let mut cluster =
        RealCluster::spawn_durable(&p, Duration::ZERO, None, &paths, FsyncPolicy::Group)
            .expect("spawn");
    let leaders =
        cluster.wait_for_all_leaders(4, Duration::from_secs(10)).expect("all groups elect");
    // Write through every group so each per-group WAL has entries.
    let rep = run_open_loop(&cluster.addrs, &p, Some(cluster.applies.clone())).expect("client");
    assert!(rep.write_latency.count() > 0, "no writes landed");
    // Kill the group-0 leader and bring it back from disk.
    cluster.kill(leaders[0]);
    std::thread::sleep(Duration::from_millis(200));
    cluster.respawn(leaders[0]).expect("respawn");
    // Every group re-establishes a committed leader with the rebooted
    // process back in the cluster.
    cluster.wait_for_all_leaders(4, Duration::from_secs(10)).expect("groups recover");
    // The per-group directories really exist (namespacing, not one WAL).
    for g in 0..4 {
        let wal = paths[leaders[0]].join(format!("g{g}")).join("wal");
        assert!(wal.exists(), "missing per-group wal {}", wal.display());
    }
    cluster.shutdown();
}
