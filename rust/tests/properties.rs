//! Property-based tests over the protocol invariants (testkit is the
//! offline proptest substitute; see DESIGN.md substitutions).
//!
//! These are the strongest safety checks in the suite: randomized fault
//! schedules (crash/partition timing, network parameters, clock error,
//! seeds) against the full simulated cluster, asserting the paper's
//! invariants; plus model-level properties of the lease gates.

use leaseguard::clock::TimeInterval;
use leaseguard::cluster::Cluster;
use leaseguard::config::{ConsistencyMode, Params};
use leaseguard::linearizability;
use leaseguard::prob::Rng;
use leaseguard::testkit::{assert_prop, PropConfig};

/// One randomized fault schedule.
#[derive(Debug, Clone)]
struct FaultCase {
    seed: u64,
    mode_idx: usize,
    crash_at_ms: i64,
    partition_instead: bool,
    restart_after_ms: i64,
    net_mean_us: f64,
    clock_error_us: i64,
    interarrival_us: f64,
    stray: bool,
}

const MODES: [ConsistencyMode; 5] = [
    ConsistencyMode::Quorum,
    ConsistencyMode::OngaroLease,
    ConsistencyMode::LogLease,
    ConsistencyMode::DeferCommit,
    ConsistencyMode::LeaseGuard,
];

fn gen_case(rng: &mut Rng) -> FaultCase {
    FaultCase {
        seed: rng.next_u64(),
        mode_idx: rng.below(MODES.len() as u64) as usize,
        crash_at_ms: rng.range_i64(100, 1200),
        partition_instead: rng.chance(0.4),
        restart_after_ms: if rng.chance(0.5) { rng.range_i64(100, 800) } else { 0 },
        net_mean_us: 100.0 + rng.f64() * 3000.0,
        clock_error_us: rng.range_i64(0, 500),
        interarrival_us: 300.0 + rng.f64() * 1200.0,
        stray: rng.chance(0.5),
    }
}

fn params_of(c: &FaultCase) -> Params {
    let mut p = Params::default();
    p.consistency = MODES[c.mode_idx];
    p.seed = c.seed;
    p.duration_us = 2_500_000;
    p.interarrival_us = c.interarrival_us;
    p.net_mean_us = c.net_mean_us;
    p.net_variance_us2 = c.net_mean_us; // paper's variance = mean shape
    p.clock_error_us = c.clock_error_us;
    if c.partition_instead {
        p.partition_leader_at_us = c.crash_at_ms * 1000;
        p.heal_after_us = if c.restart_after_ms > 0 { c.restart_after_ms * 1000 } else { 0 };
    } else {
        p.crash_leader_at_us = c.crash_at_ms * 1000;
        p.restart_after_us = if c.restart_after_ms > 0 { c.restart_after_ms * 1000 } else { 0 };
    }
    if c.stray {
        p.client_stray_prob = 0.05;
        p.op_timeout_us = 400_000;
    }
    p
}

#[test]
fn prop_consistent_modes_always_linearizable() {
    // The flagship property: under ANY crash/partition/heal schedule,
    // with correct clocks, every consistency mode except "inconsistent"
    // yields a linearizable history.
    assert_prop(
        PropConfig { cases: 40, seed: 0xDEC0DE, max_shrink_steps: 8 },
        gen_case,
        |c| {
            // Shrink toward: no restart, no stray, calmer network.
            let mut v = Vec::new();
            if c.restart_after_ms > 0 {
                let mut s = c.clone();
                s.restart_after_ms = 0;
                v.push(s);
            }
            if c.stray {
                let mut s = c.clone();
                s.stray = false;
                v.push(s);
            }
            if c.clock_error_us > 0 {
                let mut s = c.clone();
                s.clock_error_us = 0;
                v.push(s);
            }
            v
        },
        |c| {
            let rep = Cluster::new(params_of(c)).run();
            let viol = linearizability::check(&rep.history);
            if viol.is_empty() {
                Ok(())
            } else {
                Err(format!(
                    "{} violations in mode {}, first: {:?}",
                    viol.len(),
                    MODES[c.mode_idx],
                    viol.first()
                ))
            }
        },
    );
}

#[test]
fn prop_progress_after_faults() {
    // Liveness-ish: after the fault settles (last 500 ms of the run),
    // the replica set serves reads again in every consistent mode.
    assert_prop(
        PropConfig { cases: 20, seed: 0x11FE, max_shrink_steps: 4 },
        gen_case,
        |_| Vec::new(),
        |c| {
            let mut p = params_of(c);
            p.duration_us = 3_200_000; // leave room to recover
            let rep = Cluster::new(p).run();
            let tail = rep.series.window_totals(true, 2_700_000, 3_200_000);
            if tail.ok > 0 {
                Ok(())
            } else {
                Err(format!("no reads served in the final window: {tail:?}"))
            }
        },
    );
}

/// Model-level safety property of the two lease gates (§4.2 Case 2):
/// whenever a new leader's commit gate is open (it may commit), the old
/// leader's read gate must already be closed — for any entry timestamps
/// and any pair of *correct* clock readings.
#[test]
fn prop_gate_exclusivity_under_uncertainty() {
    #[derive(Debug, Clone)]
    struct GateCase {
        entry_at: i64,
        entry_err: i64,
        delta: i64,
        true_now: i64,
        err_a: i64,
        err_b: i64,
    }
    assert_prop(
        PropConfig { cases: 3000, seed: 0x6A7E, max_shrink_steps: 0 },
        |rng| GateCase {
            entry_at: rng.range_i64(0, 2_000_000),
            entry_err: rng.range_i64(0, 100),
            delta: rng.range_i64(1, 2_000_000),
            true_now: rng.range_i64(0, 5_000_000),
            err_a: rng.range_i64(0, 100),
            err_b: rng.range_i64(0, 100),
        },
        |_| Vec::new(),
        |c| {
            // Correct interval construction: truth inside the interval.
            let entry = TimeInterval::new(c.entry_at - c.entry_err, c.entry_at + c.entry_err);
            let now_commit = TimeInterval::new(c.true_now - c.err_a, c.true_now + c.err_a);
            // The reader observes the same or an EARLIER true time (the
            // dangerous direction): its reading still contains its truth.
            let reader_true = c.true_now; // same instant
            let now_read = TimeInterval::new(reader_true - c.err_b, reader_true + c.err_b);
            let commit_allowed = entry.definitely_older_than(c.delta, now_commit);
            let read_allowed = !entry.possibly_older_than(c.delta, now_read);
            if commit_allowed && read_allowed {
                Err(format!("both gates open: {c:?}"))
            } else {
                Ok(())
            }
        },
    );
}

/// Determinism as a property: any fault case replayed twice produces an
/// identical event count and history length.
#[test]
fn prop_simulation_deterministic() {
    assert_prop(
        PropConfig { cases: 10, seed: 0x5EED5, max_shrink_steps: 0 },
        gen_case,
        |_| Vec::new(),
        |c| {
            let a = Cluster::new(params_of(c)).run();
            let b = Cluster::new(params_of(c)).run();
            if a.events_processed == b.events_processed
                && a.history.entries.len() == b.history.entries.len()
                && a.t0 == b.t0
            {
                Ok(())
            } else {
                Err("replay diverged".into())
            }
        },
    );
}
