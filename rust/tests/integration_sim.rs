//! Simulator integration tests: whole-protocol runs with fault
//! injection, across every consistency mode the paper evaluates, plus
//! the Nemesis scenario-matrix regression suite.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use leaseguard::cluster::Cluster;
use leaseguard::config::{ConsistencyMode, Params};
use leaseguard::linearizability;
use leaseguard::sim::scenario::{self, MATRIX_MODES};

fn base(mode: ConsistencyMode, seed: u64) -> Params {
    let mut p = Params::default();
    p.consistency = mode;
    p.seed = seed;
    p.duration_us = 2_500_000;
    p.interarrival_us = 400.0;
    p.crash_leader_at_us = 500_000;
    p
}

#[test]
fn all_consistent_modes_survive_crash_linearizably() {
    // Every mode except "inconsistent" must be linearizable through a
    // leader crash + failover (the paper's core safety claim).
    for mode in [
        ConsistencyMode::Quorum,
        ConsistencyMode::OngaroLease,
        ConsistencyMode::LogLease,
        ConsistencyMode::DeferCommit,
        ConsistencyMode::LeaseGuard,
    ] {
        for seed in [1u64, 2, 3] {
            let rep = Cluster::new(base(mode, seed)).run();
            let viol = linearizability::check(&rep.history);
            assert!(
                viol.is_empty(),
                "{mode} seed {seed}: {} violations, first: {:?}",
                viol.len(),
                viol.first()
            );
            assert!(rep.elections >= 2, "{mode} seed {seed}: no failover election");
        }
    }
}

#[test]
fn leaseguard_serves_reads_while_awaiting_lease_loglease_does_not() {
    // The paper's inherited-lease claim, quantified: between election
    // (~1s) and old-lease expiry (1.5s), full LeaseGuard serves most
    // reads; unoptimized log lease serves (almost) none.
    let lg = Cluster::new(base(ConsistencyMode::LeaseGuard, 7)).run();
    let ll = Cluster::new(base(ConsistencyMode::LogLease, 7)).run();
    let lg_w = lg.series.window_totals(true, 1_100_000, 1_450_000);
    let ll_w = ll.series.window_totals(true, 1_100_000, 1_450_000);
    assert!(
        lg_w.ok > 100,
        "LeaseGuard should serve reads while awaiting lease: {lg_w:?}"
    );
    assert!(
        ll_w.ok <= 5,
        "LogLease should be dark until lease expiry: {ll_w:?}"
    );
}

#[test]
fn defer_commit_recovers_writes_loglease_rejects_them() {
    let dc = Cluster::new(base(ConsistencyMode::DeferCommit, 9)).run();
    let ll = Cluster::new(base(ConsistencyMode::LogLease, 9)).run();
    // Writes accepted during the wait are acked in a burst at expiry:
    // count successful writes whose ack lands in [1.4s, 1.7s].
    let dc_w = dc.series.window_totals(false, 1_400_000, 1_700_000);
    let ll_w = ll.series.window_totals(false, 1_000_000, 1_500_000);
    assert!(dc_w.ok > 100, "defer-commit ack burst expected: {dc_w:?}");
    assert!(
        ll_w.failed > 100,
        "loglease should fail writes while gated: {ll_w:?}"
    );
}

#[test]
fn inconsistent_mode_violates_linearizability_under_partition() {
    // §1's motivating bug: partition the old leader away; it keeps
    // serving (stale) reads while a new leader commits writes. The
    // omniscient checker must catch this in inconsistent mode.
    let mut violations_seen = 0;
    for seed in 1..=8u64 {
        let mut p = base(ConsistencyMode::Inconsistent, seed);
        p.crash_leader_at_us = 0;
        p.partition_leader_at_us = 500_000;
        p.client_stray_prob = 0.1; // some clients keep hitting the old leader
        p.op_timeout_us = 300_000;
        p.duration_us = 3_000_000;
        let rep = Cluster::new(p).run();
        violations_seen += linearizability::check(&rep.history).len();
    }
    assert!(
        violations_seen > 0,
        "expected stale reads from a partitioned old leader in inconsistent mode"
    );
}

#[test]
fn leaseguard_linearizable_under_partition() {
    // Same partition scenario, but LeaseGuard: the deposed leader may
    // serve reads only while its lease is provably fresh, so the
    // history must stay linearizable.
    for seed in 1..=8u64 {
        let mut p = base(ConsistencyMode::LeaseGuard, seed);
        p.crash_leader_at_us = 0;
        p.partition_leader_at_us = 500_000;
        p.client_stray_prob = 0.1;
        p.op_timeout_us = 300_000;
        p.duration_us = 3_000_000;
        let rep = Cluster::new(p).run();
        let viol = linearizability::check(&rep.history);
        assert!(viol.is_empty(), "seed {seed}: {:?}", viol.first());
    }
}

#[test]
fn broken_clocks_break_inherited_lease_reads() {
    // §4.3: "Inherited lease reads require correct clock bounds!" With
    // deliberately wrong bounds and a partitioned old leader, some seed
    // must produce a checker-visible violation — demonstrating both the
    // protocol's stated dependence and the checker's power.
    let mut violations = 0;
    for seed in 1..=10u64 {
        let mut p = base(ConsistencyMode::LeaseGuard, seed);
        p.clock_broken = true;
        p.clock_error_us = 400_000; // lies up to ~1.6 s into the future
        p.crash_leader_at_us = 0;
        p.partition_leader_at_us = 500_000;
        p.client_stray_prob = 0.1;
        p.op_timeout_us = 300_000;
        p.duration_us = 3_000_000;
        let rep = Cluster::new(p).run();
        violations += linearizability::check(&rep.history).len();
    }
    assert!(
        violations > 0,
        "broken clock bounds should eventually produce a stale read"
    );
}

#[test]
fn restart_rejoins_and_catches_up() {
    let mut p = base(ConsistencyMode::LeaseGuard, 21);
    p.restart_after_us = 400_000;
    p.duration_us = 3_000_000;
    let rep = Cluster::new(p).run();
    linearizability::assert_linearizable(&rep.history);
    // Healthy throughput at the end of the run.
    let tail = rep.series.window_totals(true, 2_400_000, 3_000_000);
    assert!(tail.ok > 200, "cluster should be healthy post-restart: {tail:?}");
}

#[test]
fn lease_renewal_keeps_reads_alive_without_writes() {
    // §5.1: read-only workload. The leader must renew its lease with
    // no-ops or every read after Δ would fail.
    let mut p = Params::default();
    p.consistency = ConsistencyMode::LeaseGuard;
    p.write_fraction = 0.0;
    p.duration_us = 3_000_000; // 3 x Δ
    p.interarrival_us = 1000.0;
    p.seed = 5;
    let rep = Cluster::new(p).run();
    let total = rep.series.window_totals(true, 0, i64::MAX);
    let fail_rate = total.failed as f64 / (total.ok + total.failed).max(1) as f64;
    assert!(
        fail_rate < 0.02,
        "reads should survive on renewal no-ops: {total:?}"
    );
    assert!(rep.node_stats.iter().any(|s| s.noops_written > 2));
    linearizability::assert_linearizable(&rep.history);
}

#[test]
fn five_node_cluster_failover() {
    let mut p = base(ConsistencyMode::LeaseGuard, 31);
    p.nodes = 5;
    let rep = Cluster::new(p).run();
    linearizability::assert_linearizable(&rep.history);
    let tail = rep.series.window_totals(true, 2_000_000, 2_500_000);
    assert!(tail.ok > 100);
}

#[test]
fn determinism_guard_zero_copy_refactor() {
    // Guard for the zero-copy replication refactor (shared EntryBatch +
    // per-round seq): a fixed-seed LeaseGuard availability run with a
    // leader crash at 300 ms must be a pure function of the seed — two
    // fresh runs agree on the event count AND on the byte-for-byte
    // client history (not just its length).
    let run = || {
        let mut p = Params::default();
        p.consistency = ConsistencyMode::LeaseGuard;
        p.seed = 0xD57E11;
        p.duration_us = 1_500_000;
        p.interarrival_us = 400.0;
        p.crash_leader_at_us = 300_000;
        Cluster::new(p).run()
    };
    let a = run();
    let b = run();
    assert_eq!(a.events_processed, b.events_processed, "event counts diverged");
    assert_eq!(a.t0, b.t0);
    assert_eq!(a.elections, b.elections);
    assert_eq!(a.limbo_len, b.limbo_len);
    assert_eq!(a.history.entries.len(), b.history.entries.len());
    // Byte-identical history: the strongest cheap check available (the
    // Debug rendering covers every field of every entry, in order).
    assert_eq!(
        format!("{:?}", a.history.entries),
        format!("{:?}", b.history.entries),
        "history diverged under a fixed seed"
    );
    assert!(a.elections >= 2, "scenario must actually fail over");
}

#[test]
fn determinism_guard_tracing() {
    // The flight recorder's determinism contract (see obs::recorder):
    // recording draws no RNG and reads no clock, so a fixed-seed
    // crash-failover run must produce a byte-identical client history
    // with the recorder ON (default capacity), at a different capacity,
    // and fully OFF. A recorder that perturbed event order or timing
    // would diverge here.
    let run = |enabled: bool, capacity: usize| {
        let mut p = Params::default();
        p.consistency = ConsistencyMode::LeaseGuard;
        p.seed = 0xD57E11;
        p.duration_us = 1_500_000;
        p.interarrival_us = 400.0;
        p.crash_leader_at_us = 300_000;
        p.flight_recorder = enabled;
        p.flight_recorder_capacity = capacity;
        Cluster::new(p).run()
    };
    let on = run(true, 1024);
    let small = run(true, 8);
    let off = run(false, 1024);
    for (label, other) in [("capacity 8", &small), ("recorder off", &off)] {
        assert_eq!(on.events_processed, other.events_processed, "{label}: event counts diverged");
        assert_eq!(on.t0, other.t0, "{label}");
        assert_eq!(on.elections, other.elections, "{label}");
        assert_eq!(on.limbo_len, other.limbo_len, "{label}");
        assert_eq!(
            format!("{:?}", on.history.entries),
            format!("{:?}", other.history.entries),
            "{label}: history diverged — tracing perturbed the simulation"
        );
    }
    // The knobs actually took effect: traced runs captured events,
    // the disabled run stored nothing.
    assert!(on.recorders.iter().any(|r| r.total_recorded() > 0), "recorder on captured nothing");
    assert!(small.recorders.iter().all(|r| r.len() <= 8));
    assert!(off.recorders.iter().all(|r| !r.is_enabled() && r.len() == 0));
    assert!(on.elections >= 2, "scenario must actually fail over");
}

#[test]
fn nemesis_matrix_linearizable_where_promised() {
    // The standing scenario-matrix regression: every catalog scenario x
    // every matrix mode. LeaseGuard and Quorum promise linearizability
    // under ANY fault schedule; Inconsistent is the control.
    let rows = scenario::run_matrix(1);
    assert_eq!(rows.len(), scenario::catalog().len() * MATRIX_MODES.len());
    for r in &rows {
        assert!(
            r.ok(),
            "{}/{}: {} violations where the mode promises linearizability",
            r.scenario,
            r.mode,
            r.violations
        );
        assert!(
            r.reads_ok + r.writes_ok > 0,
            "{}/{}: scenario produced no successful ops at all",
            r.scenario,
            r.mode
        );
        assert!(r.faults_injected > 0, "{}: no fault fired", r.scenario);
    }
}

#[test]
fn nemesis_determinism_partitions_chaos_and_skew() {
    // Same seed + same schedule => byte-identical history, extended
    // beyond the crash case to partitions, chaos windows (duplication,
    // reorder, loss draw extra RNG), and clock-skew injection.
    for name in ["leader-partition-heal", "dup-reorder-storm", "leader-clock-skew-spike"] {
        let sc = scenario::catalog()
            .into_iter()
            .find(|s| s.name == name)
            .expect("scenario in catalog");
        let a = scenario::run_report(&sc, ConsistencyMode::LeaseGuard, 0xD57E12);
        let b = scenario::run_report(&sc, ConsistencyMode::LeaseGuard, 0xD57E12);
        assert_eq!(a.events_processed, b.events_processed, "{name}: event counts diverged");
        assert_eq!(a.t0, b.t0, "{name}");
        assert_eq!(a.elections, b.elections, "{name}");
        assert_eq!(
            format!("{:?}", a.history.entries),
            format!("{:?}", b.history.entries),
            "{name}: history diverged under a fixed seed"
        );
    }
}

#[test]
fn crashed_node_fails_inflight_ops_promptly() {
    // Satellite regression: ops in flight against a node when it
    // crashes must fail fast (broken connection), not sit in `pending`
    // until the client timeout / run end skews the latency stats.
    let mut p = base(ConsistencyMode::LeaseGuard, 17);
    p.op_timeout_us = 8_000_000; // longer than the whole run: a leaked
                                 // op would ride to the drain
    p.restart_after_us = 400_000;
    let rep = Cluster::new(p).run();
    linearizability::assert_linearizable(&rep.history);
    let worst = rep
        .history
        .entries
        .iter()
        .map(|e| e.end_ts - e.start_ts)
        .max()
        .expect("history not empty");
    // Post-crash gate waits bound successful ops near Δ/2; only a
    // leaked in-flight op could approach run length (~2s).
    assert!(
        worst < 1_500_000,
        "in-flight ops at the crash must fail promptly, worst latency {worst}µs"
    );
}

#[test]
fn seeds_are_reproducible_and_distinct() {
    let a = Cluster::new(base(ConsistencyMode::LeaseGuard, 77)).run();
    let b = Cluster::new(base(ConsistencyMode::LeaseGuard, 77)).run();
    let c = Cluster::new(base(ConsistencyMode::LeaseGuard, 78)).run();
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.history.entries.len(), b.history.entries.len());
    assert_ne!(
        (a.events_processed, a.t0),
        (c.events_processed, c.t0),
        "different seeds should diverge"
    );
}
