//! Lagging-follower catch-up over the real TCP cluster: with an
//! aggressive compaction threshold, a follower that misses enough of
//! the log can only rejoin through the chunked InstallSnapshot path —
//! and the whole run must still linearize.
//!
//! The drill: 3 durable nodes under open-loop client load, kill one
//! follower, let the leader compact past its log, respawn it from its
//! data dir, and require (a) the leader actually took snapshots, (b)
//! the follower actually installed one (observed via the live metrics
//! registry, i.e. exactly what `leaseguard stat` reports), (c) one
//! linearizable history across the outage, and (d) the follower's data
//! dir recovers offline as snapshot + WAL suffix with its hard state
//! intact — the lease is re-derived from the timestamped log, never
//! resurrected from a snapshot (a resurrected lease would surface here
//! as a stale read the checker flags).

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::path::PathBuf;
use std::thread;
use std::time::{Duration, Instant};

use leaseguard::client::run_open_loop;
use leaseguard::config::{ConsistencyMode, Params};
use leaseguard::figures::realcluster::RealCluster;
use leaseguard::linearizability;
use leaseguard::storage::{FsyncPolicy, Storage};
use leaseguard::testkit::TempDir;

#[test]
fn lagging_follower_catches_up_via_snapshot_install() {
    let seed: u64 =
        std::env::var("CRASHTEST_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(7);
    let mut p = Params::default();
    p.consistency = ConsistencyMode::LeaseGuard;
    p.nodes = 3;
    p.election_timeout_us = 200_000;
    p.election_jitter_us = 150_000;
    p.heartbeat_us = 50_000;
    p.lease_duration_us = 400_000;
    p.duration_us = 2_200_000;
    p.interarrival_us = 700.0;
    p.value_bytes = 64;
    p.seed = seed;
    // Aggressive on purpose: the outage below spans hundreds of writes,
    // so the leader's log base is guaranteed to move past the victim.
    p.snapshot_threshold = 32;

    let dirs: Vec<TempDir> =
        (0..p.nodes).map(|i| TempDir::new(&format!("snap-catchup-{seed}-{i}"))).collect();
    let paths: Vec<PathBuf> = dirs.iter().map(|d| d.path().to_path_buf()).collect();
    let mut cluster =
        RealCluster::spawn_durable(&p, Duration::ZERO, None, &paths, FsyncPolicy::Group)
            .expect("spawn");
    let leader = cluster.wait_for_leader(Duration::from_secs(10)).expect("leader");
    let victim = (leader + 1) % p.nodes;

    let addrs = cluster.addrs.clone();
    let applies = cluster.applies.clone();
    let pc = p.clone();
    let client = thread::spawn(move || run_open_loop(&addrs, &pc, Some(applies)));

    // Let the workload build some log, then take the follower down for
    // long enough that compaction passes it by.
    thread::sleep(Duration::from_millis(300));
    cluster.kill(victim);
    thread::sleep(Duration::from_millis(700));
    cluster.respawn(victim).expect("respawn");

    // The respawned follower must report an installed snapshot — the
    // same counter `leaseguard stat --json` exposes. Polling the live
    // registry (rather than the final report) pins the wire path: a
    // follower that silently caught up via plain AppendEntries would
    // hang here.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let installed =
            cluster.handles[victim].as_ref().unwrap().status.group(0).snapshots_installed.get();
        if installed > 0 {
            break;
        }
        assert!(Instant::now() < deadline, "follower never installed a snapshot");
        thread::sleep(Duration::from_millis(25));
    }

    let rep = client.join().unwrap().expect("client");
    let taken: u64 = cluster
        .handles
        .iter()
        .flatten()
        .map(|h| h.status.group(0).snapshots_taken.get())
        .sum();
    assert!(taken > 0, "threshold 32 under this load must trigger compaction");
    let base_seen =
        cluster.handles[victim].as_ref().unwrap().status.group(0).last_snapshot_index.get();
    assert!(base_seen > 0, "victim's log base never advanced past zero");
    cluster.shutdown();

    // One linearizable history across kill, catch-up, and rejoin.
    let viol = linearizability::check(&rep.history);
    assert!(viol.is_empty(), "history not linearizable: {:?}", viol.first());

    // Offline recovery of the victim's dir: snapshot + WAL suffix, hard
    // state preserved. This is the "compacted node reboots" half of the
    // drill, without spinning the server back up.
    let (_, ds) = Storage::open(&paths[victim], FsyncPolicy::Group).unwrap();
    let snap = ds.snapshot.expect("victim dir holds a snapshot after catch-up");
    assert!(snap.meta.last_index > 0);
    assert_eq!(ds.log.base(), snap.meta.last_index, "log base must match the snapshot");
    assert!(
        ds.log.last_index() >= ds.log.base(),
        "suffix never behind the base: {} < {}",
        ds.log.last_index(),
        ds.log.base()
    );
    assert!(ds.current_term > 0, "hard state (term) lost by snapshot recovery");
}
