//! Snapshot + segmented-WAL recovery under corruption: tearing the
//! newest snapshot at EVERY byte offset must silently fall back to the
//! previous snapshot plus a longer segment replay — never panic, never
//! lose the log suffix, never block subsequent appends.
//!
//! Deterministic rotation/recovery cases live next to the
//! implementation (`rust/src/storage/`, `rust/src/snap/`); this suite
//! drives the public `Storage` API the way a rebooting server does.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use std::path::Path;

use leaseguard::clock::TimeInterval;
use leaseguard::kv::{Command, Store};
use leaseguard::prob::Rng;
use leaseguard::raft::{Entry, Log};
use leaseguard::snap::{self, file as snapfile};
use leaseguard::storage::{FsyncPolicy, Storage};
use leaseguard::testkit::TempDir;

fn put_entry(term: u64, i: u64) -> Entry {
    Entry {
        term,
        command: Command::Put { key: i as u32, value: i, payload_bytes: 0 },
        written_at: TimeInterval::exact(i as i64 * 100),
    }
}

/// Append entries up to `upto`, then snapshot + rotate at `snap_at`
/// (the same sequence a running node performs when its compaction
/// threshold fires).
fn grow_and_snapshot(s: &mut Storage, log: &mut Log, store: &mut Store, upto: u64, snap_at: u64) {
    for i in (log.last_index() + 1)..=upto {
        let e = put_entry(1, i);
        log.append(e);
        s.append(i, &e).unwrap();
    }
    s.sync().unwrap();
    while store.applied() < snap_at {
        let i = store.applied() + 1;
        store.apply(&Command::Put { key: i as u32, value: i, payload_bytes: 0 });
    }
    log.compact_to(snap_at);
    let snap = snap::encode(
        store,
        snap::SnapMeta {
            group: 0,
            last_index: log.base(),
            last_term: log.base_term(),
            last_written_at: log.base_written_at(),
            applied: store.applied(),
        },
    );
    s.install_snapshot(&snap, log).unwrap();
}

/// Build the canonical two-snapshot directory: entries 1..=12 with
/// snapshots at 4 and 8. After retention pruning the directory holds
/// `snap-4, snap-8, wal-4 (5..=8), wal-8 (9..=12, live)`.
fn build_two_snapshot_dir(dir: &Path) {
    let (mut s, _) = Storage::open(dir, FsyncPolicy::Never).unwrap();
    let mut log = Log::default();
    let mut store = Store::new();
    grow_and_snapshot(&mut s, &mut log, &mut store, 8, 4);
    grow_and_snapshot(&mut s, &mut log, &mut store, 12, 8);
    assert_eq!(s.segment_base(), 8);
    assert_eq!(snapfile::list(dir).unwrap(), vec![4, 8]);
    assert_eq!(snapfile::list_segments(dir).unwrap(), vec![4, 8]);
}

/// Open the dir and assert the invariant every recovery must provide:
/// base at `want_base`, full suffix to 12, entries intact.
fn assert_recovers(dir: &Path, want_base: u64, ctx: &str) {
    let (_, ds) = Storage::open(dir, FsyncPolicy::Never).unwrap();
    assert_eq!(ds.log.base(), want_base, "{ctx}: wrong recovery base");
    assert_eq!(ds.log.last_index(), 12, "{ctx}: lost the log suffix");
    for i in (want_base + 1)..=12 {
        assert_eq!(ds.log.get(i).unwrap(), &put_entry(1, i), "{ctx}: entry {i}");
    }
    let snap = ds.snapshot.unwrap_or_else(|| panic!("{ctx}: no snapshot recovered"));
    assert_eq!(snap.meta.last_index, want_base, "{ctx}: snapshot/base mismatch");
    let c = snap::decode(&snap.data).unwrap_or_else(|e| panic!("{ctx}: {e:?}"));
    assert_eq!(c.meta.applied, want_base, "{ctx}: snapshot applied counter");
    assert_eq!(c.pairs.len(), want_base as usize, "{ctx}: snapshot key count");
}

#[test]
fn torn_newest_snapshot_every_cut_point_falls_back() {
    // Cut the newest snapshot file at EVERY byte offset: recovery must
    // always yield base 4 (the previous snapshot) with the full suffix
    // replayed from the retained segments — except the uncut file,
    // which must recover at base 8. Exhaustive, not sampled — the file
    // is only a few hundred bytes.
    let d = TempDir::new("snaprec-cuts");
    build_two_snapshot_dir(d.path());
    let newest = d.path().join(snapfile::snap_name(8));
    let full = std::fs::read(&newest).unwrap();
    for cut in 0..=full.len() {
        std::fs::write(&newest, &full[..cut]).unwrap();
        let want = if cut == full.len() { 8 } else { 4 };
        assert_recovers(d.path(), want, &format!("cut {cut}/{}", full.len()));
    }
}

#[test]
fn bit_rot_in_newest_snapshot_always_falls_back() {
    // Any single-bit flip lands in the CRC, the length, or the payload:
    // all three make the file invisible and recovery must fall back —
    // returning changed-but-valid data is the one unacceptable outcome.
    let d = TempDir::new("snaprec-rot");
    build_two_snapshot_dir(d.path());
    let newest = d.path().join(snapfile::snap_name(8));
    let full = std::fs::read(&newest).unwrap();
    let mut rng = Rng::new(0xB17_207);
    for case in 0..200 {
        let mut bad = full.clone();
        let i = rng.below(bad.len() as u64) as usize;
        bad[i] ^= 1 << rng.below(8);
        std::fs::write(&newest, &bad).unwrap();
        assert_recovers(d.path(), 4, &format!("flip case {case} at byte {i}"));
    }
}

#[test]
fn appends_continue_cleanly_after_fallback_recovery() {
    // Recovery from a torn newest snapshot leaves a fully writable
    // store: new appends land in the live segment and survive another
    // reboot (still under the fallback snapshot).
    let d = TempDir::new("snaprec-continue");
    build_two_snapshot_dir(d.path());
    let newest = d.path().join(snapfile::snap_name(8));
    let full = std::fs::read(&newest).unwrap();
    std::fs::write(&newest, &full[..full.len() / 2]).unwrap();
    {
        let (mut s, ds) = Storage::open(d.path(), FsyncPolicy::Never).unwrap();
        assert_eq!(ds.log.base(), 4);
        for i in 13..=16u64 {
            s.append(i, &put_entry(1, i)).unwrap();
        }
        s.sync().unwrap();
    }
    let (_, ds) = Storage::open(d.path(), FsyncPolicy::Never).unwrap();
    assert_eq!(ds.log.base(), 4);
    assert_eq!(ds.log.last_index(), 16);
    for i in 5..=16u64 {
        assert_eq!(ds.log.get(i).unwrap(), &put_entry(1, i));
    }
}

#[test]
fn stray_tmp_snapshot_is_ignored_at_recovery() {
    // A crash between tmp write and rename leaves `snap-*.tmp` debris;
    // it must neither be loaded nor shadow the real newest snapshot.
    let d = TempDir::new("snaprec-tmp");
    build_two_snapshot_dir(d.path());
    let tmp = d.path().join(format!("{}.tmp", snapfile::snap_name(20)));
    std::fs::write(&tmp, b"half-written garbage").unwrap();
    assert_recovers(d.path(), 8, "tmp debris present");
    assert_eq!(snapfile::list(d.path()).unwrap(), vec![4, 8], "tmp file listed as a snapshot");
}
