//! Wire-codec round-trip property tests (`decode(encode(f)) == f`) via
//! the in-repo `testkit` property harness.
//!
//! Coverage contract (PR satellite): random frames over every
//! [`Message`] variant, every [`FailReason`], empty entry batches,
//! zero-length payloads, and random group ids — the cases the
//! `EntryBatch` and multi-Raft framing refactors could plausibly have
//! perturbed — plus header tampering (magic / version) on every shape.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

use leaseguard::clock::TimeInterval;
use leaseguard::kv::Command;
use leaseguard::prob::Rng;
use leaseguard::raft::log::Entry;
use leaseguard::raft::types::{FailReason, OpResult};
use leaseguard::raft::{EntryBatch, Message};
use leaseguard::server::wire::{self, ClientReq, ClientResp, Frame};
use leaseguard::testkit::{assert_prop, PropConfig};

const FAIL_REASONS: [FailReason; 6] = [
    FailReason::NotLeader,
    FailReason::NoLease,
    FailReason::LimboConflict,
    FailReason::CommitGateClosed,
    FailReason::MaybeCommitted,
    FailReason::Timeout,
];

fn gen_command(rng: &mut Rng) -> Command {
    match rng.below(3) {
        0 => Command::Noop,
        1 => Command::EndLease,
        _ => Command::Put {
            key: rng.next_u64() as u32,
            value: rng.next_u64(),
            payload_bytes: rng.below(1 << 20) as u32,
        },
    }
}

fn gen_entry(rng: &mut Rng) -> Entry {
    let lo = rng.range_i64(-1_000, 5_000_000);
    Entry {
        term: rng.below(64),
        command: gen_command(rng),
        written_at: TimeInterval::new(lo, lo + rng.range_i64(0, 500)),
    }
}

fn gen_batch(rng: &mut Rng) -> EntryBatch {
    // Bias toward the edge cases: ~1/4 of batches are empty.
    let n = if rng.chance(0.25) { 0 } else { rng.below(65) as usize };
    (0..n).map(|_| gen_entry(rng)).collect::<Vec<_>>().into()
}

fn gen_result(rng: &mut Rng) -> OpResult {
    match rng.below(3) {
        0 => OpResult::WriteOk,
        1 => {
            let n = rng.below(16) as usize; // includes the empty read
            OpResult::ReadOk((0..n).map(|_| rng.next_u64()).collect::<Vec<u64>>().into())
        }
        _ => OpResult::Failed(FAIL_REASONS[rng.below(6) as usize]),
    }
}

fn gen_frame(rng: &mut Rng) -> Frame {
    match rng.below(8) {
        0 => Frame::HelloPeer { from: rng.below(16) as usize },
        1 => Frame::Raft {
            from: rng.below(8) as usize,
            group: rng.below(64) as u32,
            msg: Message::RequestVote {
                term: rng.below(1000),
                candidate: rng.below(8) as usize,
                last_log_index: rng.next_u64() >> 20,
                last_log_term: rng.below(1000),
            },
        },
        2 => Frame::Raft {
            from: rng.below(8) as usize,
            group: rng.below(64) as u32,
            msg: Message::VoteReply {
                term: rng.below(1000),
                voter: rng.below(8) as usize,
                granted: rng.chance(0.5),
            },
        },
        3 | 4 => Frame::Raft {
            from: rng.below(8) as usize,
            group: rng.below(64) as u32,
            msg: Message::AppendEntries {
                term: rng.below(1000),
                leader: rng.below(8) as usize,
                prev_index: rng.below(1 << 20),
                prev_term: rng.below(1000),
                entries: gen_batch(rng),
                leader_commit: rng.below(1 << 20),
                seq: rng.next_u64(),
            },
        },
        5 => Frame::Raft {
            from: rng.below(8) as usize,
            group: rng.below(64) as u32,
            msg: Message::AppendReply {
                term: rng.below(1000),
                from: rng.below(8) as usize,
                success: rng.chance(0.5),
                match_index: rng.below(1 << 20),
                seq: rng.next_u64(),
            },
        },
        6 => Frame::ClientReq(ClientReq {
            op: rng.next_u64(),
            key: rng.next_u64() as u32,
            write_value: if rng.chance(0.5) { Some(rng.next_u64()) } else { None },
            // Zero-length payloads are ~1/3 of the cases.
            payload: if rng.chance(0.33) {
                Vec::new()
            } else {
                (0..rng.below(2048)).map(|_| rng.next_u64() as u8).collect()
            },
        }),
        _ => Frame::ClientResp(ClientResp {
            op: rng.next_u64(),
            exec_us: rng.range_i64(-10, 10_000_000),
            result: gen_result(rng),
        }),
    }
}

#[test]
fn prop_wire_roundtrip_random_frames() {
    assert_prop(
        PropConfig { cases: 2000, seed: 0x71BE, max_shrink_steps: 0 },
        gen_frame,
        |_| Vec::new(),
        |f| {
            let enc = wire::encode(f);
            match wire::decode(&enc) {
                Ok(dec) if dec == *f => Ok(()),
                Ok(dec) => Err(format!("roundtrip mismatch:\n  in: {f:?}\n out: {dec:?}")),
                Err(e) => Err(format!("decode failed: {e:?} for {f:?}")),
            }
        },
    );
}

#[test]
fn prop_wire_truncation_never_panics() {
    // Any prefix of a valid encoding must decode to a clean error (or a
    // valid frame for the full length), never panic.
    assert_prop(
        PropConfig { cases: 300, seed: 0x7A11C, max_shrink_steps: 0 },
        gen_frame,
        |_| Vec::new(),
        |f| {
            let enc = wire::encode(f);
            // Stride large frames so the debug-build cost stays bounded
            // (every cut point is still exercised for small frames).
            let step = 1 + enc.len() / 256;
            for cut in (0..enc.len()).step_by(step) {
                if wire::decode(&enc[..cut]).is_ok() {
                    return Err(format!("truncated prefix {cut}/{} decoded", enc.len()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_wire_header_tamper_rejected() {
    // Every frame starts with the versioned header; corrupting the magic
    // or bumping the version must fail cleanly for any frame shape.
    assert_prop(
        PropConfig { cases: 300, seed: 0xBEEF, max_shrink_steps: 0 },
        gen_frame,
        |_| Vec::new(),
        |f| {
            let mut enc = wire::encode(f);
            enc[1] = enc[1].wrapping_add(1); // future wire version
            if wire::decode(&enc).is_ok() {
                return Err(format!("bumped version decoded for {f:?}"));
            }
            enc[1] = enc[1].wrapping_sub(1);
            enc[0] ^= 0xFF; // corrupt magic
            if wire::decode(&enc).is_ok() {
                return Err(format!("corrupt magic decoded for {f:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn every_fail_reason_roundtrips() {
    for r in FAIL_REASONS {
        let f = Frame::ClientResp(ClientResp {
            op: 1,
            exec_us: 0,
            result: OpResult::Failed(r),
        });
        assert_eq!(wire::decode(&wire::encode(&f)).unwrap(), f, "{r:?}");
    }
}

#[test]
fn empty_batch_and_empty_payload_roundtrip() {
    let hb = Frame::Raft {
        from: 0,
        group: 0,
        msg: Message::AppendEntries {
            term: 1,
            leader: 0,
            prev_index: 5,
            prev_term: 1,
            entries: EntryBatch::empty(),
            leader_commit: 5,
            seq: 77,
        },
    };
    assert_eq!(wire::decode(&wire::encode(&hb)).unwrap(), hb);
    let req = Frame::ClientReq(ClientReq { op: 2, key: 0, write_value: None, payload: vec![] });
    assert_eq!(wire::decode(&wire::encode(&req)).unwrap(), req);
}
