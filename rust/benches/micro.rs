//! Microbenchmarks of the hot paths (harness=false; criterion is not
//! available offline). Feeds EXPERIMENTS.md §Perf: event-loop
//! throughput, node write path, read admission (scalar vs XLA engine at
//! several batch sizes), histogram recording, wire codec.

use std::path::Path;
use std::time::Instant;

use leaseguard::clock::TimeInterval;
use leaseguard::cluster::Cluster;
use leaseguard::config::{ConsistencyMode, Params};
use leaseguard::figures::fig8::limbo_leader;
use leaseguard::metrics::Histogram;
use leaseguard::prob::Rng;
use leaseguard::runtime::{hash_key, scalar_admission, AdmissionEngine, AdmissionInputs};
use leaseguard::server::wire::{self, ClientReq, Frame};
use leaseguard::sim::EventQueue;

fn bench<F: FnMut() -> u64>(name: &str, mut f: F) {
    // Warmup + 3 timed reps; report best ops/s.
    f();
    let mut best = 0.0f64;
    let mut last_ops = 0;
    for _ in 0..3 {
        let t0 = Instant::now();
        let ops = f();
        let dt = t0.elapsed().as_secs_f64();
        best = best.max(ops as f64 / dt);
        last_ops = ops;
    }
    println!("{name:<44} {:>14.0} ops/s  ({last_ops} ops/rep)", best);
}

fn main() {
    println!("== leaseguard microbenches ==");

    bench("event_loop: schedule+pop", || {
        let mut q = EventQueue::new();
        let n = 1_000_000u64;
        for i in 0..n {
            q.schedule(i as i64, i);
        }
        let mut popped = 0;
        while q.pop().is_some() {
            popped += 1;
        }
        popped
    });

    bench("sim: full availability run (events)", || {
        let mut p = Params::default();
        p.consistency = ConsistencyMode::LeaseGuard;
        p.duration_us = 1_000_000;
        p.interarrival_us = 100.0;
        p.crash_leader_at_us = 300_000;
        let rep = Cluster::new(p).run();
        rep.events_processed
    });

    bench("admission: scalar 256q x 64 limbo", || {
        let inp = AdmissionInputs {
            query_hashes: (0..256).map(hash_key).collect(),
            limbo_hashes: (0..64).map(hash_key).collect(),
            commit_age_us: 10,
            delta_us: 1_000_000,
            own_term_commit: false,
        };
        let mut total = 0u64;
        for _ in 0..2000 {
            total += scalar_admission(&inp).iter().filter(|&&b| b).count() as u64;
        }
        2000 * 256
    });

    if Path::new("artifacts/manifest.json").exists() {
        let engine = AdmissionEngine::load(Path::new("artifacts")).expect("engine");
        for (nq, nl) in [(64usize, 64usize), (256, 128), (1024, 256)] {
            bench(&format!("admission: XLA engine {nq}q x {nl} limbo"), || {
                let inp = AdmissionInputs {
                    query_hashes: (0..nq as u32).map(hash_key).collect(),
                    limbo_hashes: (0..nl as u32).map(hash_key).collect(),
                    commit_age_us: 10,
                    delta_us: 1_000_000,
                    own_term_commit: false,
                };
                let reps = 200;
                for _ in 0..reps {
                    let _ = engine.admit(&inp).unwrap();
                }
                (reps * nq) as u64
            });
        }
    } else {
        println!("(XLA engine benches skipped: run `make artifacts`)");
    }

    bench("node: batched read admission path (limbo)", || {
        let p = Params::default();
        let mut node = limbo_leader(&p, 100, 0.5, 3);
        let ops: Vec<(u64, u32)> = (0..1024u64).map(|i| (i, (i % 1000) as u32)).collect();
        let now = TimeInterval::exact(1_200_000);
        let reps = 200;
        for _ in 0..reps {
            let _ = node.client_read_batch(now, &ops, |i| scalar_admission(i));
        }
        reps * ops.len() as u64
    });

    bench("metrics: histogram record+p99", || {
        let mut h = Histogram::new();
        let mut r = Rng::new(1);
        let n = 2_000_000u64;
        for _ in 0..n {
            h.record(r.below(1_000_000) as i64);
        }
        assert!(h.p99() > 0);
        n
    });

    bench("wire: encode+decode 1KiB write req", || {
        let req = Frame::ClientReq(ClientReq {
            op: 1,
            key: 7,
            write_value: Some(9),
            payload: vec![0xA5; 1024],
        });
        let n = 100_000u64;
        for _ in 0..n {
            let enc = wire::encode(&req);
            let dec = wire::decode(&enc).unwrap();
            assert!(matches!(dec, Frame::ClientReq(_)));
        }
        n
    });

    println!("== done ==");
}
