//! Microbenchmarks of the hot paths (harness=false; criterion is not
//! available offline). The suite itself lives in [`leaseguard::bench`]
//! so `leaseguard bench` (CLI) and this target share one implementation.
//!
//! `cargo bench --bench micro -- --json [PATH]` additionally writes the
//! machine-readable trajectory (default `BENCH_micro.json` at the repo
//! root) — see `scripts/bench.sh`.

#![allow(clippy::unwrap_used, clippy::expect_used)] // see Cargo.toml [lints]: unwraps here are test/driver/startup paths, not untrusted input

fn main() {
    // cargo passes `--bench` to harness=false targets; ignore unknowns.
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut json: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        if let Some(v) = args[i].strip_prefix("--json=") {
            json = Some(v.to_string());
        } else if args[i] == "--json" {
            match args.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    json = Some(v.clone());
                    i += 1;
                }
                _ => json = Some("BENCH_micro.json".to_string()),
            }
        }
        i += 1;
    }

    println!("== leaseguard microbenches ==");
    let results = leaseguard::bench::run_suite();
    if let Some(path) = json {
        leaseguard::bench::write_json(std::path::Path::new(&path), &results)
            .expect("write bench json");
        println!("wrote {path}");
    }
    println!("== done ==");
}
