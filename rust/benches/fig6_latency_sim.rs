//! Bench harness for paper Figure 6 (criterion is unavailable offline;
//! this is a harness=false bench target). Regenerates the figure at a
//! reduced scale by default; run the binary/CLI form
//! (`leaseguard figure 6`) for paper-sized runs.

use leaseguard::config::Params;
use leaseguard::figures::{run_figure, Scale};

fn main() {
    let scale = std::env::var("LEASEGUARD_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.4f64);
    let t0 = std::time::Instant::now();
    let params = Params::default();
    std::fs::create_dir_all("results").ok();
    match run_figure(6, &params, Scale(scale), "results") {
        Ok(report) => {
            println!("{report}");
            println!("bench fig6 wall time: {:.1}s (scale {scale})", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("bench fig6 failed: {e:#}");
            std::process::exit(1);
        }
    }
}
